"""Roofline analysis over the dry-run artifacts (§Roofline deliverable).

Derives the three roofline terms per (arch x shape x mesh) cell from the
compiled-HLO statistics recorded by repro.launch.dryrun:

    compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16, v5e)
    memory     = HLO_bytes / HBM_bw                (819 GB/s)
    collective = ICI_bytes / link_bw               (~50 GB/s/link)

All numerators are PER-DEVICE (the dry-run parses the SPMD-partitioned
module with while-loop trip weighting), so no further division by chip
count is needed.  MODEL_FLOPS uses the standard accounting:
    train:   6 * N * D      (D = global tokens; N = active params for MoE)
    prefill: 2 * N * D
    decode:  2 * N * B      (one new token per row)
divided by the mesh's chip count for the per-device ratio.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Any, Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

MESH_CHIPS = {"16x16": 256, "2x16x16": 512}


def model_flops(rec: Dict[str, Any]) -> Optional[float]:
    from repro.configs import get_config, get_shape
    cfg = get_config(rec["arch"])
    shape = get_shape(rec["shape"])
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch        # decode: one token/row


def analyze(rec: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if rec.get("status") != "ok":
        return None
    chips = MESH_CHIPS[rec["mesh"]]
    t_comp = rec["hlo_flops"] / PEAK_FLOPS
    t_mem = rec["hlo_bytes"] / HBM_BW
    t_coll = rec["ici_bytes"] / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    mf_dev = mf / chips if mf else None
    useful = (mf_dev / rec["hlo_flops"]
              if mf_dev and rec["hlo_flops"] else None)
    # roofline fraction: useful model FLOPs over the time the dominant
    # term would take (what MFU would be if the bottleneck ran at peak)
    bound_s = max(terms.values())
    frac = (mf_dev / PEAK_FLOPS) / bound_s if mf_dev and bound_s else None
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_dev": mf_dev,
        "useful_ratio": useful,
        "roofline_fraction": frac,
        "hlo_flops": rec["hlo_flops"], "hlo_bytes": rec["hlo_bytes"],
        "ici_bytes": rec["ici_bytes"],
        "collectives": rec.get("collectives", {}),
        "bytes_per_device": rec.get("bytes_per_device", {}),
    }


def advice(row: Dict[str, Any]) -> str:
    d = row["dominant"]
    if d == "collective":
        kinds = sorted(row["collectives"].items(),
                       key=lambda kv: -kv[1]["ici_bytes"])
        top = kinds[0][0] if kinds else "?"
        return (f"cut {top} traffic (cast weights to bf16 before "
                "all-gather / shard the gathered dim / overlap with scan)")
    if d == "memory":
        return ("raise arithmetic intensity (fuse elementwise chains, "
                "keep KV/state in lower precision, larger per-step tiles)")
    if row.get("useful_ratio") and row["useful_ratio"] < 0.5:
        return ("reduce non-model FLOPs (remat policy, causal-masked "
                "attention waste, replicated heads on the model axis)")
    return "near compute roof; only kernel-level MXU utilization remains"


def load(dir_: str) -> List[Dict[str, Any]]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        row = analyze(rec)
        if row:
            rows.append(row)
    return rows


def render_table(rows: List[Dict[str, Any]], mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | "
        "dominant | useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        u = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "-"
        fr = f"{r['roofline_fraction']:.2f}" \
            if r["roofline_fraction"] else "-"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"{r['dominant']} | {u} | {fr} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rows = load(args.dir)
    print(render_table(rows, args.mesh))
    print()
    for r in rows:
        if r["mesh"] == args.mesh:
            print(f"{r['arch']:20s} {r['shape']:12s} -> {advice(r)}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
