"""Perf-regression gate: fresh smoke bench runs vs committed baselines.

Runs the three JSON-emitting benchmarks on the ``--smoke`` workload and
compares each result against the committed baseline under
``benchmarks/baselines/BENCH_<name>.json``.  Each bench runs in its own
subprocess so every run pays its own jit warm-up: numbers stay
comparable whether you run all three benches or a ``--bench`` subset
(in one shared process, whichever bench ran first would absorb the
compile cost and cold-start metrics like ``cold_ingest_fps`` would
swing 4x on ordering alone).

Checks:

  * **bit-identity gates** — boolean fields that encode correctness
    (tracks identical across engines, rows scanned exactly once,
    indexed == scan, re-query after eviction identical...) must never
    flip from their expected value.  Any flip fails the run regardless
    of tolerances.
  * **fps tolerances** — throughput metrics may not drop more than
    ``--tol`` (default 20%) below the baseline.  Regression-direction
    only: running FASTER than the baseline never fails.
  * **workload context** — numeric comparison only applies when the
    fresh run and the baseline describe the same workload (profile,
    clip count, frames per clip, smoke flag).  A mismatch means the
    baseline is stale, which is reported as a warning and skips the
    fps check — bit-identity gates still apply.

``--update`` regenerates the baselines in place (run it after an
intentional perf change and commit the new JSON).

    PYTHONPATH=src python -m benchmarks.bench_diff --smoke
    PYTHONPATH=src python -m benchmarks.bench_diff --smoke --update

Exit status 0 = all gates pass, 1 = regression (CI fails the job).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
from typing import Dict, List, Tuple

BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baselines")
BENCHES = ("pipeline", "stream", "query")

# throughput metrics (dotted paths into the result dict), higher is
# better for every one of them; timing *ratios* (speedups, sub-ms
# medians) stay out — on the smoke workload those are jitter, not perf
FPS_METRICS: Dict[str, List[str]] = {
    "pipeline": ["fps_per_frame", "fps_chunked", "fps_streaming",
                 "fps_streaming_device_tracker",
                 "exporter.fps_scrape_on"],
    "stream": ["append_fps"],
    "query": ["cold_ingest_fps", "queries_per_second"],
}

# per-metric tolerance overrides for quantities built from sub-ms
# measurements, where single-core scheduling noise swings far beyond
# the default fps tolerance run to run
METRIC_TOL: Dict[str, float] = {
    "queries_per_second": 0.60,
    # wall fps of a 4-thread broker fleet — thread scheduling on a
    # shared runner swings this well past the default tolerance
    "exporter.fps_scrape_on": 0.50,
}

# bit-identity gates: (path, expected value); any flip fails the run.
# Only determinism invariants belong here — timing-shaped flags like
# jit_entries_grew_after_warmup vary with broker coalescing and stay out
GATES: Dict[str, List[Tuple[str, bool]]] = {
    "pipeline": [("tracks_identical", True),
                 ("device_tracks_identical", True),
                 ("exporter.tracks_identical", True)],
    "stream": [("fleet.tracks_bit_identical", True),
               ("rows_scanned_exactly_once", True),
               ("standing_matches_adhoc_and_reference", True)],
    "query": [("limit_query_identical_to_inline_scan", True),
              ("index.indexed_equals_scan", True),
              ("eviction.requery_identical", True)],
}

# workload fields that must match for fps numbers to be comparable
WORKLOAD_KEYS = ("profile", "clips", "frames_per_clip",
                 "segment_frames", "smoke")


def _get(d, path: str):
    for part in path.split("."):
        if not isinstance(d, dict) or part not in d:
            return None
        d = d[part]
    return d


def _run_bench(name: str, smoke: bool) -> dict:
    """Run one bench in a fresh subprocess and return its result dict.

    A fresh interpreter per bench keeps jit caches cold for every run,
    so cold-start metrics mean the same thing regardless of which
    benches ran before (see the module docstring).
    """
    if name not in BENCHES:
        raise ValueError(f"unknown bench {name!r}")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    src = os.path.join(root, "src")
    env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else src)
    fd, out = tempfile.mkstemp(prefix=f"bench_{name}_",
                               suffix=".json")
    os.close(fd)
    try:
        cmd = [sys.executable, "-m", f"benchmarks.{name}_bench",
               "--out", out]
        if smoke:
            cmd.append("--smoke")
        subprocess.run(cmd, cwd=root, env=env, check=True)
        with open(out) as f:
            return json.load(f)
    finally:
        os.unlink(out)


def _workload_ctx(result: dict) -> dict:
    w = result.get("workload", {})
    return {k: w.get(k) for k in WORKLOAD_KEYS}


def compare(name: str, fresh: dict, baseline: dict,
            tol: float) -> Tuple[List[str], List[str]]:
    """(failures, warnings) for one bench's fresh-vs-baseline pair."""
    fails: List[str] = []
    warns: List[str] = []
    for path, want in GATES[name]:
        got = _get(fresh, path)
        if got is None:
            fails.append(f"{name}: bit-identity gate {path} missing "
                         f"from the fresh run")
        elif bool(got) != want:
            fails.append(f"{name}: bit-identity gate {path} flipped "
                         f"to {got} (want {want})")
    if _workload_ctx(fresh) != _workload_ctx(baseline):
        warns.append(f"{name}: baseline workload "
                     f"{_workload_ctx(baseline)} != fresh "
                     f"{_workload_ctx(fresh)} — stale baseline, "
                     f"fps comparison skipped (rerun --update)")
        return fails, warns
    for m in FPS_METRICS[name]:
        base_v = _get(baseline, m)
        got = _get(fresh, m)
        if base_v is None:
            warns.append(f"{name}: baseline lacks {m}, skipped")
            continue
        if got is None:
            fails.append(f"{name}: fps metric {m} missing from the "
                         f"fresh run")
            continue
        m_tol = max(tol, METRIC_TOL.get(m, tol))
        if base_v > 0 and got < base_v * (1.0 - m_tol):
            fails.append(f"{name}: {m} regressed {base_v:.2f} -> "
                         f"{got:.2f} fps (> {m_tol:.0%} drop)")
        else:
            warns.append(f"{name}: {m} {base_v:.2f} -> {got:.2f} ok")
    return fails, warns


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="compare on the smoke workload (the only "
                         "mode with committed baselines)")
    ap.add_argument("--bench", action="append", choices=BENCHES,
                    help="restrict to one bench (repeatable; "
                         "default all)")
    ap.add_argument("--baseline-dir", default=BASELINE_DIR)
    ap.add_argument("--tol", type=float, default=0.20,
                    help="max allowed fps drop vs baseline "
                         "(default 0.20)")
    ap.add_argument("--update", action="store_true",
                    help="regenerate the baselines instead of "
                         "comparing")
    args = ap.parse_args(argv)
    if not args.smoke:
        ap.error("only --smoke comparisons are supported (the "
                 "committed baselines are smoke-workload runs)")
    benches = args.bench or list(BENCHES)

    os.makedirs(args.baseline_dir, exist_ok=True)
    failures: List[str] = []
    for name in benches:
        path = os.path.join(args.baseline_dir, f"BENCH_{name}.json")
        print(f"[bench_diff] running {name} (smoke)...", flush=True)
        fresh = _run_bench(name, smoke=True)
        if args.update:
            with open(path, "w") as f:
                json.dump(fresh, f, indent=2)
                f.write("\n")
            print(f"[bench_diff] wrote baseline {path}")
            continue
        if not os.path.exists(path):
            failures.append(f"{name}: no committed baseline at {path} "
                            f"(run --update and commit it)")
            continue
        with open(path) as f:
            baseline = json.load(f)
        fails, warns = compare(name, fresh, baseline, args.tol)
        for w in warns:
            print(f"[bench_diff]   {w}")
        for msg in fails:
            print(f"[bench_diff]   FAIL {msg}")
        failures.extend(fails)

    if args.update:
        return 0
    if failures:
        print(f"[bench_diff] {len(failures)} regression(s):")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print("[bench_diff] all gates pass")
    return 0


if __name__ == "__main__":
    sys.exit(main())
