"""Render Table 1 / Fig 6 / Fig 7 / Fig 8 / Table 2 from the paper
artifacts written by repro.core.experiment."""
from __future__ import annotations

import json
from typing import List


def render_all(paths: List[str]) -> None:
    rows = []
    for p in paths:
        with open(p) as f:
            rows.append(json.load(f))
    if not rows:
        return

    methods = ["multiscope", "chameleon", "blazeit", "miris"]

    def _runtime_at(curve, best, slack):
        ok = [c["test_seconds"] for c in curve
              if c["test_accuracy"] >= best - slack]
        return min(ok) if ok else None

    for slack in (0.05, 0.10):
        label = ("paper's 5% band" if slack == 0.05 else
                 "10% band — noise-adjusted for the 10x smaller test "
                 "split vs the paper's 60 clips")
        print(f"\n-- Table 1: fastest test runtime (s) within "
              f"{int(slack * 100)}% of best accuracy ({label}) --")
        print(f"{'dataset':12s} "
              + " ".join(f"{m:>11s}" for m in methods)
              + "   speedup(vs next best)")
        speedups = []
        for r in rows:
            best = r["best_accuracy"]
            vals, t1 = [], {}
            for m in methods:
                v = _runtime_at(r["curves"][m], best, slack)
                t1[m] = v
                vals.append(f"{v:11.2f}" if v is not None
                            else f"{'-':>11s}")
            ms = t1.get("multiscope")
            others = [t1[m] for m in methods[1:]
                      if t1.get(m) is not None]
            sp = (min(others) / ms) if ms and others else None
            if sp:
                speedups.append(sp)
            print(f"{r['dataset']:12s} " + " ".join(vals)
                  + (f"   {sp:.2f}x" if sp else "   -"))
        if speedups:
            import numpy as np
            print(f"{'MEAN':12s} {'':47s}   "
                  f"{float(np.mean(speedups)):.2f}x")

    print("\n-- Fig 6: test speed-accuracy curves --")
    for r in rows:
        print(f"[{r['dataset']}]")
        for m, curve in r["curves"].items():
            pts = ", ".join(
                f"({c['test_seconds']:.2f}s,{c['test_accuracy']:.2f})"
                for c in curve)
            print(f"  {m:11s}: {pts}")

    for r in rows:
        if "ablation" in r:
            print(f"\n-- Fig 7: ablation ({r['dataset']}) --")
            for name, curve in r["ablation"].items():
                pts = ", ".join(
                    f"({c['test_seconds']:.2f}s,"
                    f"{c['test_accuracy']:.2f})" for c in curve)
                print(f"  {name:15s}: {pts}")
        if "mota" in r:
            print(f"\n-- Fig 8: count accuracy vs MOTA ({r['dataset']}) --")
            for row in r["mota"]:
                print(f"  count={row['count_accuracy']:.3f} "
                      f"mota={row['mota']:.3f}  {row['params'][:60]}")
        if "limit_query" in r:
            print(f"\n-- Table 2: limit query ({r['dataset']}) --")
            lq = r["limit_query"]
            for m in ("blazeit", "multiscope"):
                d = lq[m]
                print(f"  {m:11s}: pre={d['pre_seconds']:.1f}s "
                      f"query={d['query_seconds']:.2f}s "
                      f"correct={d['correct']}/{lq['want']}")


if __name__ == "__main__":
    import glob
    render_all(sorted(glob.glob("artifacts/paper/*.json")))
