"""Pipeline engine benchmark: per-frame reference vs chunked engine.

Measures frames/sec of both execution paths on the synthetic workload
(proxy enabled, recurrent tracker, gap=1) and emits a machine-readable
``BENCH_pipeline.json`` so future PRs have a perf trajectory to regress
against.  Timing uses ``RunResult.seconds`` — process time plus the
charged decode ledger — i.e. the same number the tuner optimizes.

    PYTHONPATH=src python -m benchmarks.pipeline_bench

Runs are interleaved and the median is reported (this container's
process scheduling is noisy); equivalence of extracted tracks between
the two engines is asserted on every rep.
"""
from __future__ import annotations

import json
import time

import numpy as np

DEFAULT_OUT = "BENCH_pipeline.json"


def build_workload(n_clips: int = 4, n_frames: int = 48,
                   train_steps: int = 150):
    from repro.configs.multiscope import MULTISCOPE_PIPELINE
    from repro.core import pipeline as pl
    from repro.core.proxy import ProxyModel
    from repro.core.tracker import init_tracker
    from repro.core.train_models import train_detector
    from repro.data.video_synth import make_split

    cfg = MULTISCOPE_PIPELINE.reduced()
    clips = make_split("caldot1", "train", n_clips, n_frames=n_frames)
    det, _ = train_detector("ssd-lite", clips[:2],
                            [cfg.detector.resolutions[-1]],
                            steps=train_steps)
    bank = pl.ModelBank(cfg, {"ssd-lite": det, "ssd-deep": det})
    res = cfg.proxy.resolutions[-1]
    proxy = ProxyModel(cfg.proxy.cell, cfg.proxy.base_channels, res)
    bank.proxies = {res: proxy}
    bank.sizes_cells = [pl.det_grid(cfg.detector.resolutions[-1]),
                        (3, 2), (5, 3)]
    bank.ref_grid = pl.det_grid(cfg.detector.resolutions[-1])
    bank.tracker_params = init_tracker(cfg.tracker)
    # calibrate the proxy threshold to the untrained proxy's score
    # distribution so the plan mixes sub-frame windows and full frames
    # (the MultiScope operating point)
    W, H = cfg.detector.resolutions[-1]
    frame, _ = pl.render_frame(clips[0], 0, W, H)
    s, _ = proxy.scores(pl._downsample(frame, res))
    threshold = float(np.quantile(s, 0.85))
    params = pl.PipelineParams(
        "ssd-lite", cfg.detector.resolutions[-1], 0.55, gap=1,
        proxy_res=res, proxy_threshold=threshold, tracker="recurrent",
        refine=False)
    return bank, params, clips


def run(out_path: str = DEFAULT_OUT, reps: int = 7) -> dict:
    from repro.core import pipeline as pl
    from repro.core.detector import detect_jit_entries
    from repro.core.engine import DEFAULT_CHUNK, run_clip_chunked

    bank, params, clips = build_workload()

    def sweep():
        """One paired rep: per clip, run BOTH engines back to back so
        each pair sees the same machine conditions (this container's
        scheduling is noisy; pairing cancels the drift)."""
        sa = sb = frames = 0.0
        same = True
        for clip in clips:
            ra = pl.run_clip_frames(bank, params, clip)
            rb = run_clip_chunked(bank, params, clip)
            sa += ra.seconds
            sb += rb.seconds
            frames += ra.frames_processed
            same &= len(ra.tracks) == len(rb.tracks) and all(
                np.array_equal(x, y)
                for x, y in zip(ra.tracks, rb.tracks))
        return frames / sa, frames / sb, same

    # warm: jit compiles + render cache for both paths
    sweep()
    entries_warm = detect_jit_entries()

    fps_frame, fps_chunk = [], []
    identical = True
    for _ in range(reps):
        fa, fb, same = sweep()
        fps_frame.append(fa)
        fps_chunk.append(fb)
        identical &= same

    ratios = [b / a for a, b in zip(fps_frame, fps_chunk)]

    result = {
        "benchmark": "pipeline_engine",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "workload": {
            "profile": "caldot1", "clips": len(clips),
            "frames_per_clip": int(clips[0].n_frames),
            "params": params.describe(), "chunk_size": DEFAULT_CHUNK,
            "reps": reps,
        },
        "fps_per_frame": float(np.median(fps_frame)),
        "fps_chunked": float(np.median(fps_chunk)),
        "fps_per_frame_all": [round(f, 2) for f in fps_frame],
        "fps_chunked_all": [round(f, 2) for f in fps_chunk],
        "speedup": float(np.median(ratios)),
        "speedup_all": [round(r, 3) for r in ratios],
        "tracks_identical": bool(identical),
        "detector_jit_entries": detect_jit_entries(),
        "jit_entries_grew_after_warmup":
            detect_jit_entries() != entries_warm,
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    assert identical, \
        "chunked engine diverged from the per-frame path (see " \
        + out_path + ")"
    return result


def main(out_path: str = DEFAULT_OUT) -> None:
    r = run(out_path)
    print(f"per-frame engine : {r['fps_per_frame']:8.1f} frames/sec")
    print(f"chunked engine   : {r['fps_chunked']:8.1f} frames/sec")
    print(f"speedup          : {r['speedup']:8.2f}x")
    print(f"tracks identical : {r['tracks_identical']}")
    print(f"detector jit entries: {r['detector_jit_entries']}"
          f" (stable after warmup: "
          f"{not r['jit_entries_grew_after_warmup']})")
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
