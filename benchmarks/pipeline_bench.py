"""Pipeline engine benchmark: per-frame reference vs chunked vs
streaming executor.

Measures frames/sec of the three scheduling modes on the synthetic
workload (trained proxy, recurrent tracker, gap=1) and emits a
machine-readable ``BENCH_pipeline.json`` so future PRs have a perf
trajectory to regress against.  ``chunk_size`` and ``executor`` fields
distinguish scheduling modes in that trajectory.  Timing uses
``RunResult.seconds`` — process time plus the charged decode ledger,
i.e. the same number the tuner optimizes; wall-clock rates are recorded
separately (prefetch overlaps decode with compute, which process time
by design does not reward).

    PYTHONPATH=src python -m benchmarks.pipeline_bench [--smoke]

Runs are interleaved and the median is reported (this container's
process scheduling is noisy); equivalence of extracted tracks across
all three modes is asserted on every rep.

A second phase (``fps_vs_streams``) scales concurrent streams (1/4/16
threads, one clip run each, per-frame ``chunk_size=1`` — the live
multi-camera regime) with and without a shared ``BatchBroker``,
recording wall fps, consolidated ``detector_dispatches`` and
``batch_fill_mean`` — and asserting both bit-identical tracks and
strictly fewer dispatches at >= 4 streams.  Each stream count also
runs with the device-resident TRACK path on (``device_assign`` through
the fused ``track_step`` kernel, steps coalesced by a shared
``TrackBroker``), recording ``fps_device_track`` /
``track_dispatches`` / ``track_fill_mean`` against the host-tracker
rows.  A chunked-regime phase compares the host tracker against
``DeviceTracker`` (one ``lax.scan`` dispatch per chunk), asserts
bit-identity (also the ``--smoke`` gate), and aggregates the per-stage
``stage_seconds`` utilization block from ``RunResult``.

The proxy threshold comes from the paper's threshold sweep over cached
validation score grids (``proxy.calibrate_threshold``) on a briefly
trained proxy — not from the old self-calibration against the untrained
score distribution.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

DEFAULT_OUT = "BENCH_pipeline.json"


def build_workload(n_clips: int = 4, n_frames: int = 48,
                   train_steps: int = 150, proxy_steps: int = 80):
    from repro.configs.multiscope import MULTISCOPE_PIPELINE
    from repro.core import pipeline as pl
    from repro.core.proxy import (ProxyModel, calibrate_threshold,
                                  cells_from_detections, proxy_loss)
    from repro.core.tracker import init_tracker
    from repro.core.train_models import _fit, train_detector
    from repro.data.video_synth import make_split
    import jax.numpy as jnp

    cfg = MULTISCOPE_PIPELINE.reduced()
    clips = make_split("caldot1", "train", n_clips, n_frames=n_frames)
    det, _ = train_detector("ssd-lite", clips[:2],
                            [cfg.detector.resolutions[-1]],
                            steps=train_steps)
    bank = pl.ModelBank(cfg, {"ssd-lite": det, "ssd-deep": det})
    res = cfg.proxy.resolutions[-1]
    proxy = ProxyModel(cfg.proxy.cell, cfg.proxy.base_channels, res)

    # detector outputs stand in for θ_best labels: train the proxy
    # briefly, then calibrate its threshold with the paper's sweep over
    # cached score grids (replaces the old untrained-quantile hack)
    W, H = cfg.detector.resolutions[-1]
    hc, wc = proxy.grid_shape()
    frames_px, labels, score_frames = [], [], []
    for ci, clip in enumerate(clips[:2]):
        for f in range(0, clip.n_frames, 2):
            frame, _ = pl.render_frame(clip, f, W, H)
            dets = det.detect_batch(frame[None], 0.55)[0]
            lab = cells_from_detections(dets, hc, wc)
            small = pl._downsample(frame, res)
            # hold out every 4th sampled frame of EACH clip (f is
            # always even, so keying on the sample index — not f —
            # keeps both clips contributing calibration frames)
            if (f // 2 + ci) % 4:
                frames_px.append(small)
                labels.append(lab)
            else:                       # held-out calibration frames
                score_frames.append((small, lab))
    rng = np.random.default_rng(0)
    fr = np.stack(frames_px)
    lb = np.stack(labels)

    def batches():
        for _ in range(proxy_steps):
            idx = rng.integers(len(fr), size=8)
            yield (jnp.asarray(fr[idx]), jnp.asarray(lb[idx]))

    params_p, _ = _fit(
        lambda p, f_, l_: proxy_loss(p, f_, l_, cfg.proxy.cell),
        proxy.params, batches(), lr=3e-3)
    proxy.params = params_p
    bank.proxies = {res: proxy}
    bank.sizes_cells = [pl.det_grid(cfg.detector.resolutions[-1]),
                        (3, 2), (5, 3)]
    bank.ref_grid = pl.det_grid(cfg.detector.resolutions[-1])
    bank.tracker_params = init_tracker(cfg.tracker)

    score_grids = [proxy.scores(s, 0.5)[0] for s, _ in score_frames]
    label_grids = [l for _, l in score_frames]
    threshold = calibrate_threshold(score_grids, label_grids,
                                    cfg.proxy.thresholds,
                                    min_recall=0.9)
    params = pl.PipelineParams(
        "ssd-lite", cfg.detector.resolutions[-1], 0.55, gap=1,
        proxy_res=res, proxy_threshold=threshold, tracker="recurrent",
        refine=False)
    return bank, params, clips


def stream_scaling(bank, params, clips, stream_counts=(1, 4, 16),
                   reps: int = 3) -> dict:
    """fps at N concurrent streams (threads, each its own clip run):
    one shared ``BatchBroker`` vs N fully independent runs.

    Streams run in PER-FRAME mode (``chunk_size=1``) — the live multi-
    camera regime the broker targets, where every stream issues one tiny
    detector call per frame and the fixed per-dispatch cost dominates.
    Cross-stream coalescing amortizes exactly that cost; with big chunks
    each stream already makes a couple of large calls per clip and there
    is nothing left to amortize on this host.

    Records the consolidated detector dispatch count and mean bucket
    fill alongside fps — the broker's win is fewer, fuller detector
    calls, and ``detector_dispatches`` must be strictly below the
    independent count from 4 streams up (asserted here, not just
    reported).  Independent/broker fleets alternate within each rep and
    medians are reported (single-core container, very noisy); a warm
    broker fleet runs first so consolidated-bucket conv compiles don't
    land in the measurement."""
    import dataclasses
    import threading

    from repro.core.executor import (BatchBroker, ExecutorOptions,
                                     TrackBroker, run_clip_streamed)

    params = dataclasses.replace(params, chunk_size=1)
    detector = bank.detectors[params.det_arch]

    def fleet(n, broker, track_broker=None, device=False):
        results = [None] * n
        errors = []

        def one(i):
            try:
                opts = ExecutorOptions(prefetch=False,
                                       batch_broker=broker,
                                       device_assign=device,
                                       track_broker=track_broker)
                results[i] = run_clip_streamed(
                    bank, params, clips[i % len(clips)], opts)
            except BaseException as exc:
                errors.append(exc)

        threads = [threading.Thread(target=one, args=(i,))
                   for i in range(n)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        assert not errors, errors
        frames = sum(r.frames_processed for r in results)
        return frames / wall, results

    def same_tracks(solo, got, what):
        for a, b in zip(solo, got):
            assert len(a.tracks) == len(b.tracks) and all(
                np.array_equal(x, y)
                for x, y in zip(a.tracks, b.tracks)), \
                f"{what} changed per-stream tracks"

    warm = BatchBroker()
    wtb = TrackBroker()
    _, ref = fleet(max(stream_counts), warm, wtb, device=True)
    warm.close()
    wtb.close()

    out = {}
    for n in stream_counts:
        fps_ind, fps_brk, fps_dev = [], [], []
        disp_ind, disp_brk, fills = [], [], []
        tdisp, tfills = [], []
        for _ in range(reps):
            detector.dispatches = 0
            fps, solo = fleet(n, None)
            fps_ind.append(fps)
            disp_ind.append(detector.dispatches)
            broker = BatchBroker()
            fps, got = fleet(n, broker)
            broker.close()
            fps_brk.append(fps)
            disp_brk.append(broker.dispatches)
            if broker.batch_fill:
                fills.append(float(np.mean(broker.batch_fill)))
            same_tracks(solo, got, "broker")
            # device-resident TRACK on top of the detector broker:
            # per-step assignment through the fused track_step kernel,
            # steps coalesced across streams by a shared TrackBroker
            broker = BatchBroker()
            tb = TrackBroker()
            fps, got = fleet(n, broker, tb, device=True)
            broker.close()
            tb.close()
            fps_dev.append(fps)
            tdisp.append(tb.dispatches)
            if tb.stream_fill:
                tfills.append(float(np.mean(tb.stream_fill)))
            same_tracks(solo, got, "device track path")
        if n >= 4:
            assert max(disp_brk) < min(disp_ind), \
                (n, disp_brk, disp_ind)
        out[str(n)] = {
            "fps_independent": round(float(np.median(fps_ind)), 2),
            "fps_broker": round(float(np.median(fps_brk)), 2),
            "fps_device_track": round(float(np.median(fps_dev)), 2),
            "detector_dispatches_independent": int(np.median(disp_ind)),
            "detector_dispatches": int(np.median(disp_brk)),
            "batch_fill_mean": round(float(np.mean(fills)), 4)
            if fills else 0.0,
            "track_dispatches": int(np.median(tdisp)),
            "track_fill_mean": round(float(np.mean(tfills)), 4)
            if tfills else 0.0,
        }
    return out


def exporter_overhead(bank, params, clips, reps: int = 3,
                      smoke: bool = False, n_streams: int = 4) -> dict:
    """The telemetry serving plane's cost on the hot path: an
    ``n_streams`` broker fleet (per-frame regime) with a live scrape
    loop hammering ``/metrics`` + ``/healthz`` vs the same fleet
    unscraped.

    Runs are PAIRED (off/on back to back, order alternating over an
    EVEN number of reps) and per-stream tracks must be bit-identical
    scraped vs unscraped on every rep — the no-perturbation contract
    on the wire.  The fps row is informational: on a shared host both
    wall and whole-process CPU of an identical fleet jitter by 10-15%
    run to run (broker flush coalescing plus scheduler noise), so a
    sub-percent effect cannot be resolved by differencing two arms.
    ``overhead_pct`` is instead measured directly: the HTTP handler
    threads account their own CPU per request (``ObsServer.stats()``)
    and the overhead is that serving CPU over the scraped arms' total
    process CPU.  Smoke mode asserts it below 1%."""
    import dataclasses
    import threading
    import urllib.request

    from repro.core.executor import (BatchBroker, ExecutorOptions,
                                     run_clip_streamed)
    from repro.obs.serve import ObsServer

    params = dataclasses.replace(params, chunk_size=1)

    def fleet():
        results = [None] * n_streams
        errors = []
        broker = BatchBroker()

        def one(i):
            try:
                opts = ExecutorOptions(prefetch=False,
                                       batch_broker=broker)
                results[i] = run_clip_streamed(
                    bank, params, clips[i % len(clips)], opts)
            except BaseException as exc:
                errors.append(exc)

        threads = [threading.Thread(target=one, args=(i,))
                   for i in range(n_streams)]
        c0 = time.process_time()
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        proc = time.process_time() - c0
        broker.close()
        assert not errors, errors
        frames = sum(r.frames_processed for r in results)
        return frames / wall, proc, results

    server = ObsServer(port=0).start()
    stop = threading.Event()
    scrapes = [0]

    def scraper():
        while not stop.is_set():
            for path in ("/metrics", "/healthz"):
                try:
                    urllib.request.urlopen(server.url + path,
                                           timeout=2).read()
                    scrapes[0] += 1
                except Exception:
                    pass
            stop.wait(0.2)

    fleet()                                 # warm both paths' compiles
    reps = max(4, reps + reps % 2)          # even: alternation balances
    fps_on, fps_off, sec_on, sec_off = [], [], [], []
    identical = True
    try:
        for rep in range(reps):
            arms = []
            for scraped in ([False, True] if rep % 2 == 0
                            else [True, False]):
                if scraped:
                    stop.clear()
                    th = threading.Thread(target=scraper, daemon=True)
                    th.start()
                f, s, res = fleet()
                if scraped:
                    stop.set()
                    th.join()
                    fps_on.append(f)
                    sec_on.append(s)
                else:
                    fps_off.append(f)
                    sec_off.append(s)
                arms.append(res)
            for a, b in zip(arms[0], arms[1]):
                identical &= len(a.tracks) == len(b.tracks) and all(
                    np.array_equal(x, y)
                    for x, y in zip(a.tracks, b.tracks))
    finally:
        stop.set()
        server.stop()
    assert identical, \
        "a live /metrics scrape loop perturbed per-stream tracks"
    stats = server.stats()
    serve_cpu = stats["handler_cpu_seconds"]
    overhead_pct = round(100.0 * serve_cpu / sum(sec_on), 3)
    if smoke:
        assert scrapes[0] > 0, "scrape loop never reached the server"
        assert overhead_pct < 1.0, \
            f"exporter overhead {overhead_pct:.2f}% >= 1% " \
            f"({serve_cpu:.4f}s handler CPU over {stats['requests']} " \
            f"requests vs {sum(sec_on):.2f}s scraped-fleet CPU)"
    return {
        "streams": n_streams,
        "fps_scrape_on": round(float(np.median(fps_on)), 2),
        "fps_scrape_off": round(float(np.median(fps_off)), 2),
        "proc_seconds_scrape_on": round(sum(sec_on), 4),
        "proc_seconds_scrape_off": round(sum(sec_off), 4),
        "serve_cpu_seconds": round(serve_cpu, 4),
        "serve_requests": stats["requests"],
        "overhead_pct": overhead_pct,
        "scrapes": scrapes[0],
        "tracks_identical": bool(identical),
    }


def run(out_path: str | None = DEFAULT_OUT, reps: int = 7,
        smoke: bool = False, trace_out: str | None = None) -> dict:
    from repro import obs
    from repro.core import pipeline as pl
    from repro.core.detector import detect_jit_entries
    from repro.core.engine import DEFAULT_CHUNK, run_clip_chunked
    from repro.core.executor import ExecutorOptions, run_clip_streamed

    obs.REGISTRY.reset()
    obs.TRACER.clear()
    if trace_out:
        obs.enable()

    if smoke:
        bank, params, clips = build_workload(n_clips=2, n_frames=24,
                                             train_steps=60,
                                             proxy_steps=40)
        reps = min(reps, 2)
    else:
        bank, params, clips = build_workload()
    chunk = params.chunk_size or DEFAULT_CHUNK
    stream_opts = ExecutorOptions()           # prefetch on, the default

    def sweep():
        """One paired rep: per clip, run the three engines back to back
        so each triple sees the same machine conditions (this
        container's scheduling is noisy; pairing cancels the drift).
        Wall seconds accompany process seconds: prefetch buys wall
        time, not CPU time."""
        s = {"frame": 0.0, "chunked": 0.0, "streaming": 0.0}
        w = {"chunked": 0.0, "streaming": 0.0}
        frames = 0.0
        same = True
        for clip in clips:
            ra = pl.run_clip_frames(bank, params, clip)
            t0 = time.perf_counter()
            rb = run_clip_chunked(bank, params, clip)
            w["chunked"] += time.perf_counter() - t0
            t0 = time.perf_counter()
            rc = run_clip_streamed(bank, params, clip, stream_opts)
            w["streaming"] += time.perf_counter() - t0
            s["frame"] += ra.seconds
            s["chunked"] += rb.seconds
            s["streaming"] += rc.seconds
            frames += ra.frames_processed
            for r in (rb, rc):
                same &= len(ra.tracks) == len(r.tracks) and all(
                    np.array_equal(x, y)
                    for x, y in zip(ra.tracks, r.tracks))
        fps = {k: frames / v for k, v in s.items()}
        wall = {k: frames / v for k, v in w.items()}
        return fps, wall, same

    # warm: jit compiles + render cache for all paths
    sweep()
    entries_warm = detect_jit_entries()

    fps_all = {"frame": [], "chunked": [], "streaming": []}
    wall_all = {"chunked": [], "streaming": []}
    identical = True
    for _ in range(reps):
        fps, wall, same = sweep()
        for k, v in fps.items():
            fps_all[k].append(v)
        for k, v in wall.items():
            wall_all[k].append(v)
        identical &= same

    med = {k: float(np.median(v)) for k, v in fps_all.items()}
    med_wall = {k: float(np.median(v)) for k, v in wall_all.items()}

    # device-resident TRACK in the chunked regime: one chunk-scan
    # dispatch per chunk (DeviceTracker) vs the host per-frame loop —
    # bit-identity asserted every rep (the `--smoke` gate), with the
    # per-stage utilization counters from the device runs aggregated
    # into the `stage_seconds` block
    dev_opts = ExecutorOptions(device_tracker=True)
    fps_dev_all, device_identical = [], True
    dev_blocks = []
    dispatch_sum = {}
    for _ in range(max(2, reps // 2)):
        s_host = s_dev = frames = 0.0
        for clip in clips:
            ra = run_clip_streamed(bank, params, clip, stream_opts)
            rd = run_clip_streamed(bank, params, clip, dev_opts)
            s_host += ra.seconds
            s_dev += rd.seconds
            frames += ra.frames_processed
            device_identical &= len(ra.tracks) == len(rd.tracks) and \
                all(np.array_equal(x, y)
                    for x, y in zip(ra.tracks, rd.tracks))
            if smoke:
                obs.assert_stage_sane(rd.stage_seconds)
            dev_blocks.append(rd.stage_seconds)
            for k, v in rd.dispatches.items():
                dispatch_sum[k] = dispatch_sum.get(k, 0) + v
        fps_dev_all.append(frames / s_dev)
    assert device_identical, \
        "device tracker diverged from the host tracker"
    merged = obs.merge_stage_blocks(dev_blocks)
    if smoke:
        obs.assert_stage_sane(merged)
    stage_seconds = {
        st: {"wall": round(d["wall"], 4),
             "process": round(d["process"], 4)}
        for st, d in merged.items()}

    scaling = stream_scaling(bank, params, clips,
                             stream_counts=(1, 4) if smoke else (1, 4, 16))
    fills = [s["batch_fill_mean"] for s in scaling.values()
             if s["batch_fill_mean"] > 0]

    exporter = exporter_overhead(bank, params, clips, reps=reps,
                                 smoke=smoke)

    result = {
        "benchmark": "pipeline_engine",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "workload": {
            "profile": "caldot1", "clips": len(clips),
            "frames_per_clip": int(clips[0].n_frames),
            "params": params.describe(), "chunk_size": chunk,
            "reps": reps, "smoke": smoke,
        },
        # scheduling-mode fields: the perf trajectory distinguishes the
        # executor mode and chunk size a number was recorded under
        "executor": "streaming",
        "chunk_size": chunk,
        "fps_per_frame": med["frame"],
        "fps_chunked": med["chunked"],
        "fps_streaming": med["streaming"],
        "fps_per_frame_all": [round(f, 2) for f in fps_all["frame"]],
        "fps_chunked_all": [round(f, 2) for f in fps_all["chunked"]],
        "fps_streaming_all": [round(f, 2) for f in fps_all["streaming"]],
        "wall_fps_chunked": med_wall["chunked"],
        "wall_fps_streaming": med_wall["streaming"],
        "fps_streaming_device_tracker":
            float(np.median(fps_dev_all)),
        "device_tracks_identical": bool(device_identical),
        # per-stage utilization (device-tracker runs, summed over
        # clips and reps): wall vs thread-CPU seconds per stage, plus
        # device dispatch counts per stage family
        "stage_seconds": stage_seconds,
        "dispatches": dispatch_sum,
        "speedup": float(np.median(
            [b / a for a, b in zip(fps_all["frame"],
                                   fps_all["chunked"])])),
        "speedup_streaming": float(np.median(
            [b / a for a, b in zip(fps_all["frame"],
                                   fps_all["streaming"])])),
        "tracks_identical": bool(identical),
        # cross-stream broker scaling: wall fps of N concurrent streams
        # sharing one BatchBroker vs N independent runs, plus the
        # consolidated dispatch count and mean bucket occupancy
        "fps_vs_streams": scaling,
        # telemetry serving plane: broker-fleet fps with a live
        # /metrics + /healthz scrape loop vs unscraped, paired reps —
        # smoke asserts <1% process-fps overhead and bit-identical
        # tracks under scrape
        "exporter": exporter,
        "detector_dispatches": {k: v["detector_dispatches"]
                                for k, v in scaling.items()},
        "batch_fill_mean": round(float(np.mean(fills)), 4) if fills
        else 0.0,
        "detector_jit_entries": detect_jit_entries(),
        "jit_entries_grew_after_warmup":
            detect_jit_entries() != entries_warm,
        # registry snapshot: counters/gauges flat, histograms summarized
        # — the same keys bench_diff.py reads for its tolerance gates
        "obs": obs.REGISTRY.snapshot(),
    }
    if trace_out:
        n_spans = obs.export_jsonl(trace_out)
        result["trace"] = {"path": trace_out, "spans": n_spans}
        obs.disable()
    if out_path:
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2)
            f.write("\n")
    assert identical, \
        "executor diverged from the per-frame path" \
        + (f" (see {out_path})" if out_path else "")
    return result


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help=f"output JSON path (default {DEFAULT_OUT})")
    ap.add_argument("--reps", type=int, default=7)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload, no file written unless --out "
                         "is explicitly set (CI correctness gate)")
    ap.add_argument("--trace-out", default=None,
                    help="enable tracing and write JSON-lines spans "
                         "here (tracing is off otherwise)")
    ap.add_argument("--serve", type=int, default=None, metavar="PORT",
                    help="expose /metrics, /healthz and /snapshot on "
                         "this port while the bench runs (0 = "
                         "ephemeral; the URL is printed)")
    args = ap.parse_args(argv)
    # default=None keeps an explicit `--out <default path>` detectable
    out = args.out if args.out is not None else \
        (None if args.smoke else DEFAULT_OUT)
    server = None
    if args.serve is not None:
        from repro.obs.serve import ObsServer
        server = ObsServer(port=args.serve).start()
        print(f"obs: serving {server.url}/metrics")
    try:
        r = run(out, reps=args.reps, smoke=args.smoke,
                trace_out=args.trace_out)
    finally:
        if server is not None:
            server.stop()
    print(f"per-frame engine : {r['fps_per_frame']:8.1f} frames/sec")
    print(f"chunked engine   : {r['fps_chunked']:8.1f} frames/sec")
    print(f"streaming engine : {r['fps_streaming']:8.1f} frames/sec"
          f"  (wall {r['wall_fps_streaming']:.1f}/s)")
    print(f"device tracker   : "
          f"{r['fps_streaming_device_tracker']:8.1f} frames/sec"
          f"  (identical: {r['device_tracks_identical']})")
    print(f"speedup          : {r['speedup']:8.2f}x chunked, "
          f"{r['speedup_streaming']:.2f}x streaming")
    print(f"tracks identical : {r['tracks_identical']}")
    for st, d in r["stage_seconds"].items():
        print(f"  stage {st:6s}: {d['wall']:7.2f}s wall "
              f"{d['process']:7.2f}s cpu  "
              f"({r['dispatches'].get(st, '-')} dispatches)")
    for n, s in r["fps_vs_streams"].items():
        print(f"{n:>2} streams       : {s['fps_broker']:8.1f} fps broker"
              f" vs {s['fps_independent']:.1f} independent, "
              f"{s['fps_device_track']:.1f} device-track  "
              f"(dispatches {s['detector_dispatches']} vs "
              f"{s['detector_dispatches_independent']}, "
              f"fill {s['batch_fill_mean']:.2f}; track "
              f"{s['track_dispatches']} @ {s['track_fill_mean']:.2f})")
    e = r["exporter"]
    print(f"exporter overhead: {e['overhead_pct']:.3f}% of fleet CPU "
          f"({e['serve_cpu_seconds']:.4f}s handler CPU over "
          f"{e['serve_requests']} requests vs "
          f"{e['proc_seconds_scrape_on']:.2f}s scraped-fleet CPU, "
          f"{e['scrapes']} scrapes; identical: "
          f"{e['tracks_identical']})")
    print(f"detector jit entries: {r['detector_jit_entries']}"
          f" (stable after warmup: "
          f"{not r['jit_entries_grew_after_warmup']})")
    if out:
        print(f"wrote {out}")
    if args.trace_out:
        print(f"wrote {r['trace']['spans']} spans to {args.trace_out}")


if __name__ == "__main__":
    main()
