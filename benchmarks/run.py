"""Benchmark orchestrator: one entry per paper table/figure + the kernel
microbench + the roofline table.

    PYTHONPATH=src python -m benchmarks.run [--full]

Default (CI-friendly) mode reads cached paper artifacts when present and
re-runs only the cheap benches; --full regenerates the 7-dataset paper
evaluation (hours on this 1-core container).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def bench_kernels() -> None:
    print("== kernels (CPU path; TPU analytic estimate) ==")
    from benchmarks import kernels
    kernels.main()


def bench_pipeline() -> None:
    print("\n== pipeline engine (per-frame vs chunked vs streaming) ==")
    from benchmarks import pipeline_bench
    pipeline_bench.main([])


def bench_roofline() -> None:
    print("\n== roofline (from dry-run artifacts) ==")
    from benchmarks import roofline
    rows = roofline.load("artifacts/dryrun")
    if not rows:
        print("no dry-run artifacts; run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all")
        return
    print(roofline.render_table(rows, "16x16"))


def bench_paper(full: bool) -> None:
    print("\n== paper evaluation (Table 1 / Fig 6-8 / Table 2) ==")
    paths = sorted(glob.glob("artifacts/paper/*.json"))
    if not paths and not full:
        print("no cached paper artifacts; run the evaluation driver:\n"
              "  PYTHONPATH=src python -m repro.core.experiment --out "
              "artifacts/paper")
        return
    if full:
        from repro.core import experiment
        sys.argv = ["experiment", "--out", "artifacts/paper"]
        experiment.main()
        paths = sorted(glob.glob("artifacts/paper/*.json"))
    from benchmarks.table1 import render_all
    render_all(paths)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    bench_kernels()
    bench_pipeline()
    bench_roofline()
    bench_paper(args.full)


if __name__ == "__main__":
    main()
