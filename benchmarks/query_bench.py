"""Track-store query benchmark: extract-once-serve-many in numbers.

Measures the quantities the query subsystem promises (``repro.query``):

  * **cold ingest** — fps of materializing the workload's clips into a
    ``TrackStore`` through the streaming executor (paid once per θ);
  * **warm query latency** — median milliseconds per query against the
    warm store, per query shape (limit / count / duration / tracks),
    asserted < 1% of the cold ingest time — PLUS the indexed-vs-scan
    split: the same count query answered from the precomputed
    histograms vs forced through the full row scan
    (``use_index=False``), on a clip set 3× the PR-3 workload;
  * **index pruning** — a selective query whose summaries skip clips
    outright (``skipped_clips``/``scanned_clips`` recorded);
  * **eviction** — a ``StoreBudget`` below the store's footprint is
    installed, LRU eviction brings it under budget (counters
    recorded), and a re-query of evicted clips returns bit-identical
    answers through transparent re-ingest;
  * **throughput** — queries/sec with N concurrent clients hammering
    one ``QueryService``.

Also asserted on every run: re-ingesting a materialized split performs
ZERO detector dispatches, the store-served limit query returns exactly
the frames of the original inline scan, and every indexed answer
equals its full-scan twin.

    PYTHONPATH=src python -m benchmarks.query_bench [--smoke]

Emits ``BENCH_query.json`` (CI uploads it as a workflow artifact).
"""
from __future__ import annotations

import argparse
import json
import tempfile
import threading
import time
from typing import Dict, List

import numpy as np

DEFAULT_OUT = "BENCH_query.json"

REGION = (0.0, 0.5, 1.0, 1.0)           # bottom half (the Table-2 query)
# far corner: provably disjoint from caldot1's highway bands, so the
# index skips every clip without touching a row
SELECTIVE_REGION = (0.0, 0.0, 0.02, 0.02)
MIN_COUNT = 2
WANT = 8


def run(out_path: str | None = DEFAULT_OUT, reps: int = 30,
        clients: int = 4, smoke: bool = False,
        trace_out: str | None = None) -> dict:
    from benchmarks.pipeline_bench import build_workload
    from repro import obs
    from repro.query import QueryService, TrackStore

    obs.REGISTRY.reset()
    obs.TRACER.clear()
    if trace_out:
        obs.enable()

    if smoke:
        bank, params, clips = build_workload(n_clips=2, n_frames=24,
                                             train_steps=60,
                                             proxy_steps=40)
        reps = min(reps, 10)
    else:
        # 3x the PR-3 workload (6 clips x 48 frames): the indexed path
        # must hold its latency as the store grows
        bank, params, clips = build_workload(n_clips=18, n_frames=48)
    det = bank.detectors[params.det_arch]
    fps_clip = clips[0].profile.fps
    spacing = 2 * fps_clip

    root = tempfile.mkdtemp(prefix="query_bench_")
    store = TrackStore(root, bank, params)
    service = QueryService(store)

    try:
        return _measure(det, store, service, clips, reps, clients,
                        smoke, spacing, params, out_path, trace_out)
    finally:
        import shutil
        shutil.rmtree(root, ignore_errors=True)


def _median_ms(service, q, clips, reps, use_index=True) -> float:
    times = []
    for _ in range(reps):
        r = service.query(q, clips, use_index=use_index)
        assert r.stats.ingested_clips == 0
        times.append(r.stats.total_seconds)
    return float(np.median(times) * 1e3)


def _measure(det, store, service, clips, reps, clients, smoke, spacing,
             params, out_path, trace_out=None) -> dict:
    from repro import obs
    from repro.query import Query, StoreBudget, TimeRange
    from repro.query.ref import reference_limit_scan

    # -- cold ingest ----------------------------------------------------------
    report = service.warm(clips)
    assert report.ingested == len(clips)
    cold_s = report.wall_seconds

    # -- re-ingest: zero model work on a warm split ---------------------------
    calls_before = det.dispatches
    report2 = service.warm(clips)
    assert report2.ingested == 0 and det.dispatches == calls_before, \
        "re-ingest of a materialized split touched the detector"
    reingest_calls_warm = det.dispatches - calls_before

    # -- correctness: store-served limit query == inline reference scan ------
    q_limit = Query.limit_frames(region=REGION, min_count=MIN_COUNT,
                                 want=WANT, min_spacing=spacing)
    served = service.query(q_limit, clips)
    reference = reference_limit_scan(
        [store.tracks(c) for c in clips], WANT, MIN_COUNT, REGION,
        spacing)
    identical = served.frames == reference
    assert identical, (served.frames, reference)
    assert served.frames == service.query(
        q_limit, clips, use_index=False).frames

    # -- warm query latency per query shape -----------------------------------
    q_count = Query.count_frames(min_count=MIN_COUNT)   # histogram-served
    queries = {
        "limit": q_limit,
        "count": Query.count_frames(region=REGION, min_count=MIN_COUNT),
        "duration": Query.duration(region=REGION),
        "tracks": Query.count_tracks(
            time_range=TimeRange(0, clips[0].n_frames)),
    }
    latency_ms: Dict[str, float] = {}
    for name, q in queries.items():
        latency_ms[name] = _median_ms(service, q, clips, reps)
    warm_worst_s = max(latency_ms.values()) / 1e3

    # -- indexed vs scan: same count query, histogram vs row scan -------------
    r_idx = service.query(q_count, clips)
    r_scan = service.query(q_count, clips, use_index=False)
    assert r_idx.aggregates == r_scan.aggregates
    # every clip is either skipped by its summary or histogram-served;
    # the row scan is never needed for this predicate
    assert r_idx.indexed_clips == r_idx.scanned_clips
    assert r_idx.indexed_clips + r_idx.skipped_clips == len(clips)
    count_indexed_ms = _median_ms(service, q_count, clips, reps)
    count_scan_ms = _median_ms(service, q_count, clips, reps,
                               use_index=False)

    # -- index pruning: selective region skips whole clips --------------------
    q_sel = Query.count_frames(region=SELECTIVE_REGION)
    r_sel = service.query(q_sel, clips)
    r_sel_scan = service.query(q_sel, clips, use_index=False)
    assert r_sel.aggregates == r_sel_scan.aggregates
    assert r_sel.skipped_clips >= 1, \
        "selective predicate failed to skip any clip via the index"
    selective_ms = _median_ms(service, q_sel, clips, reps)

    # -- eviction: budget below footprint, re-query bit-identically -----------
    q_requery = Query.count_frames(min_count=1)     # needs every clip
    count_before = service.query(q_requery, clips).aggregates
    bytes_before = store.disk_bytes()
    budget_bytes = int(bytes_before * 0.6)
    evicted = store.set_budget(StoreBudget(max_bytes=budget_bytes))
    bytes_after = store.disk_bytes()
    assert evicted >= 1 and bytes_after <= budget_bytes, \
        f"eviction failed: {evicted} evicted, {bytes_after} bytes " \
        f"against a {budget_bytes} budget"
    survivors = [c for c in clips if store.has(c)]
    r_surv = service.query(q_requery, survivors)
    assert r_surv.stats.ingested_clips == 0     # survivors stay warm
    calls0 = det.dispatches
    r_requery = service.query(q_requery, clips)  # transparent re-ingest
    assert r_requery.aggregates == count_before, \
        "re-query after eviction changed the answer"
    reingest_calls = det.dispatches - calls0
    assert r_requery.stats.ingested_clips >= 1
    store.set_budget(None)                      # unbounded again

    # -- concurrent clients ---------------------------------------------------
    per_client = reps
    errs: List[BaseException] = []

    def client(k: int):
        try:
            names = list(queries)
            for i in range(per_client):
                q = queries[names[(k + i) % len(names)]]
                r = service.query(q, clips)
                assert r.stats.ingested_clips == 0
        except BaseException as exc:     # surfaced after join
            errs.append(exc)

    threads = [threading.Thread(target=client, args=(k,))
               for k in range(clients)]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    conc_wall = time.perf_counter() - t0
    if errs:
        raise errs[0]
    qps = clients * per_client / conc_wall

    warm_over_cold = warm_worst_s / cold_s if cold_s > 0 else 0.0
    latency_ms["count_indexed"] = count_indexed_ms
    latency_ms["count_scan"] = count_scan_ms
    latency_ms["selective_skip"] = selective_ms
    result = {
        "benchmark": "track_store_query",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "workload": {
            "profile": "caldot1", "clips": len(clips),
            "frames_per_clip": int(clips[0].n_frames),
            "params": params.describe(), "reps": reps,
            "clients": clients, "smoke": smoke,
        },
        "store_fingerprint": store.fingerprint,
        "cold_ingest_seconds": cold_s,
        "cold_ingest_fps": report.fps,
        "reingest_detector_calls": reingest_calls_warm,
        "warm_query_ms": latency_ms,
        "warm_over_cold_ratio": warm_over_cold,
        "queries_per_second": qps,
        "limit_query_identical_to_inline_scan": bool(identical),
        "index": {
            "count_indexed_ms": count_indexed_ms,
            "count_scan_ms": count_scan_ms,
            "indexed_clips": int(r_idx.indexed_clips),
            "selective_skipped_clips": int(r_sel.skipped_clips),
            "selective_scanned_clips": int(r_sel.scanned_clips),
            "indexed_equals_scan": True,        # asserted above
        },
        "eviction": {
            "budget_bytes": budget_bytes,
            "bytes_before": bytes_before,
            "bytes_after": bytes_after,
            "evicted_clips": evicted,
            "evicted_bytes": int(store.evicted_bytes),
            "requery_reingest_detector_calls": int(reingest_calls),
            "requery_identical": True,          # asserted above
        },
        # the service's own rollup: per-dataset latency breakdown plus
        # the skip/index/scan clip counters folded over every query run
        "latency_report": service.latency_report(),
        "obs": obs.REGISTRY.snapshot(),
    }
    if trace_out:
        n_spans = obs.export_jsonl(trace_out)
        result["trace"] = {"path": trace_out, "spans": n_spans}
        obs.disable()
    if out_path:
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2)
            f.write("\n")
    assert warm_over_cold < 0.01, \
        f"warm query {warm_worst_s * 1e3:.1f}ms is not <1% of cold " \
        f"ingest {cold_s:.2f}s"
    if not smoke:
        # the acceptance bar: the histogram path must not lose to the
        # row scan even on the 3x clip set (timing assert kept out of
        # smoke/CI where jitter dominates sub-ms medians)
        assert count_indexed_ms <= count_scan_ms * 1.10, \
            f"indexed count {count_indexed_ms:.3f}ms slower than " \
            f"scan {count_scan_ms:.3f}ms"
    return result


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help=f"output JSON path (default {DEFAULT_OUT})")
    ap.add_argument("--reps", type=int, default=30)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload (CI correctness gate)")
    ap.add_argument("--trace-out", default=None,
                    help="enable tracing and write JSON-lines spans "
                         "here (tracing is off otherwise)")
    ap.add_argument("--serve", type=int, default=None, metavar="PORT",
                    help="expose /metrics, /healthz and /snapshot on "
                         "this port while the bench runs (0 = "
                         "ephemeral; the URL is printed)")
    args = ap.parse_args(argv)
    out = args.out if args.out is not None else DEFAULT_OUT
    server = None
    if args.serve is not None:
        from repro.obs.serve import ObsServer
        server = ObsServer(port=args.serve).start()
        print(f"obs: serving {server.url}/metrics")
    try:
        r = run(out, reps=args.reps, clients=args.clients,
                smoke=args.smoke, trace_out=args.trace_out)
    finally:
        if server is not None:
            server.stop()
    print(f"cold ingest      : {r['cold_ingest_seconds']:8.2f}s "
          f"({r['cold_ingest_fps']:.1f} fps)")
    for name, ms in r["warm_query_ms"].items():
        print(f"warm {name:14s}: {ms:8.3f} ms")
    print(f"warm/cold ratio  : {r['warm_over_cold_ratio']:8.5f} "
          f"(asserted < 0.01)")
    print(f"throughput       : {r['queries_per_second']:8.1f} q/s "
          f"at {r['workload']['clients']} clients")
    idx = r["index"]
    print(f"index            : count {idx['count_indexed_ms']:.3f}ms "
          f"indexed vs {idx['count_scan_ms']:.3f}ms scan; selective "
          f"query skipped {idx['selective_skipped_clips']}/"
          f"{r['workload']['clips']} clips")
    ev = r["eviction"]
    print(f"eviction         : {ev['evicted_clips']} clips "
          f"({ev['evicted_bytes']} B) to fit {ev['budget_bytes']} B; "
          f"re-query identical: {ev['requery_identical']}")
    print(f"re-ingest det calls: {r['reingest_detector_calls']} "
          f"(asserted 0)")
    print(f"identical to inline scan: "
          f"{r['limit_query_identical_to_inline_scan']}")
    for ds, blk in r["latency_report"].get("datasets", {}).items():
        print(f"dataset {ds:10s}: {blk['queries']} queries, "
              f"scan median {blk['scan_seconds_median'] * 1e3:.3f} ms")
    if out:
        print(f"wrote {out}")
    if args.trace_out:
        print(f"wrote {r['trace']['spans']} spans to {args.trace_out}")


if __name__ == "__main__":
    main()
