"""Track-store query benchmark: extract-once-serve-many in numbers.

Measures the three quantities the query subsystem promises
(``repro.query``):

  * **cold ingest** — fps of materializing the workload's clips into a
    ``TrackStore`` through the streaming executor (paid once per θ);
  * **warm query latency** — median milliseconds per query against the
    warm store, per query shape (limit / count / duration / tracks);
    asserted < 1% of the cold ingest time;
  * **throughput** — queries/sec with N concurrent clients hammering
    one ``QueryService``.

Also asserted on every run: re-ingesting a materialized split performs
ZERO detector dispatches, and the store-served limit query returns
exactly the frames of the original inline scan (the pre-store
``limit_query_experiment`` loop, replicated here as the reference).

    PYTHONPATH=src python -m benchmarks.query_bench [--smoke]

Emits ``BENCH_query.json`` (CI uploads it as a workflow artifact).
"""
from __future__ import annotations

import argparse
import json
import tempfile
import threading
import time
from typing import Dict, List

import numpy as np

DEFAULT_OUT = "BENCH_query.json"

REGION = (0.0, 0.5, 1.0, 1.0)           # bottom half (the Table-2 query)
MIN_COUNT = 2
WANT = 8


def run(out_path: str | None = DEFAULT_OUT, reps: int = 30,
        clients: int = 4, smoke: bool = False) -> dict:
    from benchmarks.pipeline_bench import build_workload
    from repro.query import QueryService, TrackStore

    if smoke:
        bank, params, clips = build_workload(n_clips=2, n_frames=24,
                                             train_steps=60,
                                             proxy_steps=40)
        reps = min(reps, 10)
    else:
        bank, params, clips = build_workload(n_clips=6, n_frames=48)
    det = bank.detectors[params.det_arch]
    fps_clip = clips[0].profile.fps
    spacing = 2 * fps_clip

    root = tempfile.mkdtemp(prefix="query_bench_")
    store = TrackStore(root, bank, params)
    service = QueryService(store)

    try:
        return _measure(det, store, service, clips, reps, clients,
                        smoke, spacing, params, out_path)
    finally:
        import shutil
        shutil.rmtree(root, ignore_errors=True)


def _measure(det, store, service, clips, reps, clients, smoke, spacing,
             params, out_path) -> dict:
    from repro.query import Query, TimeRange
    from repro.query.ref import reference_limit_scan

    # -- cold ingest ----------------------------------------------------------
    report = service.warm(clips)
    assert report.ingested == len(clips)
    cold_s = report.wall_seconds

    # -- re-ingest: zero model work on a warm split ---------------------------
    calls_before = det.dispatches
    report2 = service.warm(clips)
    assert report2.ingested == 0 and det.dispatches == calls_before, \
        "re-ingest of a materialized split touched the detector"

    # -- correctness: store-served limit query == inline reference scan ------
    q_limit = Query.limit_frames(region=REGION, min_count=MIN_COUNT,
                                 want=WANT, min_spacing=spacing)
    served = service.query(q_limit, clips)
    reference = reference_limit_scan(
        [store.tracks(c) for c in clips], WANT, MIN_COUNT, REGION,
        spacing)
    identical = served.frames == reference
    assert identical, (served.frames, reference)

    # -- warm query latency per query shape -----------------------------------
    queries = {
        "limit": q_limit,
        "count": Query.count_frames(region=REGION, min_count=MIN_COUNT),
        "duration": Query.duration(region=REGION),
        "tracks": Query.count_tracks(
            time_range=TimeRange(0, clips[0].n_frames)),
    }
    latency_ms: Dict[str, float] = {}
    for name, q in queries.items():
        times = []
        for _ in range(reps):
            r = service.query(q, clips)
            assert r.stats.ingested_clips == 0
            times.append(r.stats.total_seconds)
        latency_ms[name] = float(np.median(times) * 1e3)
    warm_worst_s = max(latency_ms.values()) / 1e3

    # -- concurrent clients ---------------------------------------------------
    per_client = reps
    errs: List[BaseException] = []

    def client(k: int):
        try:
            names = list(queries)
            for i in range(per_client):
                q = queries[names[(k + i) % len(names)]]
                r = service.query(q, clips)
                assert r.stats.ingested_clips == 0
        except BaseException as exc:     # surfaced after join
            errs.append(exc)

    threads = [threading.Thread(target=client, args=(k,))
               for k in range(clients)]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    conc_wall = time.perf_counter() - t0
    if errs:
        raise errs[0]
    qps = clients * per_client / conc_wall

    warm_over_cold = warm_worst_s / cold_s if cold_s > 0 else 0.0
    result = {
        "benchmark": "track_store_query",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "workload": {
            "profile": "caldot1", "clips": len(clips),
            "frames_per_clip": int(clips[0].n_frames),
            "params": params.describe(), "reps": reps,
            "clients": clients, "smoke": smoke,
        },
        "store_fingerprint": store.fingerprint,
        "cold_ingest_seconds": cold_s,
        "cold_ingest_fps": report.fps,
        "reingest_detector_calls": det.dispatches - calls_before,
        "warm_query_ms": latency_ms,
        "warm_over_cold_ratio": warm_over_cold,
        "queries_per_second": qps,
        "limit_query_identical_to_inline_scan": bool(identical),
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2)
            f.write("\n")
    assert warm_over_cold < 0.01, \
        f"warm query {warm_worst_s * 1e3:.1f}ms is not <1% of cold " \
        f"ingest {cold_s:.2f}s"
    return result


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help=f"output JSON path (default {DEFAULT_OUT})")
    ap.add_argument("--reps", type=int, default=30)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload (CI correctness gate)")
    args = ap.parse_args(argv)
    out = args.out if args.out is not None else DEFAULT_OUT
    r = run(out, reps=args.reps, clients=args.clients, smoke=args.smoke)
    print(f"cold ingest      : {r['cold_ingest_seconds']:8.2f}s "
          f"({r['cold_ingest_fps']:.1f} fps)")
    for name, ms in r["warm_query_ms"].items():
        print(f"warm {name:8s}    : {ms:8.3f} ms")
    print(f"warm/cold ratio  : {r['warm_over_cold_ratio']:8.5f} "
          f"(asserted < 0.01)")
    print(f"throughput       : {r['queries_per_second']:8.1f} q/s "
          f"at {r['workload']['clients']} clients")
    print(f"re-ingest det calls: {r['reingest_detector_calls']} "
          f"(asserted 0)")
    print(f"identical to inline scan: "
          f"{r['limit_query_identical_to_inline_scan']}")
    if out:
        print(f"wrote {out}")


if __name__ == "__main__":
    main()
