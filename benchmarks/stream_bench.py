"""Live-ingestion benchmark: segment appends + standing queries in
numbers.

Measures the quantities the stream subsystem promises (``repro.stream``):

  * **append latency** — wall time per appended segment, split into
    executor (decode/proxy/detect/track over the segment), index merge
    + store landing, and standing-query delta evaluation;
  * **watermark lag** — how long after a segment's last frame arrives
    until queries can see it (store landing + standing notification);
  * **standing-query delta latency** — per registered query, the
    incremental re-evaluation cost per watermark advance, vs
    **re-running the ad-hoc query from scratch** (the full row scan,
    ``use_index=False``) over the same open clips;
  * **fleet watermark lag, broker off/on/track** — K feeds appending
    concurrently (one ingestor + thread each, per-frame segments) with
    a shared ``executor.BatchBroker`` vs independent executors, plus a
    third mode adding the device-resident TRACK path (fused
    ``track_step`` assignment, steps coalesced by a shared
    ``TrackBroker``): lag, append wall, fleet fps, consolidated
    detector/track dispatches and per-stage ``stage_seconds``, with
    per-feed stored rows asserted bit-identical across all modes;
  * **exactness counters** — the unrestricted standing query must scan
    each visible row EXACTLY once across the whole stream
    (``rows_scanned == total rows``), and its accumulated state must
    equal the ad-hoc answer and the naive ``ref.reference_query``
    oracle at the final watermark.

The non-smoke run keeps 24 clips open simultaneously and asserts the
standing delta evaluation serves >= 10x faster than the cold ad-hoc
re-run (the acceptance bar); ``--smoke`` is the CI correctness gate —
tiny workload, every equality asserted (including sealed-vs-batch
bit-identity), timing asserts skipped where jitter dominates.

    PYTHONPATH=src python -m benchmarks.stream_bench [--smoke]

Emits ``BENCH_stream.json`` (CI uploads it as a workflow artifact).
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
from typing import Dict, List

import numpy as np

DEFAULT_OUT = "BENCH_stream.json"

REGION_TOP = (0.0, 0.0, 1.0, 0.5)


def run(out_path: str | None = DEFAULT_OUT, smoke: bool = False,
        trace_out: str | None = None) -> dict:
    from benchmarks.pipeline_bench import build_workload
    from repro import obs
    from repro.query import Query, QueryService, TrackStore
    from repro.query.ref import reference_query
    from repro.stream import SegmentIngestor, StandingQuery

    obs.REGISTRY.reset()
    obs.TRACER.clear()
    if trace_out:
        obs.enable()

    if smoke:
        bank, params, clips = build_workload(n_clips=3, n_frames=24,
                                             train_steps=60,
                                             proxy_steps=40)
        segment = 8
    else:
        # 24 always-on cameras, 48-frame days, 12-frame segments — the
        # delta-vs-rescan gap must hold with 4+ clips open at once
        # (delta cost is per appended clip; the rescan pays O(clips))
        bank, params, clips = build_workload(n_clips=24, n_frames=48)
        segment = 12
    n_frames = clips[0].n_frames
    root = tempfile.mkdtemp(prefix="stream_bench_")
    try:
        return _measure(bank, params, clips, segment, n_frames, root,
                        smoke, out_path, trace_out,
                        Query, QueryService, TrackStore,
                        reference_query, SegmentIngestor, StandingQuery)
    finally:
        import shutil
        shutil.rmtree(root, ignore_errors=True)


def _fleet_lag(bank, params, clips, segment, root, smoke,
               TrackStore, SegmentIngestor) -> dict:
    """Watermark lag with K camera feeds appending CONCURRENTLY (one
    ingestor + thread per feed, per-frame ``chunk_size=1``), with a
    shared ``BatchBroker`` vs fully independent executors.

    The broker consolidates every feed's per-segment detector windows
    into shared dispatches; per-feed stored rows must stay bit-identical
    (asserted), only the batching and the lag/throughput change.  Lag
    here is the bench's usual store-landing + standing-notify slice of
    each append; append wall and fleet fps are recorded alongside so
    the linger the broker spends waiting for peers is visible too.
    The "track" mode keeps the detector broker and moves TRACK onto
    the device as well (``device_assign`` + shared ``TrackBroker``) —
    the fleet-phase row for the fused track_step path."""
    import dataclasses
    import os
    import threading

    from repro import obs
    from repro.core.executor import (BatchBroker, ExecutorOptions,
                                     TrackBroker)

    p1 = dataclasses.replace(params, chunk_size=1)
    feeds = clips[:3] if smoke else clips[:8]
    detector = bank.detectors[params.det_arch]
    out = {"feeds": len(feeds), "segment_frames": segment}
    rows_by_mode = {}
    # "track" = detector broker PLUS the device-resident TRACK path:
    # per-step assignment through the fused track_step kernel, steps
    # coalesced across feeds by a shared TrackBroker.  "warm" is an
    # unrecorded track-mode fleet run first so the fused kernel's jit
    # compiles (one per padded batch/slot shape) don't land in the
    # measured appends.
    for mode in ("warm", "off", "on", "track"):
        broker = BatchBroker() if mode != "off" else None
        track_broker = TrackBroker() if mode in ("warm", "track") \
            else None
        detector.dispatches = 0
        stores, ingestors = [], []
        for i, c in enumerate(feeds):
            s = TrackStore(os.path.join(root, f"fleet_{mode}_{i}"),
                           bank, p1)
            ing = SegmentIngestor(
                s, options=ExecutorOptions(
                    prefetch=False, batch_broker=broker,
                    device_assign=mode in ("warm", "track"),
                    track_broker=track_broker))
            ing.open(c)
            stores.append(s)
            ingestors.append(ing)
        reports = [[] for _ in feeds]
        errors: List[BaseException] = []

        def run_feed(i):
            try:
                c = feeds[i]
                n_seg = (c.n_frames + segment - 1) // segment
                for _ in range(n_seg):
                    reports[i].append(ingestors[i].append(c, segment))
            except BaseException as exc:
                errors.append(exc)

        threads = [threading.Thread(target=run_feed, args=(i,))
                   for i in range(len(feeds))]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        if broker is not None:
            broker.close()
        if track_broker is not None:
            track_broker.close()
        assert not errors, errors
        if mode == "warm":
            continue
        flat = [r for rs in reports for r in rs]
        assert all(rs[-1].sealed for rs in reports)
        lag = [r.store_seconds + r.standing_seconds for r in flat]
        out[f"watermark_lag_ms_broker_{mode}"] = {
            "median": float(np.median(lag) * 1e3),
            "p95": float(np.percentile(lag, 95) * 1e3),
        }
        out[f"append_wall_ms_broker_{mode}"] = float(
            np.median([r.wall_seconds for r in flat]) * 1e3)
        out[f"fleet_fps_broker_{mode}"] = round(
            sum(r.frames_processed for r in flat) / wall, 2)
        out[f"detector_dispatches_broker_{mode}"] = int(
            broker.dispatches if broker is not None
            else detector.dispatches)
        # per-stage utilization summed over every append in the fleet
        stage = obs.merge_stage_blocks(r.stage_seconds for r in flat)
        if smoke:
            obs.assert_stage_sane(stage)
        out[f"stage_seconds_broker_{mode}"] = {
            st: {k: round(v, 4) for k, v in d.items()}
            for st, d in stage.items()}
        if track_broker is not None:
            out["track_dispatches"] = track_broker.dispatches
            out["track_steps_in"] = track_broker.steps_in
            out["track_fill_mean"] = round(
                float(np.mean(track_broker.stream_fill)), 4) \
                if track_broker.stream_fill else 0.0
        rows_by_mode[mode] = [stores[i].get(c).rows
                              for i, c in enumerate(feeds)]
    for mode in ("on", "track"):
        for a, b in zip(rows_by_mode["off"], rows_by_mode[mode]):
            np.testing.assert_array_equal(a, b)
    out["tracks_bit_identical"] = True
    assert out["detector_dispatches_broker_on"] \
        < out["detector_dispatches_broker_off"]
    return out


def _measure(bank, params, clips, segment, n_frames, root, smoke,
             out_path, trace_out, Query, QueryService, TrackStore,
             reference_query, SegmentIngestor, StandingQuery) -> dict:
    import os

    from repro import obs

    store = TrackStore(os.path.join(root, "live"), bank, params)
    service = QueryService(store)
    ingestor = SegmentIngestor(store, service=service)
    q_count = Query.count_frames(min_count=1)
    q_region = Query.count_frames(region=REGION_TOP, min_count=1)
    n_sqs = 2

    for c in clips:
        ingestor.open(c)

    append_wall: List[float] = []
    append_exec: List[float] = []
    append_store: List[float] = []
    append_standing: List[float] = []
    adhoc_total_s: List[float] = []
    adhoc_scan_s: List[float] = []
    reports = []
    n_segments = (n_frames + segment - 1) // segment
    # Phase A (first half of the stream): both standing queries
    # registered — their per-watermark delta evaluation is timed in
    # the post-append slot.  Phase B (second half): the timed query is
    # UNREGISTERED and keeping its answer fresh reverts to the
    # baseline world — re-running the ad-hoc query after every
    # watermark advance, timed in the same post-append slot.  Delta
    # cost is independent of accumulated history (it folds one
    # segment's new rows), so giving the rescan the LARGER second-half
    # store is the conservative comparison; the region query stays
    # registered to the end for the full-stream exactness asserts.
    sq_count = service.register_standing(StandingQuery(q_count, clips))
    sq_region = service.register_standing(
        StandingQuery(q_region, clips))
    timed_standing = True
    for si in range(n_segments):
        if si == (n_segments + 1) // 2 and timed_standing:
            timed_standing = False
            mid_rows = sum(len(store.get(c).rows) for c in clips)
            assert sq_count.rows_scanned == mid_rows, \
                f"standing query scanned {sq_count.rows_scanned} " \
                f"rows, stream delivered {mid_rows}: a row was " \
                f"rescanned"
            mid_scanned = sq_count.rows_scanned
            service.unregister_standing(sq_count)
        for c in clips:
            rep = ingestor.append(c, segment)
            reports.append(rep)
            append_wall.append(rep.wall_seconds)
            append_exec.append(rep.wall_seconds - rep.store_seconds
                               - rep.standing_seconds)
            append_store.append(rep.store_seconds)
            if timed_standing:
                append_standing.append(rep.standing_seconds)
            else:
                r = service.query(q_count, clips, use_index=False)
                adhoc_total_s.append(r.stats.total_seconds)
                adhoc_scan_s.append(r.stats.scan_seconds)
        # per-watermark exactness: accumulated state == ad-hoc
        live_sqs = ((sq_count, q_count), (sq_region, q_region)) \
            if timed_standing else ((sq_region, q_region),)
        for sq, q in live_sqs:
            acc = sq.result()
            adhoc = service.query(q, clips)
            assert acc.aggregates == adhoc.aggregates, \
                (si, acc.aggregates, adhoc.aggregates)
    assert all(r.sealed for r in reports[-len(clips):])

    # per-stage executor seconds summed over every append of the
    # single-stream phase (the fleet phase reports its own blocks)
    stage_totals = obs.merge_stage_blocks(
        r.stage_seconds for r in reports)
    if smoke:
        obs.assert_stage_sane(stage_totals)

    # -- exactness counters ---------------------------------------------------
    total_rows = sum(len(store.get(c).rows) for c in clips)
    # every delivered row is exactly one of scanned / summary-skipped
    # (a summary-disjoint delta is dropped whole, rows uncounted)
    assert sq_region.rows_scanned + sq_region.rows_skipped \
        == total_rows, \
        f"standing query scanned {sq_region.rows_scanned} + skipped " \
        f"{sq_region.rows_skipped} rows, stream delivered " \
        f"{total_rows}: a row was rescanned or lost"
    ref = reference_query(
        [store.tracks(c) for c in clips],
        [c.profile.fps for c in clips],
        region=REGION_TOP,
        min_len=2, min_count=1, aggregate="count")
    assert sq_region.result().aggregates == ref["aggregates"]

    if smoke:
        # sealed stream == one-shot batch ingest, bit for bit
        batch = TrackStore(os.path.join(root, "batch"), bank, params)
        batch.ingest(clips)
        for c in clips:
            a, b = batch.get(c), store.get(c)
            np.testing.assert_array_equal(a.rows, b.rows)
            np.testing.assert_array_equal(a.hist, b.hist)
            assert a.summary == b.summary and a.counters == b.counters

    fleet = _fleet_lag(bank, params, clips, segment, root, smoke,
                       TrackStore, SegmentIngestor)

    delta_ms = float(np.median(append_standing) / n_sqs * 1e3)
    adhoc_ms = float(np.median(adhoc_total_s) * 1e3)
    adhoc_scan_ms = float(np.median(adhoc_scan_s) * 1e3)
    speedup = adhoc_ms / delta_ms if delta_ms > 0 else float("inf")
    lag = [r.store_seconds + r.standing_seconds for r in reports]
    frames_appended = sum(r.frames_processed for r in reports)
    result = {
        "benchmark": "stream_ingest",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "workload": {
            "profile": "caldot1", "clips": len(clips),
            "frames_per_clip": n_frames, "segment_frames": segment,
            "segments": len(reports),
            "params": params.describe(), "smoke": smoke,
        },
        "append_ms": {
            "median": float(np.median(append_wall) * 1e3),
            "p95": float(np.percentile(append_wall, 95) * 1e3),
            "executor_median": float(np.median(append_exec) * 1e3),
            "store_median": float(np.median(append_store) * 1e3),
            "standing_median": float(np.median(append_standing) * 1e3),
        },
        "append_fps": frames_appended / max(sum(append_wall), 1e-9),
        "watermark_lag_ms": {
            "median": float(np.median(lag) * 1e3),
            "p95": float(np.percentile(lag, 95) * 1e3),
        },
        "standing_delta_ms": delta_ms,
        "adhoc_query_ms": adhoc_ms,
        "adhoc_scan_ms": adhoc_scan_ms,
        "delta_speedup_over_adhoc": speedup,
        "rows_total": int(total_rows),
        "standing_rows_scanned": int(sq_region.rows_scanned),
        "standing_rows_skipped": int(sq_region.rows_skipped),
        "midpoint_rows_scanned_once": int(mid_scanned),
        "rows_scanned_exactly_once": True,      # asserted above
        "standing_matches_adhoc_and_reference": True,
        "open_clips_during_adhoc_measure": len(clips),
        "stage_seconds": {
            st: {k: round(v, 4) for k, v in d.items()}
            for st, d in stage_totals.items()},
        "fleet": fleet,
        "obs": obs.REGISTRY.snapshot(),
    }
    if trace_out:
        n_spans = obs.export_jsonl(trace_out)
        result["trace"] = {"path": trace_out, "spans": n_spans}
        obs.disable()
    if out_path:
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2)
            f.write("\n")
    if not smoke:
        # the acceptance bar (timing asserts stay out of smoke/CI where
        # sub-ms medians are jitter-dominated)
        assert speedup >= 10.0, \
            f"standing delta {delta_ms:.4f}ms only {speedup:.1f}x " \
            f"faster than ad-hoc scan {adhoc_ms:.4f}ms (need 10x)"
    return result


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help=f"output JSON path (default {DEFAULT_OUT})")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload (CI correctness gate)")
    ap.add_argument("--trace-out", default=None,
                    help="enable tracing and write JSON-lines spans "
                         "here (tracing is off otherwise)")
    ap.add_argument("--serve", type=int, default=None, metavar="PORT",
                    help="expose /metrics, /healthz and /snapshot on "
                         "this port while the bench runs (0 = "
                         "ephemeral; the URL is printed)")
    args = ap.parse_args(argv)
    out = args.out if args.out is not None else DEFAULT_OUT
    server = None
    if args.serve is not None:
        from repro.obs.serve import ObsServer
        server = ObsServer(port=args.serve).start()
        print(f"obs: serving {server.url}/metrics")
    try:
        r = run(out, smoke=args.smoke, trace_out=args.trace_out)
    finally:
        if server is not None:
            server.stop()
    a = r["append_ms"]
    print(f"append latency   : {a['median']:8.2f} ms median "
          f"(p95 {a['p95']:.2f}; executor {a['executor_median']:.2f} "
          f"+ store {a['store_median']:.2f} "
          f"+ standing {a['standing_median']:.2f})")
    print(f"append throughput: {r['append_fps']:8.1f} frames/s wall")
    w = r["watermark_lag_ms"]
    print(f"watermark lag    : {w['median']:8.2f} ms median "
          f"(p95 {w['p95']:.2f})")
    print(f"standing delta   : {r['standing_delta_ms']:8.4f} ms vs "
          f"ad-hoc re-run {r['adhoc_query_ms']:.4f} ms "
          f"(scan {r['adhoc_scan_ms']:.4f}) -> "
          f"{r['delta_speedup_over_adhoc']:.1f}x "
          f"at {r['open_clips_during_adhoc_measure']} open clips")
    print(f"rows scanned once: {r['standing_rows_scanned']} scanned "
          f"+ {r['standing_rows_skipped']} summary-skipped == "
          f"{r['rows_total']} (asserted)")
    fl = r["fleet"]
    for mode in ("off", "on", "track"):
        w = fl[f"watermark_lag_ms_broker_{mode}"]
        print(f"fleet broker {mode:>5}: "
              f"{fl[f'fleet_fps_broker_{mode}']:8.1f} fps, lag "
              f"{w['median']:.2f} ms median (p95 {w['p95']:.2f}), "
              f"{fl[f'detector_dispatches_broker_{mode}']} dispatches "
              f"at {fl['feeds']} feeds")
    print(f"fleet track path : {fl['track_dispatches']} coalesced "
          f"track dispatches for {fl['track_steps_in']} steps "
          f"(fill {fl['track_fill_mean']:.2f})")
    if out:
        print(f"wrote {out}")
    if args.trace_out:
        print(f"wrote {r['trace']['spans']} spans to {args.trace_out}")


if __name__ == "__main__":
    main()
