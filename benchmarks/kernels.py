"""Kernel micro-benchmarks: µs/call of each op's CPU execution path plus
the analytic TPU-target roofline estimate per kernel.

On this CPU container the Pallas kernels execute in interpret mode (not
representative of TPU speed), so the measured numbers benchmark the jnp
dispatch path that the dry-run lowers; the analytic columns give the
TPU v5e expectation (bytes / 819 GB/s vs FLOPs / 197 TFLOP/s).

    PYTHONPATH=src python -m benchmarks.kernels [--smoke]

``--smoke`` is the CI correctness gate: it auto-discovers every kernel
package under ``src/repro/kernels/`` (any directory with a
``kernel.py``) and runs its ``smoke.py:smoke()`` — interpret-mode
Pallas vs the jnp reference, the same contract the kernel tests
enforce, runnable without pytest.  The ``kernel-contract`` pass of
``python -m repro.analysis`` verifies every package ships that entry.
"""
from __future__ import annotations

import argparse
import importlib
import time
from pathlib import Path
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

PEAK = 197e12
BW = 819e9


def _time(fn, *args, iters: int = 5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6    # µs


def run() -> List[Dict]:
    rows = []
    key = jax.random.PRNGKey(0)

    from repro.kernels.flash_attention import flash_attention
    B, S, Hq, Hkv, D = 1, 1024, 8, 2, 64
    q = jax.random.normal(key, (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(key, (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(key, (B, S, Hkv, D), jnp.float32)
    us = _time(lambda: flash_attention(q, k, v))
    flops = 4 * B * S * S * Hq * D
    rows.append({"name": f"flash_attention B{B} S{S} H{Hq}/{Hkv} D{D}",
                 "us_per_call": us,
                 "tpu_est_us": flops / PEAK * 1e6})

    from repro.kernels.decode_attention import decode_attention
    B, S, Hq, Hkv, D = 8, 8192, 8, 2, 64
    q = jax.random.normal(key, (B, Hq, D), jnp.float32)
    k = jax.random.normal(key, (B, S, Hkv, D), jnp.bfloat16)
    v = jax.random.normal(key, (B, S, Hkv, D), jnp.bfloat16)
    kvlen = jnp.full((B,), S, jnp.int32)
    us = _time(lambda: decode_attention(q, k, v, kvlen))
    bytes_ = B * S * Hkv * D * 2 * 2
    rows.append({"name": f"decode_attention B{B} S{S}",
                 "us_per_call": us, "tpu_est_us": bytes_ / BW * 1e6})

    from repro.kernels.ssd_scan import ssd_scan
    b, S, H, P, N = 1, 2048, 8, 64, 64
    x = jax.random.normal(key, (b, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(key, (b, S, H)))
    A = -jnp.exp(jax.random.normal(key, (H,)) * 0.3)
    Bm = jax.random.normal(key, (b, S, N)) * 0.5
    C = jax.random.normal(key, (b, S, N)) * 0.5
    Dv = jax.random.normal(key, (H,)) * 0.1
    us = _time(lambda: ssd_scan(x, dt, A, Bm, C, Dv))
    Q = 128
    flops = b * H * (S // Q) * (2 * Q * Q * N + 2 * Q * Q * P
                                + 2 * Q * N * P * 2)
    rows.append({"name": f"ssd_scan S{S} H{H} P{P} N{N}",
                 "us_per_call": us, "tpu_est_us": flops / PEAK * 1e6})

    from repro.kernels.proxy_score import proxy_score
    feat = jax.random.normal(key, (1, 24, 32, 64), jnp.float32)
    w = jax.random.normal(key, (64,))
    us = _time(lambda: proxy_score(feat, w, 0.0, 0.5))
    rows.append({"name": "proxy_score 24x32x64",
                 "us_per_call": us,
                 "tpu_est_us": feat.size * 4 / BW * 1e6})

    from repro.kernels.window_gather import window_gather
    frame = jax.random.normal(key, (512, 768, 3), jnp.float32)
    oc = jnp.array([[0, 0], [2, 4], [4, 8], [6, 2]], jnp.int32)
    us = _time(lambda: window_gather(frame, oc, win_h=128, win_w=128))
    rows.append({"name": "window_gather 4x128x128",
                 "us_per_call": us,
                 "tpu_est_us": 4 * 128 * 128 * 3 * 4 * 2 / BW * 1e6})

    from repro.kernels.proxy_plan import proxy_plan
    B, hp, wp, C, hc, wc = 16, 24, 32, 64, 5, 8
    feat = jax.random.normal(key, (B, hp, wp, C), jnp.float32)
    w = jax.random.normal(key, (C,))
    us = _time(lambda: proxy_plan(feat, w, 0.0, 0.5, grid_hw=(hc, wc)))
    rows.append({"name": f"proxy_plan B{B} {hp}x{wp}x{C}->{hc}x{wc}",
                 "us_per_call": us,
                 "tpu_est_us": feat.size * 4 / BW * 1e6})

    from repro.kernels.assign import assign_batch
    K, N = 16, 32
    costs = jax.random.uniform(key, (K, N, N), jnp.float32)
    us = _time(lambda: assign_batch(costs))
    # JV augmenting paths: ~N scans of the NxN slack matrix per row
    rows.append({"name": f"assign_batch K{K} N{N}",
                 "us_per_call": us,
                 "tpu_est_us": K * N * N * N * 4 / BW * 1e6})

    from repro.kernels.track_step import (pack_params, track_step)
    from repro.kernels.track_step.ops import LOG1P_TABLE_2D
    from repro.kernels.track_step.smoke import track_operands
    K, Q, H, e, M = 8, 32, 32, 16, 32
    arrs, thr, np_params = track_operands(
        np.random.default_rng(0), K, Q, H, e, M)
    packed = pack_params(np_params)
    jarrs = [jnp.asarray(a) for a in arrs]
    jthr = jnp.asarray(thr)
    us = _time(lambda: track_step(*jarrs, jthr, packed, LOG1P_TABLE_2D))
    # matmuls (GRU + match head) on the MXU, JV slack scans on the VPU
    flops = K * (6 * Q * (e + H) * H
                 + 2 * Q * Q * ((H + e + 6) * M + M))
    rows.append({"name": f"track_step K{K} Q{Q} H{H} e{e}",
                 "us_per_call": us,
                 "tpu_est_us": (flops / PEAK
                                + K * Q * Q * Q * 4 / BW) * 1e6})

    from repro.obs.metrics import REGISTRY
    for r in rows:
        slug = r["name"].split()[0]
        REGISTRY.histogram(
            f"kernels.{slug}.us_per_call").observe(r["us_per_call"])
    return rows


def discover_kernel_packages() -> List[str]:
    """Kernel package names: directories under ``src/repro/kernels/``
    that contain a ``kernel.py``."""
    import repro.kernels
    root = Path(repro.kernels.__file__).parent
    return sorted(p.name for p in root.iterdir()
                  if p.is_dir() and (p / "kernel.py").is_file())


def smoke() -> None:
    """CI gate: run every kernel package's smoke.py — interpret-mode
    Pallas output vs the jnp reference."""
    names = discover_kernel_packages()
    assert names, "no kernel packages discovered"
    for name in names:
        mod = importlib.import_module(f"repro.kernels.{name}.smoke")
        mod.smoke()
        print(f"kernels smoke OK: {name}")
    print(f"kernels smoke OK: {len(names)} packages")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="correctness gate only (no timing sweep)")
    ap.add_argument("--serve", type=int, default=None, metavar="PORT",
                    help="expose /metrics, /healthz and /snapshot on "
                         "this port while the bench runs (0 = "
                         "ephemeral; the URL is printed)")
    args = ap.parse_args(argv)
    server = None
    if args.serve is not None:
        from repro.obs.serve import ObsServer
        server = ObsServer(port=args.serve).start()
        print(f"obs: serving {server.url}/metrics")
    try:
        if args.smoke:
            smoke()
            return
        print("name,us_per_call,tpu_est_us")
        for r in run():
            print(f"{r['name']},{r['us_per_call']:.1f},"
                  f"{r['tpu_est_us']:.2f}")
    finally:
        if server is not None:
            server.stop()


if __name__ == "__main__":
    main()
