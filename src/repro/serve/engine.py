"""Batched LM serving engine: ragged-prompt prefill + token-by-token
decode for the seed's transformer stack (``repro.models``).

NOTE: this module is NOT the video-analytics serving path.  MultiScope
queries are served by ``repro.query`` — a persistent ``TrackStore``
materializes extracted tracks once and a ``QueryService`` answers
exploratory queries from the packed arrays in milliseconds; see
src/repro/query/__init__.py.  This engine serves the auxiliary language
models only.

Prompts are right-padded to a common length; per-row true lengths drive
(a) the gather of each row's last-real-token logits after prefill and
(b) the kv_len masking during decode, so padding never leaks into
attention.  Decode is one jit'd step reused across tokens with the cache
donated (in-place buffer reuse).

Sampling: greedy (temperature=0) or softmax sampling with a counter-based
key per (row, step) so generation is deterministic given the seed.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model

PyTree = Any


@dataclass
class ServeEngine:
    model: Model
    params: PyTree
    max_len: int
    temperature: float = 0.0
    seed: int = 0
    _decode_jit: Any = field(default=None, repr=False)

    def __post_init__(self):
        def decode(params, token, pos, cache, key):
            logits, cache = self.model.decode_step(params, token, pos,
                                                   cache)
            if self.temperature > 0:
                nxt = jax.random.categorical(
                    key, logits / self.temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            return nxt.astype(jnp.int32)[:, None], cache
        self._decode_jit = jax.jit(decode, donate_argnums=(3,))

    def generate(self, prompts: List[List[int]], max_new_tokens: int,
                 extras: Optional[Dict[str, Any]] = None
                 ) -> List[List[int]]:
        B = len(prompts)
        lens = np.array([len(p) for p in prompts], np.int32)
        L = int(lens.max())
        toks = np.zeros((B, L), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        batch = {"tokens": jnp.asarray(toks), **(extras or {})}
        logits, aux, cache = self.model.forward(batch=batch,
                                                params=self.params,
                                                return_cache=True)
        from repro.models import transformer as tf_mod
        if self.model.cfg.family == "encdec":
            k, v = cache["self"]
            pad = self.max_len - k.shape[2]
            if pad > 0:
                w = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
                cache = dict(cache)
                cache["self"] = (jnp.pad(k, w), jnp.pad(v, w))
        else:
            cache = tf_mod.pad_cache(self.model.cfg, cache, self.max_len)
        # first sampled token comes from each row's LAST REAL position
        last = jnp.asarray(lens - 1)
        row_logits = jnp.take_along_axis(
            logits, last[:, None, None], axis=1)[:, 0]
        if self.temperature > 0:
            key = jax.random.PRNGKey(self.seed)
            tok = jax.random.categorical(
                key, row_logits / self.temperature, axis=-1)
        else:
            tok = jnp.argmax(row_logits, axis=-1)
        tok = tok.astype(jnp.int32)[:, None]
        # NOTE on SSM/hybrid rows shorter than L: state absorbed padding;
        # exact ragged SSM prefill would re-run per-row. Attention archs
        # are exact via kv_len. Documented engine limitation.
        pos = jnp.asarray(lens)
        out = [list(p) for p in prompts]
        for step in range(max_new_tokens):
            for i in range(B):
                out[i].append(int(tok[i, 0]))
            key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
            tok, cache = self._decode_jit(self.params, tok, pos, cache,
                                          key)
            pos = pos + 1
        return out
