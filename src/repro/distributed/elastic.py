"""Elastic re-meshing: shrink/grow the data axis and reshard state.

Protocol on host failure (posture for 1000+ nodes):
  1. the supervisor detects dead hosts (missed heartbeats);
  2. the coordinator picks the largest power-of-two data-axis size that
     the surviving hosts support (the model axis is kept intact — TP
     groups are co-located within a pod and a lost TP member kills that
     replica anyway);
  3. every survivor restarts the jit program against the new mesh and
     restores the latest checkpoint with the NEW shardings — the
     checkpoint format is mesh-agnostic (full logical arrays per leaf),
     so resharding is just device_put with different NamedShardings.

In this repo the mechanism is exercised end-to-end at small scale by
tests/test_distributed.py: train on mesh A, checkpoint, rebuild on mesh B
(different data-axis size), restore, continue — losses match a no-failure
run after the same number of steps.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.distributed.sharding import LogicalRules, tree_shardings

PyTree = Any


def largest_pow2_leq(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def elastic_mesh_shape(n_devices: int, model_size: int,
                       pod_size: Optional[int] = None
                       ) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest power-of-two data axis that fits the surviving devices."""
    if n_devices < model_size:
        raise ValueError(
            f"{n_devices} devices cannot host model axis {model_size}")
    data = largest_pow2_leq(n_devices // model_size)
    if pod_size and data > pod_size:
        pods = data // pod_size
        return (pods, pod_size, model_size), ("pod", "data", "model")
    return (data, model_size), ("data", "model")


def make_elastic_mesh(n_devices: int, model_size: int,
                      devices: Optional[Sequence] = None) -> Mesh:
    shape, axes = elastic_mesh_shape(n_devices, model_size)
    devs = list(devices or jax.devices())[:int(np.prod(shape))]
    return Mesh(np.asarray(devs).reshape(shape), axes)


def reshard(tree: PyTree, axes_tree: PyTree, shapes_tree: PyTree,
            new_mesh: Mesh) -> PyTree:
    """device_put every leaf with the sharding the new mesh resolves."""
    rules = LogicalRules(new_mesh)
    shardings = tree_shardings(rules, shapes_tree, axes_tree)
    return jax.tree.map(jax.device_put, tree, shardings)
