"""Sharded, manifest-based checkpointing with async save and integrity
hashes — the fault-tolerance substrate.

Layout (one directory per step):

    <root>/step_000123/
        manifest.json         {step, leaves: {path: {file, shape, dtype,
                               sha256}}, meta}
        p00000_<name>.npy     one file per pytree leaf

Design points for the 1000+-node posture:
  * each leaf file is written atomically (tmp + rename) and content-hashed,
    so a killed host never corrupts a checkpoint;
  * the manifest is written LAST — a checkpoint without a manifest is
    ignored by ``latest_step`` (crash-consistent commit point);
  * on a real multihost deployment each process saves the leaves whose
    first shard it owns (``owned_only=True`` filters by
    ``jax.process_index()``); restore device_puts into whatever sharding
    the CURRENT mesh requests, which is what makes elastic re-mesh
    (repro.distributed.elastic) a restore-with-different-rules operation;
  * async mode pushes serialization to a worker thread: the train loop
    only blocks on ``jax.device_get`` (fast) and continues while files
    stream to disk.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _leaf_paths(tree: PyTree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def _sanitize(key: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "_", key)[:120]


def _write_atomic(path: str, arr: np.ndarray) -> str:
    tmp = path + ".tmp"
    np.save(tmp, arr, allow_pickle=False)
    os.replace(tmp + ".npy" if not tmp.endswith(".npy") else tmp, path)
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class Checkpointer:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree: PyTree, meta: Optional[dict] = None,
             async_: bool = False) -> str:
        # materialize on host before handing to the writer thread
        host_tree = jax.device_get(tree)
        if async_:
            self.wait()
            self._thread = threading.Thread(
                target=self._save_sync, args=(step, host_tree, meta))
            self._thread.start()
            return self._dir(step)
        return self._save_sync(step, host_tree, meta)

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def _save_sync(self, step: int, host_tree: PyTree,
                   meta: Optional[dict]) -> str:
        d = self._dir(step)
        os.makedirs(d, exist_ok=True)
        leaves = _leaf_paths(host_tree)
        manifest = {"step": step, "meta": meta or {}, "leaves": {}}
        for i, (key, leaf) in enumerate(sorted(leaves.items())):
            arr = np.asarray(leaf)
            fname = f"p{i:05d}_{_sanitize(key)}.npy"
            digest = _write_atomic(os.path.join(d, fname), arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "sha256": digest}
        # manifest last = commit point
        tmp = os.path.join(d, "manifest.json.tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f, indent=1)
        os.replace(tmp, os.path.join(d, "manifest.json"))
        self._gc()
        return d

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in sorted(os.listdir(self.root)):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(
                    os.path.join(self.root, name, "manifest.json")):
                out.append(int(m.group(1)))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None,
                verify: bool = True) -> Tuple[PyTree, dict]:
        """Restore into the structure of ``template``; if ``shardings`` is
        given (matching pytree of NamedSharding), leaves are device_put
        with them — this is the elastic re-mesh entry point."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self._dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        keys = _leaf_paths(template)
        shard_map_ = _leaf_paths(shardings) if shardings is not None else {}
        restored = {}
        for key in keys:
            ent = manifest["leaves"].get(key)
            if ent is None:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            path = os.path.join(d, ent["file"])
            arr = np.load(path, allow_pickle=False)
            if verify:
                h = hashlib.sha256()
                with open(path, "rb") as f:
                    for chunk in iter(lambda: f.read(1 << 20), b""):
                        h.update(chunk)
                if h.hexdigest() != ent["sha256"]:
                    raise IOError(f"hash mismatch for {key} in {d}")
            if key in shard_map_:
                restored[key] = jax.device_put(arr, shard_map_[key])
            else:
                restored[key] = arr
        # rebuild in template order
        flat = jax.tree_util.tree_flatten_with_path(template)
        keys_in_order = ["/".join(str(getattr(p, "key",
                                               getattr(p, "idx", p)))
                                  for p in path)
                         for path, _ in flat[0]]
        leaves = [restored[k] for k in keys_in_order]
        return jax.tree_util.tree_unflatten(flat[1], leaves), manifest
