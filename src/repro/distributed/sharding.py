"""Logical-axis -> mesh-axis sharding rules with divisibility fallback.

Every tensor in the framework (params, activations, caches, optimizer state)
carries *logical* axes ("embed", "mlp", "vocab", "batch", "kv_seq", ...).
``LogicalRules`` resolves them against a concrete mesh:

  * each logical axis has a priority list of candidate mesh axes / axis
    tuples;
  * a candidate is taken only if its total size divides the tensor dim and
    none of its mesh axes are already used by another dim of the same tensor;
  * otherwise fall through; an exhausted list means replicate that dim.

This one mechanism gives DP (+pod DP), FSDP/ZeRO-3 (weight "embed" dims on
the data axes), TP (mlp/qkv/vocab/heads on "model"), EP (experts on "model"
with TP-within-expert fallback for n_experts < model-axis, e.g. grok's 8
experts on a 16-wide model axis) and SP (kv_seq on "model" when kv_heads
doesn't divide — the 500k-context decode path).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Candidate = Tuple[str, ...]


# Priority lists per logical axis.  Entries are tuples of mesh axis names;
# "+pod" variants are synthesized automatically when the mesh has a pod axis.
DEFAULT_RULES: Dict[str, List[Candidate]] = {
    # weight axes
    "embed":      [("data",)],           # FSDP / ZeRO-3 weight sharding
    "vocab":      [("model",)],
    "mlp":        [("model",)],
    "qkv":        [("model",)],          # q-projection output dim
    "kv":         [("model",)],          # kv-projection output dim
    "expert":     [("model",)],          # EP when n_experts divides
    "expert_mlp": [("model",)],          # TP-within-expert fallback
    "conv":       [],
    "layers":     [],
    "state":      [],
    # activation axes
    "batch":      [("pod", "data"), ("data",)],
    "seq":        [],
    "heads":      [("model",)],
    "kv_heads":   [("model",)],
    "head_dim":   [("model",)],          # fallback TP when heads don't divide
    "kv_seq":     [("model",)],          # SP for long-context KV caches
    "cell_y":     [],                    # MultiScope proxy grids
    "cell_x":     [],
}

# For MoE expert weights, when "expert" can't shard we want the expert's own
# mlp dim to pick up "model" — expressed by listing both and letting the
# used-axis bookkeeping handle it (see pspec_for_shape).


class LogicalRules:
    def __init__(self, mesh: Mesh,
                 rules: Optional[Dict[str, List[Candidate]]] = None):
        self.mesh = mesh
        self.axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)

    def _expand(self, cand: Candidate) -> Optional[Tuple[str, ...]]:
        """Map a candidate onto this mesh; synthesize pod prefixing for
        'data', drop candidates that reference absent axes."""
        names = []
        for ax in cand:
            if ax == "pod" and "pod" not in self.axis_sizes:
                continue
            if ax not in self.axis_sizes:
                return None
            names.append(ax)
        if not names:
            return None
        return tuple(names)

    def _cand_size(self, names: Tuple[str, ...]) -> int:
        return int(np.prod([self.axis_sizes[n] for n in names]))

    def candidates(self, logical: str) -> List[Tuple[str, ...]]:
        out = []
        for cand in self.rules.get(logical, []):
            # synthesize ("pod", ...) variant first when pod exists
            if "pod" in self.axis_sizes and "pod" not in cand \
                    and cand and cand[0] == "data":
                exp = self._expand(("pod",) + cand)
                if exp:
                    out.append(exp)
            exp = self._expand(cand)
            if exp:
                out.append(exp)
        return out

    def pspec_for_shape(self, shape: Sequence[int],
                        axes: Sequence[Optional[str]]) -> P:
        """Resolve logical axes against a concrete shape."""
        if len(shape) != len(axes):
            raise ValueError(f"shape {shape} vs axes {axes}")
        used: set = set()
        entries: List[Optional[Tuple[str, ...]]] = []
        for dim, logical in zip(shape, axes):
            entry: Optional[Tuple[str, ...]] = None
            if logical is not None:
                for cand in self.candidates(logical):
                    if any(n in used for n in cand):
                        continue
                    if dim % self._cand_size(cand) == 0:
                        entry = cand
                        used.update(cand)
                        break
            entries.append(entry)
        return P(*[e if e is None or len(e) > 1 else e[0] for e in entries])

    def pspec(self, axes: Sequence[Optional[str]],
              shape: Sequence[int]) -> P:
        return self.pspec_for_shape(shape, axes)

    def named_sharding(self, shape: Sequence[int],
                       axes: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec_for_shape(shape, axes))


def is_axes_leaf(x) -> bool:
    """An axes annotation: tuple of axis names / None (() = scalar)."""
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


def tree_pspecs(rules: LogicalRules, shapes, axes_tree):
    """Map matching (ShapeDtypeStruct tree, logical-axes tree) -> PSpec
    tree.  Axes tree drives the traversal so scalar axes ``()`` work."""
    import jax
    return jax.tree.map(
        lambda ax, sds: rules.pspec_for_shape(sds.shape, ax),
        axes_tree, shapes, is_leaf=is_axes_leaf)


def tree_shardings(rules: LogicalRules, shapes, axes_tree):
    import jax
    specs = tree_pspecs(rules, shapes, axes_tree)
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def replicated_like(mesh: Mesh, tree):
    """Fully-replicated NamedSharding tree matching ``tree``."""
    import jax
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
