"""Fault tolerance + straggler mitigation for the training loop.

``Supervisor`` wraps the step loop with:
  * periodic checkpointing (async) via repro.distributed.checkpoint;
  * crash recovery: any exception from the step function triggers a
    restore-from-latest and replay (bounded retries) — on a real cluster
    the restart path re-resolves the mesh from live hosts first (see
    repro.distributed.elastic);
  * straggler detection: an EWMA of per-step wall time per host; hosts
    exceeding ``straggler_factor`` x the median over a window are flagged
    and reported through ``on_straggler`` (deployments use this to request
    backup workers / evict the host).

The data pipeline must be SKIPPABLE (batch_at(step)) so replay after
restore does not double-train — repro.data.tokens provides that.
"""
from __future__ import annotations

import time
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

from repro.distributed.checkpoint import Checkpointer


@dataclass
class HeartbeatMonitor:
    window: int = 20
    straggler_factor: float = 2.0
    _times: Dict[int, deque] = field(default_factory=lambda: defaultdict(
        lambda: deque(maxlen=64)))

    def record(self, host: int, seconds: float) -> None:
        self._times[host].append(seconds)

    def stragglers(self):
        import statistics
        means = {h: statistics.fmean(list(ts)[-self.window:])
                 for h, ts in self._times.items() if ts}
        if len(means) < 2:
            return []
        med = statistics.median(means.values())
        return [h for h, m in means.items()
                if m > self.straggler_factor * med]


@dataclass
class Supervisor:
    checkpointer: Checkpointer
    checkpoint_every: int = 50
    max_restarts: int = 3
    on_straggler: Optional[Callable[[list], None]] = None
    monitor: HeartbeatMonitor = field(default_factory=HeartbeatMonitor)
    restarts: int = 0

    def run(self, state: Any, step_fn: Callable[[Any, int], Any],
            start_step: int, num_steps: int,
            template: Any = None, shardings: Any = None) -> Any:
        """Run ``num_steps`` of ``step_fn(state, step) -> state`` with
        checkpoint/restart.  ``template`` defaults to ``state`` (used to
        rebuild the pytree on restore)."""
        template = state if template is None else template
        step = start_step
        end = start_step + num_steps
        while step < end:
            try:
                t0 = time.monotonic()
                state = step_fn(state, step)
                self.monitor.record(0, time.monotonic() - t0)
                step += 1
                if step % self.checkpoint_every == 0:
                    self.checkpointer.save(step, state, async_=True)
                bad = self.monitor.stragglers()
                if bad and self.on_straggler:
                    self.on_straggler(bad)
            except KeyboardInterrupt:
                raise
            except Exception:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                self.checkpointer.wait()
                latest = self.checkpointer.latest_step()
                if latest is None:
                    # nothing durable yet: restart from the initial state
                    step = start_step
                    continue
                state, manifest = self.checkpointer.restore(
                    template, step=latest, shardings=shardings)
                step = manifest["step"]
        self.checkpointer.wait()
        return state
