from repro.distributed.sharding import LogicalRules, tree_pspecs, tree_shardings  # noqa: F401
