"""Error-feedback int8 gradient compression for cross-pod reduction.

At 2+ pods the inter-pod links are the scarcest bandwidth (data-center
interconnect vs intra-pod ICI), so cross-pod gradient all-reduce is the
tensor to compress.  We use the standard error-feedback scheme (1-bit
Adam / EF-SGD lineage, here at 8 bits):

    q_t   = Q(g_t + e_{t-1})          # int8 row-wise absmax quantization
    e_t   = (g_t + e_{t-1}) - D(q_t)  # residual kept LOCALLY
    out   = D(allreduce(q_t))         # wire carries int8 (4x fewer bytes)

The residual e_t re-enters the next step, so quantization error
accumulates to zero rather than biasing the trajectory.

Two entry points:
  * ``ef_roundtrip`` — pure quantize/dequantize + error feedback, used as a
    TrainStep.grad_transform; under SPMD jit the all-reduce stays fused in
    XLA and this simulates exactly the wire precision (the numerics the
    tests validate).
  * ``compressed_psum`` — explicit shard_map psum over a named axis in
    int32 (summing int8 payloads without overflow: 8-bit values x <= 2^15
    pods fit int32), for deployments that lower the cross-pod reduce
    manually.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def _quant(x):
    """fp32 -> (int8, row absmax scale).  Rows = last axis."""
    if x.ndim == 0:
        scale = jnp.maximum(jnp.abs(x), 1e-30)
        return jnp.round(x / scale * 127).astype(jnp.int8), scale
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-30)
    q = jnp.round(jnp.clip(x / scale, -1, 1) * 127).astype(jnp.int8)
    return q, scale


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale / 127.0


def init_error_buffer(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_roundtrip(grads: PyTree, err: PyTree) -> Tuple[PyTree, PyTree]:
    """Quantize grads+err to int8 precision and return (dequantized grads,
    new error buffer)."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, s = _quant(x)
        d = _dequant(q, s)
        return d, x - d
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([p[0] for p in pairs]),
            treedef.unflatten([p[1] for p in pairs]))


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-on-the-wire psum for use INSIDE shard_map: quantize locally,
    sum int32 payloads across the axis, dequantize with the max scale."""
    q, scale = _quant(x)
    scale_max = jax.lax.pmax(scale, axis_name)
    # renormalize local payload to the common scale before the wire sum
    q2 = jnp.round(_dequant(q, scale) / scale_max * 127).astype(jnp.int32)
    total = jax.lax.psum(q2, axis_name)
    return total.astype(jnp.float32) * scale_max / 127.0
