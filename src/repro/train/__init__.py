from repro.train.step import TrainStep, build_train_step  # noqa: F401
