"""Train-step builder: loss -> grads -> clip -> (optional compression) ->
AdamW, with microbatch gradient accumulation via lax.scan.

The returned ``train_step(params, opt_state, batch, ...)`` is a pure
function ready for ``jax.jit`` with shardings; ``repro.launch`` wires the
in/out shardings from the logical axes.

Accumulation: the global batch is split into ``accum`` microbatches along
the batch axis and scanned; grads are averaged in fp32.  Activation memory
scales with batch/accum while weight-gradient memory is one full set —
the standard large-batch trick.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim.adamw import AdamW, AdamWState
from repro.optim.clip import clip_by_global_norm

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainStep:
    model: Model
    optimizer: AdamW
    accum: int = 1
    max_grad_norm: float = 1.0
    grad_transform: Optional[Callable[[PyTree], PyTree]] = None
    # cast fp32 master weights to bf16 ONCE at step entry, so the FSDP
    # all-gathers inside the layer scan move bf16 (2x less ICI traffic);
    # grads flow back through the cast and accumulate in fp32
    cast_bf16: bool = False

    def _maybe_cast(self, params: PyTree) -> PyTree:
        if not self.cast_bf16:
            return params
        import jax.numpy as jnp
        return jax.tree.map(
            lambda p: p.astype(jnp.bfloat16)
            if p.dtype == jnp.float32 and p.ndim >= 2 else p, params)

    def _microbatch(self, batch: Dict[str, Any], n: int):
        def split(x):
            b = x.shape[0]
            assert b % n == 0, (b, n)
            return x.reshape(n, b // n, *x.shape[1:])
        return jax.tree.map(split, batch)

    def grads(self, params: PyTree, batch: Dict[str, Any]
              ) -> Tuple[PyTree, Dict[str, jax.Array]]:
        def loss_fn(p, b):
            return self.model.loss(self._maybe_cast(p), b)
        loss_and_grad = jax.value_and_grad(loss_fn, has_aux=True)
        if self.accum <= 1:
            (loss, metrics), g = loss_and_grad(params, batch)
            return g, {"loss": loss, **metrics}
        micro = self._microbatch(batch, self.accum)

        def body(carry, mb):
            g_acc, loss_acc = carry
            (loss, _), g = loss_and_grad(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (g_acc, loss_acc + loss), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                          params)
        (g, loss_sum), _ = jax.lax.scan(body, (g0, jnp.zeros(())), micro)
        scale = 1.0 / self.accum
        g = jax.tree.map(lambda x: x * scale, g)
        return g, {"loss": loss_sum * scale}

    def __call__(self, params: PyTree, opt_state: AdamWState,
                 batch: Dict[str, Any]):
        g, metrics = self.grads(params, batch)
        g, gnorm = clip_by_global_norm(g, self.max_grad_norm)
        if self.grad_transform is not None:
            g = self.grad_transform(g)
        params, opt_state = self.optimizer.update(g, opt_state, params)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics


def build_train_step(model: Model, optimizer: AdamW, *, accum: int = 1,
                     max_grad_norm: float = 1.0, grad_transform=None,
                     cast_bf16: bool = False) -> TrainStep:
    return TrainStep(model, optimizer, accum, max_grad_norm,
                     grad_transform, cast_bf16)
