from repro.optim.adamw import AdamW, adamw  # noqa: F401
from repro.optim.schedules import cosine_schedule, linear_schedule  # noqa: F401
from repro.optim.clip import clip_by_global_norm, global_norm  # noqa: F401
