"""AdamW with fp32 master weights and an optional 8-bit second moment.

The optimizer is a pure-function pair (init, update) over pytrees.  The
8-bit ``v`` uses per-row absmax quantization (last axis kept fp-accurate
via a fp32 scale per leading index), the standard memory trick for fitting
314B-class models (grok) in 16 GB/chip HBM: v bytes drop 4x and the Adam
update dequantizes on the fly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


def _quantize_v(v):
    """fp32 -> (int8, fp32 row scale).  v >= 0 (second moment)."""
    if v.ndim == 0:
        scale = jnp.maximum(v, 1e-30)
        return (v / scale * 127).astype(jnp.int8), scale
    amax = jnp.max(v, axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-30)
    q = jnp.round(v / scale * 127).astype(jnp.int8)
    return q, scale


def _dequantize_v(q, scale):
    return q.astype(jnp.float32) * scale / 127.0


class AdamWState(NamedTuple):
    step: jax.Array
    m: PyTree
    v: PyTree            # fp32, or (int8, scale) pairs when quantized


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    quantize_v: bool = False

    def init(self, params: PyTree) -> AdamWState:
        m = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        if self.quantize_v:
            v = jax.tree.map(
                lambda p: _quantize_v(jnp.zeros(p.shape, jnp.float32)),
                params)
        else:
            v = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                             params)
        return AdamWState(jnp.zeros((), jnp.int32), m, v)

    def _lr(self, step):
        if callable(self.lr):
            return self.lr(step)
        return jnp.asarray(self.lr, jnp.float32)

    def update(self, grads: PyTree, state: AdamWState, params: PyTree):
        step = state.step + 1
        lr = self._lr(step)
        bc1 = 1.0 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = self.b1 * m + (1 - self.b1) * g
            if self.quantize_v:
                vq, vs = v
                vf = _dequantize_v(vq, vs)
            else:
                vf = v
            vf = self.b2 * vf + (1 - self.b2) * g * g
            mhat = m / bc1
            vhat = vf / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            new_v = _quantize_v(vf) if self.quantize_v else vf
            return new_p, m, new_v

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, AdamWState(step, new_m, new_v)

    # sharding helper: optimizer state inherits the param logical axes
    def state_axes(self, param_axes: PyTree) -> Any:
        def vx(ax):
            if self.quantize_v:
                # (int8 tensor, keepdims row scale)
                scale_ax = ax[:-1] + (None,) if ax else ax
                return (ax, scale_ax)
            return ax
        is_leaf = lambda x: isinstance(x, tuple) and all(   # noqa: E731
            isinstance(e, (str, type(None))) for e in x)
        m_axes = param_axes
        v_axes = jax.tree.map(vx, param_axes, is_leaf=is_leaf)
        return AdamWState((), m_axes, v_axes)


def adamw(lr=1e-3, **kw) -> AdamW:
    return AdamW(lr=lr, **kw)
