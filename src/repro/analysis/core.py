"""Framework for the contract linter: file loading, the pass registry,
findings, and suppression comments.

A *pass* is a function ``(Project) -> List[Finding]`` registered under
a stable id with :func:`lint_pass`.  The runner applies suppression
comments afterwards, so passes stay oblivious to them:

  ``# repro-lint: disable=<pass>[,<pass>] -- <why>``
      trailing on the offending line, or alone on the line directly
      above it.  The ``-- <why>`` justification is REQUIRED: a bare
      suppression is itself reported (pass id ``suppression``).
  ``# repro-lint: disable-file=<pass> -- <why>``
      anywhere in the file; disables the pass for the whole file.

Only stdlib modules here — the linter must run in a bare CI job with
no jax/numpy installed.
"""
from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence

__all__ = ["Finding", "SourceFile", "Project", "Report", "PASSES",
           "lint_pass", "run_passes"]

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*disable(?P<scope>-file)?="
    r"(?P<passes>[A-Za-z0-9_,\-]+)"
    r"(?:\s*--\s*(?P<why>\S.*))?")


@dataclass
class Finding:
    """One defect at one location.  ``path`` is repo-root-relative."""
    pass_id: str
    path: str
    line: int
    message: str
    suppressed: bool = False
    justification: str = ""

    def as_dict(self) -> dict:
        return {"pass": self.pass_id, "path": self.path,
                "line": self.line, "message": self.message,
                "suppressed": self.suppressed,
                "justification": self.justification}

    def __str__(self) -> str:
        tag = " (suppressed)" if self.suppressed else ""
        return (f"{self.path}:{self.line}: [{self.pass_id}] "
                f"{self.message}{tag}")


@dataclass
class _Suppression:
    passes: List[str]
    why: str
    line: int
    file_wide: bool


class SourceFile:
    """One parsed python file: source lines, AST, and the suppression
    comments found on its lines."""

    def __init__(self, path: Path, rel: str):
        self.path = path
        self.rel = rel
        self.text = path.read_text(encoding="utf-8", errors="replace")
        self.lines = self.text.splitlines()
        self.parse_error: Optional[str] = None
        try:
            self.tree: Optional[ast.Module] = ast.parse(self.text)
        except SyntaxError as exc:
            self.tree = None
            self.parse_error = f"syntax error: {exc.msg} (line {exc.lineno})"
        # pass -> why, file wide
        self.file_disables: Dict[str, str] = {}
        # effective line -> {pass -> why}
        self.line_disables: Dict[int, Dict[str, str]] = {}
        self.suppressions: List[_Suppression] = []
        self._scan_suppressions()

    def _scan_suppressions(self) -> None:
        for i, raw in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(raw)
            if not m:
                continue
            passes = [p for p in m.group("passes").split(",") if p]
            why = (m.group("why") or "").strip()
            file_wide = m.group("scope") == "-file"
            self.suppressions.append(
                _Suppression(passes, why, i, file_wide))
            if file_wide:
                for p in passes:
                    self.file_disables.setdefault(p, why)
                continue
            # a comment-only line suppresses the NEXT line; a trailing
            # comment suppresses its own line
            target = i + 1 if raw.lstrip().startswith("#") else i
            slot = self.line_disables.setdefault(target, {})
            for p in passes:
                slot.setdefault(p, why)

    def disabled(self, pass_id: str, line: int) -> Optional[str]:
        """The justification string if ``pass_id`` is suppressed at
        ``line`` (empty string = suppressed without a why), else None."""
        if pass_id in self.file_disables:
            return self.file_disables[pass_id]
        slot = self.line_disables.get(line)
        if slot is not None and pass_id in slot:
            return slot[pass_id]
        return None


class Project:
    """The lint unit: a repo root plus the python trees scanned under
    it (``src/repro`` and ``benchmarks`` by default — tests seed their
    fixtures under a tmp root with the same shape)."""

    DEFAULT_DIRS = ("src/repro", "benchmarks")

    def __init__(self, root, rel_dirs: Sequence[str] = DEFAULT_DIRS):
        self.root = Path(root).resolve()
        self.rel_dirs = tuple(rel_dirs)
        self.files: List[SourceFile] = []
        for d in self.rel_dirs:
            base = self.root / d
            if not base.is_dir():
                continue
            for p in sorted(base.rglob("*.py")):
                if "__pycache__" in p.parts:
                    continue
                rel = p.relative_to(self.root).as_posix()
                self.files.append(SourceFile(p, rel))
        self._by_rel = {sf.rel: sf for sf in self.files}

    def file(self, rel: str) -> Optional[SourceFile]:
        return self._by_rel.get(rel)

    def read_text(self, rel: str) -> Optional[str]:
        p = self.root / rel
        if not p.is_file():
            return None
        return p.read_text(encoding="utf-8", errors="replace")


@dataclass
class _PassInfo:
    pass_id: str
    summary: str
    fn: Callable[[Project], List[Finding]]


#: pass id -> _PassInfo, in registration order
PASSES: Dict[str, _PassInfo] = {}


def lint_pass(pass_id: str, summary: str):
    """Register a pass function under ``pass_id``."""
    def deco(fn):
        PASSES[pass_id] = _PassInfo(pass_id, summary, fn)
        return fn
    return deco


@dataclass
class Report:
    """All findings of one run, suppressions applied."""
    findings: List[Finding] = field(default_factory=list)

    @property
    def active(self) -> List[Finding]:
        return [f for f in self.findings if not f.suppressed]

    @property
    def suppressed(self) -> List[Finding]:
        return [f for f in self.findings if f.suppressed]

    def as_dict(self) -> dict:
        return {"findings": [f.as_dict() for f in self.findings],
                "counts": {"active": len(self.active),
                           "suppressed": len(self.suppressed)}}

    def to_json(self) -> str:
        return json.dumps(self.as_dict(), indent=2, sort_keys=True)


def run_passes(project: Project,
               select: Optional[Sequence[str]] = None) -> Report:
    """Run the selected (default: all) passes and apply suppressions."""
    selected = list(select) if select else list(PASSES)
    unknown = [p for p in selected if p not in PASSES]
    if unknown:
        raise KeyError(f"unknown pass(es): {', '.join(unknown)} "
                       f"(available: {', '.join(PASSES)})")
    findings: List[Finding] = []
    for pid in selected:
        findings.extend(PASSES[pid].fn(project))
    # a file that fails to parse can hide anything — always a finding
    for sf in project.files:
        if sf.parse_error:
            findings.append(Finding("parse", sf.rel, 1, sf.parse_error))
    for f in findings:
        sf = project.file(f.path)
        if sf is None:
            continue
        why = sf.disabled(f.pass_id, f.line)
        if why is not None:
            f.suppressed = True
            f.justification = why
    # suppressions must carry a justification (and bare file-wide ones
    # doubly so) — enforced here so every pass gets it for free
    for sf in project.files:
        for sup in sf.suppressions:
            if not sup.why:
                findings.append(Finding(
                    "suppression", sf.rel, sup.line,
                    "suppression without justification — append "
                    "' -- <why>'"))
    findings.sort(key=lambda f: (f.path, f.line, f.pass_id))
    return Report(findings)
