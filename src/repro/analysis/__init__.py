"""repro.analysis — the contract linter.

AST-based static checks (stdlib ``ast`` only, zero third-party deps)
for the invariants the pipeline's speedups rest on: the fastmath f32
bit-contract, the kernel ref-twin layout, the guarded-by lock
discipline, the obs span/metric naming tables, and the no-tracked-
bytecode rule.  Run it as ``python -m repro.analysis`` (CI runs
``--strict``); see README.md in this package for the pass catalog and
the suppression syntax.
"""
from repro.analysis.core import (Finding, Project, Report, PASSES,
                                 lint_pass, run_passes)
from repro.analysis import passes as _passes  # noqa: F401  (registers)

__all__ = ["Finding", "Project", "Report", "PASSES", "lint_pass",
           "run_passes"]
