"""obs-naming: code <-> `src/repro/obs/README.md` naming-table parity.

Every span name passed to ``TRACER.open/emit/span`` and every metric
name passed to ``REGISTRY.counter/gauge/histogram`` must match a row
of the README's span/metric tables, and every documented row must be
emitted by at least one call site — no undocumented names, no dead
documentation.

Table names may use ``{a,b}`` alternation (expanded), ``{ident}``
placeholders (wildcard segment), and a trailing ``[...]`` instance
label (stripped on both sides).  f-string call sites contribute a
wildcard segment per interpolation hole, so
``f"{prefix}.stage.{st}.wall_seconds"`` matches
``executor.stage.{name}.wall_seconds``.
"""
from __future__ import annotations

import ast
import re
from typing import List, Optional, Tuple

from repro.analysis.core import Finding, Project, lint_pass

_PASS = "obs-naming"
_README = "src/repro/obs/README.md"
_WILD = "\0"

_METRIC_METHODS = {"counter", "gauge", "histogram"}
_SPAN_METHODS = {"open", "emit", "span"}
_ALT_RE = re.compile(r"\{([^{}]*,[^{}]*)\}")
_PLACEHOLDER_RE = re.compile(r"\{[A-Za-z_]\w*\}")
_INSTANCE_RE = re.compile(r"\[[^\[\]]*\]\s*$")
_BACKTICK_RE = re.compile(r"`([^`]+)`")

Pattern = Tuple[str, ...]        # dotted segments; _WILD = wildcard


def _expand(name: str) -> List[str]:
    m = _ALT_RE.search(name)
    if not m:
        return [name]
    out: List[str] = []
    for alt in m.group(1).split(","):
        out.extend(_expand(name[:m.start()] + alt.strip()
                           + name[m.end():]))
    return out


def _to_pattern(name: str) -> Pattern:
    name = _INSTANCE_RE.sub("", name.strip())
    name = _PLACEHOLDER_RE.sub(_WILD, name)
    return tuple(_WILD if _WILD in seg else seg
                 for seg in name.split("."))


def _doc_patterns(text: str) -> List[Tuple[Pattern, int, str]]:
    """(pattern, line, raw) for every backticked name in a first
    table column.  Tokens starting with ``.`` continue the previous
    token (``broker.{d,t}.dispatches` / `.units_in```)."""
    out: List[Tuple[Pattern, int, str]] = []
    for lineno, line in enumerate(text.splitlines(), start=1):
        if not line.lstrip().startswith("|"):
            continue
        first_cell = line.split("|")[1] if "|" in line else ""
        prev: Optional[str] = None
        for raw in _BACKTICK_RE.findall(first_cell):
            raw = raw.strip()
            if raw.startswith(".") and prev is not None:
                n_seg = len([s for s in raw.split(".") if s])
                base = prev.split(".")
                raw = ".".join(base[:-n_seg]) + raw
            prev = raw
            for name in _expand(raw):
                out.append((_to_pattern(name), lineno, raw))
    return out


def _match(a: Pattern, b: Pattern) -> bool:
    return len(a) == len(b) and all(
        x == _WILD or y == _WILD or x == y for x, y in zip(a, b))


def _name_arg(node: ast.Call) -> Optional[str]:
    """The name literal of a call's first argument: plain string, or
    an f-string with _WILD holes.  None = not statically knowable."""
    if not node.args:
        return None
    arg = node.args[0]
    if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
        return arg.value
    if isinstance(arg, ast.JoinedStr):
        parts: List[str] = []
        for v in arg.values:
            if isinstance(v, ast.Constant):
                parts.append(str(v.value))
            else:
                parts.append(_WILD)
        return "".join(parts)
    if isinstance(arg, ast.Name):
        # a previously-assigned literal (e.g. span_name = f"stage...")
        return None
    return None


def _receiver(node: ast.Call) -> Optional[str]:
    fn = node.func
    if not isinstance(fn, ast.Attribute):
        return None
    v = fn.value
    base = v.id if isinstance(v, ast.Name) else \
        v.attr if isinstance(v, ast.Attribute) else None
    if base in ("TRACER", "tracer") and fn.attr in _SPAN_METHODS:
        return "span"
    if base in ("REGISTRY", "registry") \
            and fn.attr in _METRIC_METHODS:
        return "metric"
    return None


def _code_name_pattern(raw: str) -> Pattern:
    raw = _INSTANCE_RE.sub("", raw)
    # an f-string hole inside a [...] instance label leaves a
    # dangling "[" once the closing bracket was consumed by the hole
    raw = re.sub(r"\[[^\[\]]*$", "", raw)
    return tuple(_WILD if _WILD in seg else seg
                 for seg in raw.split("."))


# names assigned to locals and used as the call arg later (the
# executor's per-stage span_name) — resolved by a simple one-step scan
def _literal_locals(tree: ast.Module) -> dict:
    env: dict = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            fake = ast.Call(func=ast.Name(id="x", ctx=ast.Load()),
                            args=[node.value], keywords=[])
            lit = _name_arg(fake)
            if lit is not None:
                env[node.targets[0].id] = lit
    return env


@lint_pass(_PASS,
           "span/metric name literals must appear in the obs README "
           "naming tables and vice versa")
def run(project: Project) -> List[Finding]:
    out: List[Finding] = []
    text = project.read_text(_README)
    if text is None:
        out.append(Finding(_PASS, _README, 1,
                           "obs naming tables not found (README "
                           "missing)"))
        return out
    docs = _doc_patterns(text)
    if not docs:
        out.append(Finding(_PASS, _README, 1,
                           "no naming-table rows found in the obs "
                           "README"))
        return out
    used = [False] * len(docs)
    for sf in project.files:
        if sf.tree is None:
            continue
        env = _literal_locals(sf.tree)
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            kind = _receiver(node)
            if kind is None:
                continue
            raw = _name_arg(node)
            if raw is None and node.args \
                    and isinstance(node.args[0], ast.Name):
                raw = env.get(node.args[0].id)
            if raw is None:
                continue
            pat = _code_name_pattern(raw)
            hit = False
            for i, (dpat, _ln, _raw) in enumerate(docs):
                if _match(pat, dpat):
                    used[i] = True
                    hit = True
            if not hit:
                shown = raw.replace(_WILD, "{...}")
                out.append(Finding(
                    _PASS, sf.rel, node.lineno,
                    f"{kind} name `{shown}` is not documented in "
                    f"{_README} — add it to the naming table (or fix "
                    f"the name)"))
    for (dpat, lineno, raw), was_used in zip(docs, used):
        if not was_used:
            out.append(Finding(
                _PASS, _README, lineno,
                f"documented name `{raw}` has no emitting call site "
                f"— dead documentation (remove the row or restore "
                f"the metric)"))
    return out
