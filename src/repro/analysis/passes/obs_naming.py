"""obs-naming: code <-> `src/repro/obs/README.md` naming-table parity.

Every span name passed to ``TRACER.open/emit/span``, every metric name
passed to ``REGISTRY.counter/gauge/histogram/provider``, every endpoint
path passed to ``@route(...)``, every ``HealthComponent(...)`` name and
every ``AlertRule(...)`` name must match a row of the README's tables,
and every documented row must have at least one emitting call site —
no undocumented names, no dead documentation.

Rows are pooled by the markdown section they appear under: a heading
containing ``endpoint`` / ``health`` / ``alert`` opens that pool; any
other heading (or none — bare tables in tests) opens the shared
span/metric pool.  Code sites check only against their own pool.

Table names may use ``{a,b}`` alternation (expanded), ``{ident}``
placeholders (wildcard segment), and a trailing ``[...]`` instance
label (stripped on both sides).  f-string call sites contribute a
wildcard segment per interpolation hole, so
``f"{prefix}.stage.{st}.wall_seconds"`` matches
``executor.stage.{name}.wall_seconds``.
"""
from __future__ import annotations

import ast
import re
from typing import List, Optional, Tuple

from repro.analysis.core import Finding, Project, lint_pass

_PASS = "obs-naming"
_README = "src/repro/obs/README.md"
_WILD = "\0"

_METRIC_METHODS = {"counter", "gauge", "histogram", "provider"}
_SPAN_METHODS = {"open", "emit", "span"}
# constructor/decorator names whose first (or ``name=``/``path=``)
# string literal is a lintable name, and the pool it checks against
_NAMED_CTORS = {"route": "endpoint", "HealthComponent": "health",
                "AlertRule": "alert"}
_ALT_RE = re.compile(r"\{([^{}]*,[^{}]*)\}")
_PLACEHOLDER_RE = re.compile(r"\{[A-Za-z_]\w*\}")
_INSTANCE_RE = re.compile(r"\[[^\[\]]*\]\s*$")
_BACKTICK_RE = re.compile(r"`([^`]+)`")

Pattern = Tuple[str, ...]        # dotted segments; _WILD = wildcard


def _expand(name: str) -> List[str]:
    m = _ALT_RE.search(name)
    if not m:
        return [name]
    out: List[str] = []
    for alt in m.group(1).split(","):
        out.extend(_expand(name[:m.start()] + alt.strip()
                           + name[m.end():]))
    return out


def _to_pattern(name: str) -> Pattern:
    name = _INSTANCE_RE.sub("", name.strip())
    name = _PLACEHOLDER_RE.sub(_WILD, name)
    return tuple(_WILD if _WILD in seg else seg
                 for seg in name.split("."))


def _section_pool(heading: str) -> str:
    h = heading.lower()
    if "endpoint" in h:
        return "endpoint"
    if "health" in h:
        return "health"
    if "alert" in h:
        return "alert"
    return "name"


def _doc_patterns(text: str) -> List[Tuple[Pattern, int, str, str]]:
    """(pattern, line, raw, pool) for every backticked name in a first
    table column.  Tokens starting with ``.`` continue the previous
    token (``broker.{d,t}.dispatches` / `.units_in```).  The pool is
    the enclosing markdown section's (see ``_section_pool``)."""
    out: List[Tuple[Pattern, int, str, str]] = []
    pool = "name"
    for lineno, line in enumerate(text.splitlines(), start=1):
        stripped = line.strip()
        if stripped.startswith("#"):
            pool = _section_pool(stripped.lstrip("#"))
            continue
        if not stripped.startswith("|"):
            continue
        first_cell = line.split("|")[1] if "|" in line else ""
        prev: Optional[str] = None
        for raw in _BACKTICK_RE.findall(first_cell):
            raw = raw.strip()
            if raw.startswith(".") and prev is not None:
                n_seg = len([s for s in raw.split(".") if s])
                base = prev.split(".")
                raw = ".".join(base[:-n_seg]) + raw
            prev = raw
            for name in _expand(raw):
                out.append((_to_pattern(name), lineno, raw, pool))
    return out


def _match(a: Pattern, b: Pattern) -> bool:
    return len(a) == len(b) and all(
        x == _WILD or y == _WILD or x == y for x, y in zip(a, b))


def _str_literal(arg: ast.AST) -> Optional[str]:
    if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
        return arg.value
    if isinstance(arg, ast.JoinedStr):
        parts: List[str] = []
        for v in arg.values:
            if isinstance(v, ast.Constant):
                parts.append(str(v.value))
            else:
                parts.append(_WILD)
        return "".join(parts)
    return None


def _name_arg(node: ast.Call) -> Optional[str]:
    """The name literal of a call's first argument (or a ``name=`` /
    ``path=`` keyword): plain string, or an f-string with _WILD holes.
    None = not statically knowable."""
    if node.args:
        return _str_literal(node.args[0])
    for kw in node.keywords:
        if kw.arg in ("name", "path"):
            return _str_literal(kw.value)
    return None


def _callable_name(fn: ast.AST) -> Optional[str]:
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return None


def _receiver(node: ast.Call) -> Optional[Tuple[str, str]]:
    """(display kind, doc pool) for a lintable call, else None."""
    fn = node.func
    if isinstance(fn, ast.Attribute):
        v = fn.value
        base = v.id if isinstance(v, ast.Name) else \
            v.attr if isinstance(v, ast.Attribute) else None
        if base in ("TRACER", "tracer") and fn.attr in _SPAN_METHODS:
            return "span", "name"
        if base in ("REGISTRY", "registry") \
                and fn.attr in _METRIC_METHODS:
            return "metric", "name"
    ctor = _callable_name(fn)
    if ctor in _NAMED_CTORS:
        pool = _NAMED_CTORS[ctor]
        return pool, pool
    return None


def _code_name_pattern(raw: str) -> Pattern:
    raw = _INSTANCE_RE.sub("", raw)
    # an f-string hole inside a [...] instance label leaves a
    # dangling "[" once the closing bracket was consumed by the hole
    raw = re.sub(r"\[[^\[\]]*$", "", raw)
    return tuple(_WILD if _WILD in seg else seg
                 for seg in raw.split("."))


# names assigned to locals and used as the call arg later (the
# executor's per-stage span_name) — resolved by a simple one-step scan
def _literal_locals(tree: ast.Module) -> dict:
    env: dict = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            lit = _str_literal(node.value)
            if lit is not None:
                env[node.targets[0].id] = lit
    return env


@lint_pass(_PASS,
           "span/metric/endpoint/health/alert name literals must "
           "appear in the obs README naming tables and vice versa")
def run(project: Project) -> List[Finding]:
    out: List[Finding] = []
    text = project.read_text(_README)
    if text is None:
        out.append(Finding(_PASS, _README, 1,
                           "obs naming tables not found (README "
                           "missing)"))
        return out
    docs = _doc_patterns(text)
    if not docs:
        out.append(Finding(_PASS, _README, 1,
                           "no naming-table rows found in the obs "
                           "README"))
        return out
    used = [False] * len(docs)
    for sf in project.files:
        if sf.tree is None:
            continue
        env = _literal_locals(sf.tree)
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            rec = _receiver(node)
            if rec is None:
                continue
            kind, pool = rec
            raw = _name_arg(node)
            if raw is None and node.args \
                    and isinstance(node.args[0], ast.Name):
                raw = env.get(node.args[0].id)
            if raw is None:
                continue
            pat = _code_name_pattern(raw)
            hit = False
            for i, (dpat, _ln, _raw, dpool) in enumerate(docs):
                if dpool == pool and _match(pat, dpat):
                    used[i] = True
                    hit = True
            if not hit:
                shown = raw.replace(_WILD, "{...}")
                out.append(Finding(
                    _PASS, sf.rel, node.lineno,
                    f"{kind} name `{shown}` is not documented in "
                    f"{_README} — add it to the naming table (or fix "
                    f"the name)"))
    for (dpat, lineno, raw, _pool), was_used in zip(docs, used):
        if not was_used:
            out.append(Finding(
                _PASS, _README, lineno,
                f"documented name `{raw}` has no emitting call site "
                f"— dead documentation (remove the row or restore "
                f"the metric)"))
    return out
