"""lock-discipline: guarded-by annotations + static deadlock check.

Shared mutable state is annotated at its ``__init__`` assignment::

    self._pending = []          # guarded-by: _cv

and every ``self._pending`` read/write must then occur inside a
``with self._cv:`` block (or in an allowlisted ``__init__`` /
``__repr__`` / ``__del__`` context).  Methods that run with a lock
already held — "caller must hold the lock" helpers, or bodies that
acquire/release manually — declare it on (or directly above) the
``def`` line::

    def _evict(self, key):      # holds-lock: _lock

The pass also builds the cross-class lock-acquisition graph: an edge
``A.l1 -> B.l2`` means some code path acquires ``l2`` while holding
``l1``.  Receivers resolve through ``self.attr = ClassName(...)``
constructor assignments, string type annotations on attributes and
parameters (``service: "QueryService"``), same-class return
annotations (``-> "TrackStore"``), and ``for x in self.attr`` /
``x = self.attr`` aliasing.  Any cycle in the graph is a potential
deadlock and fails the pass; re-entrant self-edges are allowed for
``threading.RLock`` only.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import Finding, Project, SourceFile, lint_pass

_PASS = "lock-discipline"

_GUARD_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_]\w*)")
_HOLDS_RE = re.compile(r"#\s*holds-lock:\s*([A-Za-z_]\w*)")
_LOCK_CTORS = {"Lock", "RLock", "Condition", "Semaphore",
               "BoundedSemaphore"}
_ALLOWED_METHODS = {"__init__", "__repr__", "__del__"}
_IDENT_RE = re.compile(r"[A-Za-z_]\w*")

# (class name, lock attr) — one lock instance in the graph
Node = Tuple[str, str]


class _ClassInfo:
    def __init__(self, sf: SourceFile, node: ast.ClassDef):
        self.sf = sf
        self.node = node
        self.name = node.name
        self.locks: Dict[str, str] = {}          # attr -> kind
        self.guarded: Dict[str, str] = {}        # field -> lock attr
        self.guard_lines: Dict[str, int] = {}
        self.attr_types: Dict[str, str] = {}     # attr -> class name
        self.methods: Dict[str, ast.FunctionDef] = {
            n.name: n for n in node.body
            if isinstance(n, ast.FunctionDef)}


def _ann_classes(ann: ast.AST, known: Set[str]) -> Optional[str]:
    """First known class name mentioned in an annotation (handles
    Name, string constants, and container subscripts)."""
    for n in ast.walk(ann):
        if isinstance(n, ast.Name) and n.id in known:
            return n.id
        if isinstance(n, ast.Constant) and isinstance(n.value, str):
            for ident in _IDENT_RE.findall(n.value):
                if ident in known:
                    return ident
    return None


def _lock_ctor_kind(value: ast.AST) -> Optional[str]:
    if isinstance(value, ast.Call):
        fn = value.func
        name = fn.attr if isinstance(fn, ast.Attribute) else \
            fn.id if isinstance(fn, ast.Name) else None
        if name in _LOCK_CTORS:
            return name
    return None


def _self_attr(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) \
            and isinstance(node.value, ast.Name) \
            and node.value.id == "self":
        return node.attr
    return None


def _line_ann(sf: SourceFile, regex, line: int) -> List[str]:
    """Annotation matches trailing on ``line``, or on a comment-ONLY
    line directly above (a trailing comment on the previous statement
    never bleeds onto this one)."""
    out: List[str] = []
    if 2 <= line and sf.lines[line - 2].lstrip().startswith("#"):
        out.extend(regex.findall(sf.lines[line - 2]))
    if 1 <= line <= len(sf.lines):
        out.extend(regex.findall(sf.lines[line - 1]))
    return out


def _collect_classes(project: Project) -> Dict[str, _ClassInfo]:
    classes: Dict[str, _ClassInfo] = {}
    for sf in project.files:
        if sf.tree is None:
            continue
        for node in sf.tree.body:
            if isinstance(node, ast.ClassDef):
                classes[node.name] = _ClassInfo(sf, node)
    return classes


def _collect_fields(ci: _ClassInfo, known: Set[str],
                    out: List[Finding]) -> None:
    """Locks, guarded fields, and attribute types from assignments."""
    sf = ci.sf
    for meth in ci.methods.values():
        for stmt in ast.walk(meth):
            tgt = value = ann = None
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                tgt, value = stmt.targets[0], stmt.value
            elif isinstance(stmt, ast.AnnAssign):
                tgt, value, ann = stmt.target, stmt.value, \
                    stmt.annotation
            else:
                continue
            attr = _self_attr(tgt)
            if attr is None:
                continue
            kind = _lock_ctor_kind(value) if value is not None else None
            if kind is not None:
                ci.locks[attr] = kind
            if ann is not None:
                t = _ann_classes(ann, known)
                if t is not None:
                    ci.attr_types.setdefault(attr, t)
            if value is not None and isinstance(value, ast.Call) \
                    and isinstance(value.func, ast.Name) \
                    and value.func.id in known:
                ci.attr_types.setdefault(attr, value.func.id)
            for lock in _line_ann(sf, _GUARD_RE, stmt.lineno):
                ci.guarded[attr] = lock
                ci.guard_lines[attr] = stmt.lineno
    for field, lock in ci.guarded.items():
        if lock not in ci.locks:
            out.append(Finding(
                _PASS, sf.rel, ci.guard_lines[field],
                f"{ci.name}.{field} is guarded-by `{lock}`, but "
                f"{ci.name} creates no threading lock under that "
                f"name"))


class _MethodScan:
    """One method's walk: guarded-access checks, direct lock
    acquisitions, in-method edges, and resolved outgoing calls."""

    def __init__(self, classes: Dict[str, _ClassInfo], ci: _ClassInfo,
                 meth: ast.FunctionDef, findings: List[Finding],
                 edges: Dict[Tuple[Node, Node], Tuple[str, int]]):
        self.classes = classes
        self.ci = ci
        self.meth = meth
        self.findings = findings
        self.edges = edges
        self.acquires: Set[Node] = set()
        self.calls: List[Tuple[str, str, Tuple[str, ...]]] = []
        self.local_types: Dict[str, str] = {}
        self.lock_aliases: Dict[str, str] = {}   # local -> lock attr
        self.holds = tuple(h for h in _line_ann(ci.sf, _HOLDS_RE,
                                                meth.lineno)
                           if h in ci.locks)
        self._reported: Set[Tuple[str, int]] = set()
        known = set(classes)
        for arg in (meth.args.posonlyargs + meth.args.args
                    + meth.args.kwonlyargs):
            if arg.annotation is not None:
                t = _ann_classes(arg.annotation, known)
                if t is not None:
                    self.local_types[arg.arg] = t

    # -- type resolution --------------------------------------------------

    def _lock_of(self, node: ast.AST) -> Optional[str]:
        """The lock attr a receiver expression denotes: ``self._cv``
        directly, or a local aliased via ``cv = self._cv``."""
        attr = _self_attr(node)
        if attr is not None and attr in self.ci.locks:
            return attr
        if isinstance(node, ast.Name):
            return self.lock_aliases.get(node.id)
        return None

    def _type_of(self, node: ast.AST) -> Optional[str]:
        attr = _self_attr(node)
        if attr is not None:
            return self.ci.attr_types.get(attr)
        if isinstance(node, ast.Name):
            return self.local_types.get(node.id)
        return None

    def _call_return_type(self, call: ast.Call) -> Optional[str]:
        """Same-class call with a string return annotation."""
        attr = _self_attr(call.func)
        if attr is None:
            return None
        target = self.ci.methods.get(attr)
        if target is None or target.returns is None:
            return None
        return _ann_classes(target.returns, set(self.classes))

    def _note_assign(self, node: ast.Assign) -> None:
        if len(node.targets) != 1 \
                or not isinstance(node.targets[0], ast.Name):
            return
        name = node.targets[0].id
        lock = _self_attr(node.value)
        if lock is not None and lock in self.ci.locks:
            self.lock_aliases[name] = lock
            return
        t = self._type_of(node.value)
        if t is None and isinstance(node.value, ast.Call):
            fn = node.value.func
            if isinstance(fn, ast.Name) and fn.id in self.classes:
                t = fn.id
            else:
                t = self._call_return_type(node.value)
        if t is not None:
            self.local_types[name] = t

    def _note_for(self, node: ast.For) -> None:
        it = node.iter
        if isinstance(it, ast.Call) and isinstance(it.func, ast.Name) \
                and it.func.id in ("list", "sorted", "tuple") \
                and it.args:
            it = it.args[0]
        t = self._type_of(it)
        if t is not None and isinstance(node.target, ast.Name):
            self.local_types[node.target.id] = t

    # -- the walk ---------------------------------------------------------

    def run(self) -> None:
        held = set(self.holds)
        for stmt in self.meth.body:
            self._visit(stmt, held)

    def _edge(self, src: str, dst: Node, line: int) -> None:
        key = ((self.ci.name, src), dst)
        self.edges.setdefault(key, (self.ci.sf.rel, line))

    def _acquire(self, lock: str, held: Set[str], line: int) -> None:
        kind = self.ci.locks.get(lock)
        if lock in held and kind != "RLock":
            self._report(line, f"re-acquisition of non-reentrant "
                               f"{self.ci.name}.{lock} ({kind}) — "
                               f"self-deadlock")
        self.acquires.add((self.ci.name, lock))
        for h in held:
            if h != lock:
                self._edge(h, (self.ci.name, lock), line)

    def _report(self, line: int, msg: str) -> None:
        if (msg, line) in self._reported:
            return
        self._reported.add((msg, line))
        self.findings.append(Finding(_PASS, self.ci.sf.rel, line, msg))

    def _check_access(self, node: ast.Attribute,
                      held: Set[str]) -> None:
        attr = _self_attr(node)
        if attr is None:
            return
        lock = self.ci.guarded.get(attr)
        if lock is None or lock in held:
            return
        if self.meth.name in _ALLOWED_METHODS:
            return
        ctx = "write to" if isinstance(node.ctx,
                                       (ast.Store, ast.Del)) \
            else "read of"
        self._report(
            node.lineno,
            f"{ctx} {self.ci.name}.{attr} outside `with "
            f"self.{lock}` (guarded-by {lock}; hold the lock, or "
            f"annotate the method `# holds-lock: {lock}`)")

    def _handle_call(self, node: ast.Call, held: Set[str]) -> None:
        fn = node.func
        if isinstance(fn, ast.Attribute):
            # manual acquire()/release() on self.<lock> or an alias —
            # flow-sensitive: the held set mutates for the statements
            # that follow at this nesting level
            inner = self._lock_of(fn.value)
            if inner is not None:
                if fn.attr == "acquire" and inner not in held:
                    self._acquire(inner, held, node.lineno)
                    held.add(inner)
                elif fn.attr == "release":
                    held.discard(inner)
                return
            recv_t = self._type_of(fn.value)
            if recv_t is not None:
                self.calls.append((recv_t, fn.attr,
                                   (node.lineno, *sorted(held))))
            attr = _self_attr(fn)
            if attr is not None and attr in self.ci.methods:
                self.calls.append((self.ci.name, attr,
                                   (node.lineno, *sorted(held))))
                # the holds-lock contract: callers must already hold
                target = self.ci.methods[attr]
                for req in _line_ann(self.ci.sf, _HOLDS_RE,
                                     target.lineno):
                    if req in self.ci.locks and req not in held:
                        self._report(
                            node.lineno,
                            f"call to {self.ci.name}.{attr}() which "
                            f"requires `{req}` held (holds-lock) "
                            f"without holding it")

    def _visit(self, node: ast.AST, held: Set[str]) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # a nested def runs later (thread targets, callbacks):
            # locks held at the definition site are NOT held then
            for stmt in node.body:
                self._visit(stmt, set())
            return
        if isinstance(node, ast.With):
            acquired: List[str] = []
            for item in node.items:
                ce = item.context_expr
                self._visit(ce, held)
                lock = self._lock_of(ce)
                if lock is not None:
                    self._acquire(lock, held, node.lineno)
                    acquired.append(lock)
            inner = held | set(acquired)
            for stmt in node.body:
                self._visit(stmt, inner)
            return
        if isinstance(node, ast.Assign):
            self._note_assign(node)
        elif isinstance(node, ast.For):
            self._note_for(node)
        elif isinstance(node, ast.Call):
            self._handle_call(node, held)
        elif isinstance(node, ast.Attribute):
            self._check_access(node, held)
        for child in ast.iter_child_nodes(node):
            self._visit(child, held)


def _find_cycles(edges: Dict[Tuple[Node, Node], Tuple[str, int]],
                 lock_kinds: Dict[Node, str],
                 out: List[Finding]) -> None:
    adj: Dict[Node, List[Node]] = {}
    for (a, b) in edges:
        if a == b:
            if lock_kinds.get(a) != "RLock":
                rel, line = edges[(a, b)]
                out.append(Finding(
                    _PASS, rel, line,
                    f"{a[0]}.{a[1]} may be re-acquired on a path "
                    f"that already holds it (non-reentrant) — "
                    f"self-deadlock"))
            continue
        adj.setdefault(a, []).append(b)
    seen_cycles: Set[frozenset] = set()
    state: Dict[Node, int] = {}          # 1 = on stack, 2 = done

    def dfs(n: Node, path: List[Node]) -> None:
        state[n] = 1
        path.append(n)
        for m in adj.get(n, ()):
            if state.get(m) == 1:
                cyc = path[path.index(m):] + [m]
                key = frozenset(cyc)
                if key not in seen_cycles:
                    seen_cycles.add(key)
                    rel, line = edges[(n, m)]
                    pretty = " -> ".join(f"{c}.{l}" for c, l in cyc)
                    out.append(Finding(
                        _PASS, rel, line,
                        f"lock-order cycle (potential deadlock): "
                        f"{pretty}"))
            elif state.get(m) is None:
                dfs(m, path)
        path.pop()
        state[n] = 2

    for n in list(adj):
        if state.get(n) is None:
            dfs(n, [])


@lint_pass(_PASS,
           "guarded-by field accesses must hold their lock; the "
           "cross-class lock graph must be acyclic")
def run(project: Project) -> List[Finding]:
    out: List[Finding] = []
    classes = _collect_classes(project)
    known = set(classes)
    for ci in classes.values():
        _collect_fields(ci, known, out)
    # methods: access checks + direct acquisitions + resolved calls
    edges: Dict[Tuple[Node, Node], Tuple[str, int]] = {}
    scans: Dict[Tuple[str, str], _MethodScan] = {}
    for ci in classes.values():
        for name, meth in ci.methods.items():
            ms = _MethodScan(classes, ci, meth, out, edges)
            ms.run()
            scans[(ci.name, name)] = ms
    # transitive may-acquire per method, then call-site edges
    may: Dict[Tuple[str, str], Set[Node]] = {
        k: set(ms.acquires) for k, ms in scans.items()}
    changed = True
    while changed:
        changed = False
        for k, ms in scans.items():
            for (recv, meth2, _site) in ms.calls:
                extra = may.get((recv, meth2))
                if extra and not extra <= may[k]:
                    may[k] |= extra
                    changed = True
    for (cname, _mname), ms in scans.items():
        for (recv, meth2, site) in ms.calls:
            line, held = site[0], site[1:]
            for node in may.get((recv, meth2), ()):
                for h in held:
                    key = ((cname, h), node)
                    edges.setdefault(key, (ms.ci.sf.rel, line))
    lock_kinds: Dict[Node, str] = {}
    for ci in classes.values():
        for attr, kind in ci.locks.items():
            lock_kinds[(ci.name, attr)] = kind
    _find_cycles(edges, lock_kinds, out)
    return out
