"""bit-contract: the fastmath f32 twin discipline.

Files on the host==device bit-identity path (the tracker, the
Hungarian solvers, the track_step/assign kernels, and anything that
imports ``core.fastmath``) must not call the raw transcendental /
matmul entry points — ``jnp.exp``, ``jnp.tanh``, ``jax.nn.sigmoid``,
``jnp.matmul``/``jnp.dot`` or the ``@`` operator — because XLA and
numpy disagree in the last ulp; the ``core.fastmath`` ``np_*/jx_*``
twins pin one shared algorithm on both sides.

The pass also re-litigates the PR 7 scatter pitfall statically: in a
``.at[idx].set(..., mode="drop")`` / ``.add(..., mode="drop")``, jnp
WRAPS a negative index before the drop applies, so ``-1`` sentinels
silently write the last row.  Any drop-mode scatter whose index
expression (or the local it names) contains a negative constant is
flagged — misses must route to an out-of-bounds index (>= axis size).
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.core import Finding, Project, lint_pass

# files on the bit-identity path by construction
_SCOPE_FILES = ("core/tracker.py", "core/hungarian.py")
_SCOPE_DIRS = ("/kernels/track_step/", "/kernels/assign/")
# the twin implementations themselves ARE the contract
_EXEMPT = ("core/fastmath.py",)

_BANNED_ATTRS = {"exp", "tanh", "sigmoid", "expit", "matmul", "dot"}
_BANNED_ROOTS = {"np", "numpy", "jnp", "lax", "jax.nn", "jax.lax",
                 "jax.numpy", "jax.scipy.special"}
_TWIN = {"exp": "exp", "tanh": "tanh", "sigmoid": "sigmoid",
         "expit": "sigmoid", "matmul": "matmul", "dot": "matmul"}


def _dotted(node: ast.AST) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _imports_fastmath(tree: ast.Module) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod.endswith("fastmath") \
                    or any(a.name == "fastmath" for a in node.names):
                return True
        elif isinstance(node, ast.Import):
            if any(a.name.endswith("fastmath") for a in node.names):
                return True
    return False


def _in_scope(sf) -> bool:
    rel = sf.rel
    if any(rel.endswith(x) for x in _EXEMPT):
        return False
    if any(rel.endswith(x) for x in _SCOPE_FILES):
        return True
    if any(d in "/" + rel for d in _SCOPE_DIRS):
        return True
    return sf.tree is not None and _imports_fastmath(sf.tree)


def _has_negative_const(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.UnaryOp) and isinstance(n.op, ast.USub) \
                and isinstance(n.operand, ast.Constant) \
                and isinstance(n.operand.value, (int, float)):
            return True
        if isinstance(n, ast.Constant) \
                and isinstance(n.value, (int, float)) and n.value < 0:
            return True
    return False


def _drop_scatter(call: ast.Call) -> Optional[ast.AST]:
    """The index expression of ``x.at[idx].set(.., mode="drop")``
    (or .add/.max/.min), else None."""
    fn = call.func
    if not (isinstance(fn, ast.Attribute)
            and fn.attr in ("set", "add", "max", "min")):
        return None
    sub = fn.value
    if not (isinstance(sub, ast.Subscript)
            and isinstance(sub.value, ast.Attribute)
            and sub.value.attr == "at"):
        return None
    for kw in call.keywords:
        if kw.arg == "mode" and isinstance(kw.value, ast.Constant) \
                and kw.value.value == "drop":
            return sub.slice
    return None


class _FuncAssigns(ast.NodeVisitor):
    """name -> value expressions assigned to it inside one function."""

    def __init__(self):
        self.assigns: dict = {}

    def visit_Assign(self, node: ast.Assign):
        for tgt in node.targets:
            if isinstance(tgt, ast.Name):
                self.assigns.setdefault(tgt.id, []).append(node.value)
        self.generic_visit(node)


@lint_pass("bit-contract",
           "raw jnp/np transcendentals, @, and negative drop-mode "
           "scatter indices on host==device bit-identity paths")
def run(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for sf in project.files:
        if sf.tree is None or not _in_scope(sf):
            continue
        # enclosing-function assignment maps for the scatter check
        func_of: dict = {}
        for fn in ast.walk(sf.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fa = _FuncAssigns()
                for stmt in fn.body:
                    fa.visit(stmt)
                for sub in ast.walk(fn):
                    func_of.setdefault(id(sub), fa.assigns)
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.BinOp) \
                    and isinstance(node.op, ast.MatMult):
                out.append(Finding(
                    "bit-contract", sf.rel, node.lineno,
                    "raw `@` matmul on a bit-identity path — use "
                    "core.fastmath np_matmul/jx_matmul (fma "
                    "contraction and XLA dot reassociation break the "
                    "f32 bit match)"))
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            if isinstance(fn, ast.Attribute) \
                    and fn.attr in _BANNED_ATTRS:
                root = _dotted(fn.value)
                if root is not None and (
                        root in _BANNED_ROOTS
                        or root.split(".")[0] in ("np", "numpy", "jnp")):
                    twin = _TWIN[fn.attr]
                    out.append(Finding(
                        "bit-contract", sf.rel, node.lineno,
                        f"raw {root}.{fn.attr} on a bit-identity path "
                        f"— use core.fastmath np_{twin}/jx_{twin}"))
            idx = _drop_scatter(node)
            if idx is None:
                continue
            bad = _has_negative_const(idx)
            culprit = ""
            if not bad:
                assigns = func_of.get(id(node), {})
                for name_node in ast.walk(idx):
                    if isinstance(name_node, ast.Name):
                        for val in assigns.get(name_node.id, []):
                            if _has_negative_const(val):
                                bad, culprit = True, name_node.id
                                break
                    if bad:
                        break
            if bad:
                who = f" (via `{culprit}`)" if culprit else ""
                out.append(Finding(
                    "bit-contract", sf.rel, node.lineno,
                    f'drop-mode scatter index may be negative{who}: '
                    f'jnp wraps negative indices BEFORE mode="drop" '
                    f'applies, silently writing the last row — route '
                    f'misses to an index >= the axis size instead'))
    return out
