"""tracked-bytecode: no ``.pyc`` / ``__pycache__`` content in git.

Committed bytecode slipped in once (PR 3); the CI grep gate that kept
it out now lives here as a linter pass.  Uses ``git ls-files`` so
untracked local ``__pycache__`` noise never false-positives; if git is
unavailable (fixture trees in tests), falls back to a filesystem walk.
"""
from __future__ import annotations

import re
import subprocess
from typing import List

from repro.analysis.core import Finding, Project, lint_pass

_PASS = "tracked-bytecode"
_BAD_RE = re.compile(r"(^|/)__pycache__(/|$)|\.pyc$")


def _git_ls_files(root) -> List[str]:
    proc = subprocess.run(
        ["git", "ls-files"], cwd=root, capture_output=True,
        text=True, timeout=60)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr.strip() or "git ls-files failed")
    return proc.stdout.splitlines()


def _walk(root) -> List[str]:
    return [p.relative_to(root).as_posix()
            for p in root.rglob("*")
            if p.is_file() and ".git" not in p.parts]


@lint_pass(_PASS,
           "no tracked Python bytecode (__pycache__/, *.pyc)")
def run(project: Project) -> List[Finding]:
    try:
        files = _git_ls_files(project.root)
        how = "tracked"
    except (OSError, RuntimeError, subprocess.TimeoutExpired):
        files = _walk(project.root)
        how = "stray"
    return [Finding(_PASS, f, 1,
                    f"{how} Python bytecode — delete it and add "
                    f"__pycache__/ to .gitignore")
            for f in files if _BAD_RE.search(f)]
