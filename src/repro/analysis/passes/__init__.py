"""Pass modules — importing this package registers every pass."""
from repro.analysis.passes import (bit_contract, kernel_contract,
                                   lock_discipline, obs_naming,
                                   bytecode)  # noqa: F401

__all__ = ["bit_contract", "kernel_contract", "lock_discipline",
           "obs_naming", "bytecode"]
