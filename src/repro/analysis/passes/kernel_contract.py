"""kernel-contract: the three-file kernel package layout.

Every ``src/repro/kernels/<name>/`` package (anything shipping a
``kernel.py``) must:

- ship an ``ops.py`` (the public jit'd entry / backend dispatch) and a
  ``ref.py`` oracle twin;
- for each public ``<base>_pallas`` function in ``kernel.py``, define
  ``<base>_ref`` in ``ref.py`` whose required signature matches:
  required positional parameters agree in name and order, required
  keyword-only parameters agree as sets (the kernel-side ``interpret``
  flag excepted).  Defaulted parameters are tuning knobs and stay
  free;
- expose the interpret fallback: every ``*_pallas`` takes an
  ``interpret`` parameter;
- ship a ``smoke.py`` with a top-level ``smoke()`` —
  ``benchmarks/kernels.py --smoke`` auto-discovers and runs them, so a
  kernel cannot exist without riding the CI interpret-vs-ref gate.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis.core import Finding, Project, lint_pass

_PASS = "kernel-contract"


def _packages(project: Project) -> Dict[str, Dict[str, object]]:
    """package dir rel -> {filename stem -> SourceFile}."""
    pkgs: Dict[str, Dict[str, object]] = {}
    for sf in project.files:
        parts = sf.rel.split("/")
        if "kernels" not in parts[:-1]:
            continue
        k = parts.index("kernels")
        if len(parts) != k + 3:        # kernels/<name>/<file>.py only
            continue
        pkg = "/".join(parts[:k + 2])
        pkgs.setdefault(pkg, {})[parts[-1]] = sf
    return {pkg: files for pkg, files in pkgs.items()
            if "kernel.py" in files}


def _top_functions(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in tree.body
            if isinstance(n, ast.FunctionDef)}


def _required_sig(fn: ast.FunctionDef) -> Tuple[List[str], set]:
    """(required positional names in order, required kwonly name set)."""
    a = fn.args
    pos = [arg.arg for arg in a.posonlyargs + a.args]
    n_def = len(a.defaults)
    req_pos = pos[:len(pos) - n_def] if n_def else pos
    req_kw = {arg.arg for arg, d in zip(a.kwonlyargs, a.kw_defaults)
              if d is None}
    return req_pos, req_kw


def _param_names(fn: ast.FunctionDef) -> set:
    a = fn.args
    return {arg.arg for arg in a.posonlyargs + a.args + a.kwonlyargs}


@lint_pass(_PASS,
           "every kernels/<name>/ package ships ops.py + a ref.py twin "
           "with a matching signature, the interpret fallback, and a "
           "smoke.py entry for benchmarks/kernels.py --smoke")
def run(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for pkg, files in sorted(_packages(project).items()):
        ksf = files["kernel.py"]
        if ksf.tree is None:
            continue
        for missing in ("ops.py", "ref.py", "smoke.py"):
            if missing not in files:
                what = {
                    "ops.py": "the public dispatch entry (ops.py)",
                    "ref.py": "the oracle twin (ref.py)",
                    "smoke.py": "the CI gate entry (smoke.py with a "
                                "top-level smoke())",
                }[missing]
                out.append(Finding(_PASS, ksf.rel, 1,
                                   f"{pkg} is missing {what}"))
        rsf = files.get("ref.py")
        ref_fns = _top_functions(rsf.tree) \
            if rsf is not None and rsf.tree is not None else {}
        ssf = files.get("smoke.py")
        if ssf is not None and ssf.tree is not None \
                and "smoke" not in _top_functions(ssf.tree):
            out.append(Finding(_PASS, ssf.rel, 1,
                               "smoke.py must define a top-level "
                               "smoke() for the --smoke gate"))
        for name, fn in _top_functions(ksf.tree).items():
            if name.startswith("_") or not name.endswith("_pallas"):
                continue
            if "interpret" not in _param_names(fn):
                out.append(Finding(
                    _PASS, ksf.rel, fn.lineno,
                    f"{name} has no `interpret` parameter — every "
                    f"Pallas kernel must expose the interpret "
                    f"fallback"))
            ref_name = name[:-len("_pallas")] + "_ref"
            rfn: Optional[ast.FunctionDef] = ref_fns.get(ref_name)
            if rfn is None:
                if rsf is not None:
                    out.append(Finding(
                        _PASS, ksf.rel, fn.lineno,
                        f"{name} has no `{ref_name}` twin in "
                        f"{rsf.rel}"))
                continue
            kpos, kkw = _required_sig(fn)
            rpos, rkw = _required_sig(rfn)
            kkw.discard("interpret")
            if kpos != rpos:
                out.append(Finding(
                    _PASS, rsf.rel, rfn.lineno,
                    f"{ref_name}({', '.join(rpos)}) does not match "
                    f"{name}({', '.join(kpos)}) — required "
                    f"positional parameters must agree in name and "
                    f"order"))
            elif kkw != rkw:
                out.append(Finding(
                    _PASS, rsf.rel, rfn.lineno,
                    f"{ref_name} required keyword-only params "
                    f"{sorted(rkw)} != {name}'s {sorted(kkw)}"))
    return out
