"""CLI: ``python -m repro.analysis [--strict] [--json out.json]``.

Exit status: 0 when clean (always, without ``--strict``); 1 when
``--strict`` and any unsuppressed finding remains.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis import PASSES, Project, run_passes


def _default_root() -> Path:
    # <root>/src/repro/analysis/__main__.py
    return Path(__file__).resolve().parents[3]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Contract linter: static checks for the "
                    "bit-identity, kernel-twin, lock-discipline, "
                    "obs-naming, and tracked-bytecode invariants.")
    ap.add_argument("--root", default=None,
                    help="repo root (default: auto-detected)")
    ap.add_argument("--select", default=None,
                    help="comma-separated pass ids (default: all)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the machine-readable report here")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any unsuppressed finding")
    ap.add_argument("--list-passes", action="store_true",
                    help="list registered passes and exit")
    args = ap.parse_args(argv)

    if args.list_passes:
        for pid, info in PASSES.items():
            print(f"{pid:18s} {info.summary}")
        return 0

    root = Path(args.root) if args.root else _default_root()
    select = [s for s in args.select.split(",") if s] \
        if args.select else None
    project = Project(root)
    report = run_passes(project, select=select)

    if args.json:
        Path(args.json).write_text(report.to_json() + "\n",
                                   encoding="utf-8")
    for f in report.findings:
        print(f)
    active, supp = report.active, report.suppressed
    print(f"repro.analysis: {len(active)} finding(s), "
          f"{len(supp)} suppressed, {len(project.files)} files, "
          f"{len(PASSES) if select is None else len(select)} pass(es)")
    if args.strict and active:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
