"""Incremental secondary-index merge for one open clip.

The batch path builds a clip's index (count histograms, per-track
bboxes, ``ClipSummary``) from scratch at materialize time
(``repro.query.index.build_index``).  Mid-stream that would be an
O(rows) rebuild per appended segment; ``StreamIndexState`` instead
folds each watermark's NEW information into persistent structures in
O(changed rows + histogram width):

  * **histogram merge** — raw tracks are append-only (the stream path
    forbids refinement), so a track's existing rows never change and
    per-frame counts only grow.  For bucket ``b``: a track already
    qualified (``prev_len >= b``) contributes just its NEW rows; a
    track that CROSSED the bucket this segment (``prev_len < b <=
    new_len``) contributes all its rows — the old ones were never
    counted under ``b``.  Tracks that didn't change contribute nothing
    and are never touched.
  * **bbox / occupancy merge** — per-track envelopes and the per-bucket
    GRID occupancy masks grow monotonically by the same new/crossed
    split.
  * **summary** — rebuilt from the (incrementally maintained) hist +
    bboxes via ``index.summarize`` with the precomputed grid masks
    passed through, so its scalars are bit-identical to a full rebuild
    by construction; the differential tests additionally assert the
    hist/bbox arrays themselves equal ``build_index`` run from scratch
    at every watermark (tests/test_stream.py).

The merge also emits the watermark's ``TrackDelta`` list — per changed
track, the visible rows not yet delivered downstream.  Standing
queries consume exactly these deltas, which is what makes their
incremental evaluation scan each visible row once, ever
(``repro.stream.standing``).

Everything here derives deterministically from the visible tracks at a
watermark, so the state can be REBUILT from a stored open-clip NPZ
(``from_packed``) when an ingestor resumes in a fresh process.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.query.index import (MIN_LEN_BUCKETS, grids_from_rows,
                               occupancy_mask, summarize)
from repro.query.store import PackedTracks


@dataclass
class TrackDelta:
    """One visible track's not-yet-delivered rows at a watermark.

    ``rows`` are the track's rows beyond what earlier watermarks
    delivered — for a track newly visible (it just reached the
    tracker's ``min_hits``) that is ALL its rows, including the
    pre-watermark ones it accumulated while invisible."""
    track_id: int
    prev_len: int               # visible rows delivered before
    new_len: int                # visible rows now
    rows: np.ndarray            # (new_len - prev_len, 6)


@dataclass
class WatermarkDelta:
    """What one ``merge`` call changed.

    Besides the per-track view (``tracks``), the merge precomputes the
    delta ONCE as plain Python lists, shared by every standing query
    subscribed to the clip.  Deltas are a few dozen rows; at that size
    a pure-Python fold beats numpy outright (each vector op pays ~µs
    of dispatch for ~ns of work), so the standing-query hot path never
    touches numpy at all (``repro.stream.standing``).
    ``prev_watermark`` lets a consumer prove the delta follows exactly
    what it has already folded (sequential-delivery fast path)."""
    watermark: int
    prev_watermark: int = -1
    tracks: List[TrackDelta] = field(default_factory=list)
    rows_delivered: int = 0     # sum of len(td.rows)
    rows_list: Optional[list] = None    # R x [f, cx, cy, w, h, tid]
    tid_list: Optional[list] = None     # per-track ids
    len_list: Optional[list] = None     # per-track visible lengths now
    n_list: Optional[list] = None       # per-track delta row counts

    def finalize(self) -> "WatermarkDelta":
        """Build the shared plain-Python view from ``tracks``."""
        if self.tracks:
            self.rows_list = (
                np.concatenate([td.rows for td in self.tracks])
                if len(self.tracks) > 1 else self.tracks[0].rows
            ).tolist()
            self.tid_list = [td.track_id for td in self.tracks]
            self.len_list = [td.new_len for td in self.tracks]
            self.n_list = [len(td.rows) for td in self.tracks]
        else:
            self.rows_list = []
            self.tid_list = []
            self.len_list = []
            self.n_list = []
        return self


class StreamIndexState:
    """Incrementally maintained index for one open clip."""

    def __init__(self, n_frames: int):
        self.n_frames = int(n_frames)
        B = len(MIN_LEN_BUCKETS)
        # full-span histogram; snapshots slice [:, :watermark]
        self.hist = np.zeros((B, self.n_frames), np.int32)
        self.grid: List[int] = [0] * B
        self.delivered: Dict[int, int] = {}      # tid -> rows delivered
        self.bbox: Dict[int, np.ndarray] = {}    # tid -> (4,) envelope
        self._last_watermark = 0                 # delta sequencing

    # -- resume ---------------------------------------------------------------

    @classmethod
    def from_packed(cls, packed: PackedTracks,
                    n_frames: int) -> "StreamIndexState":
        """Rebuild the merge state from a stored open-clip NPZ (resume
        path).  The stored hist/track_bbox ARE the state; delivered
        lengths come from the offsets, and grid masks from the
        persisted summary (or the rows when the summary predates
        grids)."""
        st = cls(n_frames)
        packed.build_index_arrays()
        st.hist[:, :packed.hist.shape[1]] = packed.hist
        summary = packed.summary
        for i in range(packed.n_tracks):
            tr = packed.track(i)
            tid = int(tr[0, 5])
            st.delivered[tid] = len(tr)
            st.bbox[tid] = packed.track_bbox[i].astype(np.float32).copy()
        st._last_watermark = packed.watermark \
            if packed.watermark is not None else packed.n_frames
        if summary.grid is not None:
            st.grid = list(summary.grid)
        else:
            st.grid = list(grids_from_rows(packed.rows, packed.offsets))
        return st

    # -- the merge ------------------------------------------------------------

    def merge(self, tracks: Sequence[np.ndarray],
              watermark: int) -> WatermarkDelta:
        """Fold a watermark's visible tracks into the index.  ``tracks``
        is the tracker's current ``result()`` — visible tracks in
        packed order; only tracks whose visible length grew are
        touched."""
        delta = WatermarkDelta(int(watermark),
                               prev_watermark=self._last_watermark)
        self._last_watermark = int(watermark)
        for tr in tracks:
            if not len(tr):
                continue
            tid = int(tr[0, 5])
            prev = self.delivered.get(tid, 0)
            n = len(tr)
            if n == prev:
                continue                # untouched this segment
            if n < prev:                # appends only — see module doc
                raise RuntimeError(
                    f"track {tid} shrank ({prev} -> {n} rows); the "
                    f"stream index merge requires append-only tracks "
                    f"(is refinement enabled?)")
            new = tr[prev:]
            f_new = new[:, 0].astype(np.int64)
            f_all = tr[:, 0].astype(np.int64)
            for bi, b in enumerate(MIN_LEN_BUCKETS):
                if prev >= b:           # already qualified: new rows only
                    np.add.at(self.hist[bi], f_new, 1)
                    self.grid[bi] |= occupancy_mask(new[:, 1], new[:, 2])
                elif n >= b:            # crossed the bucket: all rows
                    np.add.at(self.hist[bi], f_all, 1)
                    self.grid[bi] |= occupancy_mask(tr[:, 1], tr[:, 2])
            bb = self.bbox.get(tid)
            if bb is None:
                bb = np.asarray([np.inf, np.inf, -np.inf, -np.inf],
                                np.float32)
                self.bbox[tid] = bb
            bb[0] = min(bb[0], float(new[:, 1].min()))
            bb[1] = min(bb[1], float(new[:, 2].min()))
            bb[2] = max(bb[2], float(new[:, 1].max()))
            bb[3] = max(bb[3], float(new[:, 2].max()))
            self.delivered[tid] = n
            delta.tracks.append(TrackDelta(tid, prev, n, new))
            delta.rows_delivered += len(new)
        return delta.finalize()

    # -- snapshots ------------------------------------------------------------

    def attach(self, packed: PackedTracks, watermark: int) -> None:
        """Attach the merged index to this watermark's ``PackedTracks``
        — the exact arrays ``build_index``/``summarize`` would produce
        from scratch (asserted differentially, tests/test_stream.py).
        The hist slice is a copy, so later merges never mutate a
        served ``PackedTracks``."""
        width = int(watermark)
        if len(packed.rows):
            width = max(width, int(packed.rows[:, 0].max()) + 1)
        packed.hist = self.hist[:, :width].copy()
        empty = np.asarray([np.inf, np.inf, -np.inf, -np.inf],
                           np.float32)
        if packed.n_tracks:
            boxes = []
            for i in range(packed.n_tracks):
                if packed.offsets[i] == packed.offsets[i + 1]:
                    boxes.append(empty.copy())      # zero-length stub
                    continue
                tid = int(packed.rows[packed.offsets[i], 5])
                boxes.append(self.bbox.get(tid, empty).copy())
            packed.track_bbox = np.stack(boxes).astype(np.float32)
        else:
            packed.track_bbox = np.empty((0, 4), np.float32)
        packed._summary = summarize(packed.rows, packed.offsets,
                                    packed.hist, packed.track_bbox,
                                    grid=tuple(self.grid))
