"""Live ingestion: segment-append pipelines over open clips.

The batch subsystems (``repro.core.executor`` → ``repro.query``)
assume a FINISHED clip; always-on feeds (traffic cameras) never
finish.  This package makes the store/index/service stack live —
cameras append frame segments to open clips and every query stays
answerable at each watermark in between:

  * ``checkpoint`` — ``TrackerCheckpoint``: the TRACK stage's
    cross-chunk state (active tracks, GRU hidden state, next-id
    counter, frame cursor) made serializable, so segment-append ingest
    is BIT-IDENTICAL to one-shot ingest and a new process resumes a
    stream exactly;
  * ``state``      — ``StreamIndexState``: per-watermark incremental
    merge of the clip's secondary index (count histograms, track
    bboxes, occupancy grids, ``ClipSummary``) in O(changed rows), with
    the per-track ``TrackDelta`` stream driving standing queries;
  * ``ingest``     — ``SegmentIngestor``: drives the executor's stage
    graph over each appended segment (decode prefetch, chunked
    dispatch, shared decode pool all apply) and lands monotone
    watermarks in the ``TrackStore``'s open-clip NPZ layout;
  * ``standing``   — ``StandingQuery``: a registered query re-evaluated
    incrementally per watermark — only never-seen rows scanned,
    summary-skippable deltas dropped — whose accumulated deltas
    reconstruct the ad-hoc answer bit-for-bit at every watermark.

Differential guarantees (tests/test_stream.py,
benchmarks/stream_bench.py): for every tested segment split, the
sealed clip's rows/hist/bboxes/summary equal a one-shot batch ingest
exactly; at every intermediate watermark the incrementally merged
index equals a full rebuild; standing-query accumulations equal the
ad-hoc plan and the naive ``ref.reference_query`` oracle.
"""
from repro.stream.checkpoint import TrackerCheckpoint  # noqa: F401
from repro.stream.ingest import (AppendReport,  # noqa: F401
                                 SegmentIngestor)
from repro.stream.standing import StandingDelta, StandingQuery  # noqa: F401
from repro.stream.state import (StreamIndexState,  # noqa: F401
                                TrackDelta, WatermarkDelta)
