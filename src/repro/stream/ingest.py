"""SegmentIngestor: append frame segments to open clips, keeping every
query answerable in between.

The live-ingestion pipeline, per appended segment:

  1. the segment's frame ids are the next slice of θ's gap progression
     (the frame CURSOR survives segment boundaries that fall between
     gap strides);
  2. the executor's stage graph runs over exactly those frames
     (``ClipExecutor.start(frame_ids=..., tracker=...)``), with the
     open clip's resumed tracker — DECODE prefetch, chunked PROXY /
     DETECT and the per-chunk crop-embedding batching all apply
     unchanged, and appends can share one ``DecodePool``.  A FLEET of
     cameras (one ingestor per feed, each appending from its own
     thread) passes one shared ``executor.BatchBroker`` through
     ``ExecutorOptions.batch_broker`` so every feed's per-segment
     windows — typically 1-2 per size class — coalesce into
     consolidated detector dispatches; per-feed tracks stay
     bit-identical (the broker invariant), only the batching changes;
  3. the tracker's visible tracks are packed at the new watermark and
     the clip's secondary index is INCREMENTALLY merged
     (``StreamIndexState``) — no full rebuild;
  4. the result lands in the ``TrackStore`` under the open-clip NPZ
     layout (monotone ``watermark``), atomically, so concurrent
     queries see either the previous prefix or the new one;
  5. a ``TrackerCheckpoint`` sidecar is persisted, so a NEW ingestor
     (same process or not) resumes the stream bit-identically;
  6. registered standing queries are notified with the watermark's
     track deltas (``QueryService.notify_append``).

Bit-identity contract: ingesting a clip as ANY sequence of segment
appends yields the same tracks, rows, histograms and summaries as a
one-shot batch ingest — chunking never changes per-frame results
(tests/test_executor.py) and TRACK state is carried exactly
(``TrackerCheckpoint``), so only the schedule differs.  Asserted across
segment sizes and θ in tests/test_stream.py.

Track refinement is a batch-finalization step (it rewrites already-
emitted rows, breaking the append-only property every incremental
structure here relies on), so θ with ``refine=True`` is rejected at
construction; live deployments serve raw tracks and refine offline.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core.executor import STAGES, ClipExecutor, ExecutorOptions
from repro.core.pipeline import RunResult
from repro.data.video_synth import Clip
from repro.obs.metrics import (REGISTRY, DriftMonitor, drift_enabled,
                               empty_stage_block)
from repro.obs.recorder import crash_dump
from repro.obs.trace import TRACER
from repro.query.store import ClipKey, PackedTracks, TrackStore, clip_key
from repro.stream.checkpoint import TrackerCheckpoint
from repro.stream.state import StreamIndexState, WatermarkDelta

CKPT_SUFFIX = "ckpt.npz"


@dataclass
class AppendReport:
    """What one ``append`` call did."""
    key: ClipKey
    watermark: int              # frames visible after this append
    appended: int               # frames this append advanced by
    frames_processed: int       # gap-progression frames actually run
    seconds: float = 0.0        # RunResult cost-model seconds
    wall_seconds: float = 0.0   # wall clock: executor + index + store
    store_seconds: float = 0.0  # of which index merge + NPZ landing
    standing_seconds: float = 0.0   # of which standing-query deltas
    rows_total: int = 0         # visible rows at the new watermark
    rows_delivered: int = 0     # rows newly delivered to the index
    sealed: bool = False
    delta: Optional[WatermarkDelta] = None
    # per-stage executor profile for this segment (RunResult pass-
    # throughs): stage -> {"wall": s, "process": s}, and device
    # dispatch counts per stage
    stage_seconds: Optional[Dict[str, Dict[str, float]]] = None
    dispatches: Optional[Dict[str, int]] = None
    # per-stream drift summary (obs.DriftMonitor.summary()); populated
    # only while obs.enable_drift() is on
    drift: Optional[dict] = None


@dataclass
class _OpenClip:
    """Mutable per-open-clip stream state."""
    clip: Clip
    tracker: object
    cursor: int                 # next gap-progression frame to decode
    watermark: int
    index: StreamIndexState
    seconds: float = 0.0        # accumulated RunResult seconds
    counters: List[int] = field(default_factory=lambda: [0, 0, 0, 0])
    drift: Optional[DriftMonitor] = None    # lazy, drift-enabled only


class SegmentIngestor:
    """Drives live segment appends for one ``TrackStore`` version.

    One ingestor owns the open clips it has ``open``-ed; appends are
    serialized under one lock (the executor already parallelizes
    inside a segment).  ``service`` (a ``QueryService``) is notified
    after every append so standing queries re-evaluate incrementally.
    """

    def __init__(self, store: TrackStore, service=None,
                 options: Optional[ExecutorOptions] = None,
                 checkpoint_every: int = 1):
        if store.bank is None:
            raise ValueError("live ingestion needs a store with a "
                             "model bank")
        if store.params.refine:
            raise ValueError(
                "live ingestion requires refine=False: refinement "
                "rewrites already-served rows, breaking the stream's "
                "append-only contract (refine offline after sealing)")
        self.store: TrackStore = store
        self.service: Optional["QueryService"] = service
        self.options = options or ExecutorOptions()
        self.checkpoint_every = max(0, int(checkpoint_every))
        self._executor = ClipExecutor(store.bank, store.params,
                                      self.options)
        self._open: Dict[ClipKey, _OpenClip] = {}  # guarded-by: _lock
        self._appends: Dict[ClipKey, int] = {}  # guarded-by: _lock
        self._lock = threading.RLock()

    # -- lifecycle ------------------------------------------------------------

    def open(self, clip: Clip) -> int:
        """Open a clip for appends; returns the current watermark (0
        for a fresh stream, the persisted watermark when resuming a
        stream another ingestor checkpointed).

        Resume tolerates a checkpoint BEHIND the stored watermark —
        the normal state when ``checkpoint_every > 1``, or after a
        crash between the store landing and the sidecar write: the
        stream ROLLS BACK to the checkpoint (index state rebuilt from
        the checkpointed tracker, store re-materialized at its
        watermark), and re-appending the rolled-back frames is
        deterministic, so the sealed clip is still bit-identical."""
        key = clip_key(clip)
        with self._lock:
            if key in self._open:
                return self._open[key].watermark
            ckpt_path = self.store.sidecar_path(clip, CKPT_SUFFIX)
            packed = self.store.get(clip)
            mid_stream = packed is not None \
                and packed.watermark is not None \
                and packed.watermark < clip.n_frames
            if mid_stream:
                try:
                    ckpt = TrackerCheckpoint.load(ckpt_path)
                except FileNotFoundError:
                    raise RuntimeError(
                        f"open clip {key} has watermark "
                        f"{packed.watermark} but no tracker checkpoint "
                        f"at {ckpt_path}; cannot resume")
                if ckpt.watermark > packed.watermark:
                    raise RuntimeError(
                        f"checkpoint watermark {ckpt.watermark} is "
                        f"AHEAD of stored watermark "
                        f"{packed.watermark} for {key}: the sidecar "
                        f"does not match this store")
                state = self._resume(clip, ckpt, packed)
            elif packed is not None:
                raise RuntimeError(
                    f"clip {key} is already fully materialized for "
                    f"this θ; nothing to append")
            else:
                state = _OpenClip(clip, self._fresh_tracker(), 0, 0,
                                  StreamIndexState(clip.n_frames))
            self._open[key] = state
            return state.watermark

    def _resume(self, clip: Clip, ckpt: TrackerCheckpoint,
                packed: PackedTracks) -> _OpenClip:
        """Rebuild live state from a checkpoint.  When the sidecar
        matches the stored watermark the persisted index IS the merge
        state (cheap path); otherwise roll back: replay the
        checkpointed tracker's visible tracks into a fresh index and
        re-materialize the store at the checkpoint's watermark."""
        tracker = ckpt.restore(self.store.bank, self.store.params,
                               self.options)
        if ckpt.watermark == packed.watermark:
            return _OpenClip(
                clip, tracker, ckpt.cursor, ckpt.watermark,
                StreamIndexState.from_packed(packed, clip.n_frames),
                seconds=packed.seconds,
                counters=list(packed.counters) or [0, 0, 0, 0])
        index = StreamIndexState(clip.n_frames)
        tracks = tracker.result()
        index.merge(tracks, ckpt.watermark)
        rolled = PackedTracks.pack(tracks, clip,
                                   n_frames=ckpt.watermark, build=False)
        rolled.seconds = ckpt.seconds
        rolled.counters = tuple(ckpt.counters)
        rolled.watermark = ckpt.watermark
        index.attach(rolled, ckpt.watermark)
        self.store.materialize_packed(clip, rolled)
        return _OpenClip(clip, tracker, ckpt.cursor, ckpt.watermark,
                         index, seconds=ckpt.seconds,
                         counters=list(ckpt.counters))

    def _fresh_tracker(self):
        """Same construction every other execution path does — built
        here so the instance can be carried across segment runs."""
        from repro.core.pipeline import make_tracker
        return make_tracker(self.store.bank, self.store.params,
                            device_assign=self.options.device_assign,
                            device_tracker=self.options.device_tracker)

    def watermark(self, clip: Clip) -> int:
        with self._lock:
            return self._open[clip_key(clip)].watermark

    # -- appends --------------------------------------------------------------

    def append(self, clip: Clip, n_frames: int) -> AppendReport:
        """Append the next ``n_frames`` frames of the camera feed to
        the open clip: run the stage graph over the segment, merge the
        index, land the watermark in the store, notify standing
        queries.  Clamped at the clip's end; the final append seals the
        clip (its NPZ becomes byte-for-byte the batch-ingest layout,
        minus the timing field)."""
        if TRACER.enabled:
            stream = f"{clip.profile.name}/{clip.split}{clip.clip_id}"
            with TRACER.span("stream.append", "stream",
                             stream=stream) as sp:
                rep = self._append(clip, n_frames)
                sp.args = {"watermark": rep.watermark,
                           "appended": rep.appended,
                           "rows_delivered": rep.rows_delivered,
                           "sealed": rep.sealed}
                return rep
        return self._append(clip, n_frames)

    def _append(self, clip: Clip, n_frames: int) -> AppendReport:
        try:
            return self._append_inner(clip, n_frames)
        except BaseException as exc:
            # black box (no-op unless a FlightRecorder is installed):
            # the dump's checkpoint pointer is the sidecar an operator
            # resumes the stream from after the crash
            crash_dump(
                "stream.append", exc,
                checkpoint=self.store.sidecar_path(clip, CKPT_SUFFIX),
                extra={"stream": f"{clip.profile.name}/{clip.split}"
                                 f"{clip.clip_id}",
                       "requested_frames": int(n_frames)})
            raise

    def _append_inner(self, clip: Clip, n_frames: int) -> AppendReport:
        t_wall = time.perf_counter()
        if int(n_frames) < 0:
            raise ValueError(f"cannot append {n_frames} frames: "
                             f"watermarks are monotone")
        key = clip_key(clip)
        with self._lock:
            st = self._open.get(key)
            if st is None:
                raise KeyError(f"clip {key} is not open (call open())")
            hi = min(st.watermark + int(n_frames), clip.n_frames)
            ids = list(range(st.cursor, hi, self.store.params.gap))
            result = self._run_segment(st, ids)
            st.cursor += self.store.params.gap * len(ids)
            appended = hi - st.watermark
            st.watermark = hi
            st.seconds += result.seconds
            st.counters[0] += result.frames_processed
            st.counters[1] += result.detector_windows
            st.counters[2] += result.full_frames
            st.counters[3] += result.skipped_frames
            sealed = st.watermark >= clip.n_frames

            t_store = time.perf_counter()
            delta = st.index.merge(result.tracks, st.watermark)
            packed = PackedTracks.pack(
                result.tracks, clip, n_frames=st.watermark, build=False)
            packed.seconds = st.seconds
            packed.counters = tuple(st.counters)
            packed.watermark = None if sealed else st.watermark
            st.index.attach(packed, st.watermark)
            self._appends[key] = self._appends.get(key, 0) + 1
            ckpt_due = bool(
                self.checkpoint_every
                and self._appends[key] % self.checkpoint_every == 0)
            # index.json flushes ride the checkpoint cadence: the NPZ
            # (always current) + sidecar are the resume state, and the
            # in-memory entry serves in-process queries, so re-writing
            # every dataset summary per append would pay O(all clips)
            # for one watermark field
            self.store.materialize_packed(clip, packed,
                                          flush=sealed or ckpt_due)
            if sealed:
                self._remove_checkpoint(clip)
                self._open.pop(key, None)
                self._appends.pop(key, None)
            elif ckpt_due:
                self.checkpoint(clip)
            store_seconds = time.perf_counter() - t_store

            report = AppendReport(
                key, st.watermark, appended, len(ids),
                seconds=result.seconds, store_seconds=store_seconds,
                rows_total=len(packed.rows),
                rows_delivered=delta.rows_delivered,
                sealed=sealed, delta=delta,
                stage_seconds=result.stage_seconds,
                dispatches=result.dispatches)
            if self.service is not None:
                t_sq = time.perf_counter()
                self.service.notify_append(clip, packed, delta)
                report.standing_seconds = time.perf_counter() - t_sq
            report.wall_seconds = time.perf_counter() - t_wall
            if drift_enabled():
                if st.drift is None:
                    st.drift = DriftMonitor()
                    # the summary also rides REGISTRY.snapshot() (and
                    # with it /metrics scrapers' /snapshot view) as a
                    # zero-copy provider: the snapshot call reads the
                    # live monitor, appends pay nothing extra
                    stream = (f"{clip.profile.name}/{clip.split}"
                              f"{clip.clip_id}")
                    REGISTRY.provider(f"stream.drift[{stream}]",
                                      st.drift.summary)
                st.drift.observe(st.watermark,
                                 proxy_fracs=result.proxy_fracs,
                                 track_count=len(result.tracks))
                report.drift = st.drift.summary()
            self._publish(clip, report)
            return report

    def _publish(self, clip: Clip, report: AppendReport) -> None:
        """Fold one append into the registry: live-path latency
        histograms plus the per-clip watermark gauges the fleet
        dashboard reads (lag = how long this watermark took to land in
        the store from the moment append() was called)."""
        REGISTRY.counter("stream.appends").inc()
        REGISTRY.histogram("stream.append.wall_seconds").observe(
            report.wall_seconds)
        REGISTRY.histogram("stream.append.store_seconds").observe(
            report.store_seconds)
        if self.service is not None:
            REGISTRY.histogram("stream.append.standing_seconds").observe(
                report.standing_seconds)
        stream = f"{clip.profile.name}/{clip.split}{clip.clip_id}"
        REGISTRY.gauge(f"stream.watermark[{stream}]").set(
            report.watermark)
        REGISTRY.gauge(f"stream.watermark_lag_seconds[{stream}]").set(
            report.wall_seconds)

    def _run_segment(self, st: _OpenClip,
                     ids: Sequence[int]) -> RunResult:
        if not ids:
            # segment smaller than the gap stride: nothing to run, but
            # the watermark still advances (and queries still answer);
            # the zero stage block keeps AppendReport.stage_seconds
            # uniformly shaped across appends
            return RunResult(st.tracker.result(), 0.0, 0, 0, 0, 0,
                             stage_seconds=empty_stage_block(STAGES),
                             dispatches={"proxy": 0, "detect": 0,
                                         "track": 0})
        run = self._executor.start(st.clip, frame_ids=ids,
                                   tracker=st.tracker)
        return self._executor.finish(run)

    # -- checkpoints ----------------------------------------------------------

    def checkpoint(self, clip: Clip) -> str:
        """Persist the open clip's tracker checkpoint sidecar; returns
        its path.  With the store's NPZ (always current), this is the
        complete resume state."""
        key = clip_key(clip)
        with self._lock:
            st = self._open[key]
            path = self.store.sidecar_path(clip, CKPT_SUFFIX)
            TrackerCheckpoint.capture(
                st.tracker, st.cursor, st.watermark,
                counters=st.counters, seconds=st.seconds).save(path)
            return path

    def _remove_checkpoint(self, clip: Clip) -> None:
        import os
        try:
            os.remove(self.store.sidecar_path(clip, CKPT_SUFFIX))
        except FileNotFoundError:
            pass

    def seal(self, clip: Clip) -> PackedTracks:
        """Append whatever remains and return the final packed clip —
        bit-identical (tracks, rows, hist, bboxes, summary, counters)
        to a one-shot batch ingest of the same clip."""
        key = clip_key(clip)
        with self._lock:
            if key in self._open:
                self.append(clip,
                            clip.n_frames - self._open[key].watermark)
            packed = self.store.get(clip)
            if packed is None:
                raise KeyError(f"clip {key} has no materialized data")
            return packed
