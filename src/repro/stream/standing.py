"""Standing queries: register once, receive exact deltas per watermark.

A ``StandingQuery`` wraps a regular ``repro.query.ops.Query`` and is
re-evaluated INCREMENTALLY every time a segment append advances an open
clip's watermark.  The evaluation never rescans materialized rows:

  * the segment ingestor's index merge already computes, per watermark,
    exactly the visible rows never delivered before (``TrackDelta``);
    the standing evaluation scans ONLY those rows — each visible row of
    the stream is examined once, ever (counter-asserted by
    ``rows_scanned`` in tests and benchmarks/stream_bench.py);
  * rows of tracks still below the plan's ``min_len`` are pre-filtered
    (region/time) and parked per track as frame lists; when the track
    crosses the threshold the parked FRAMES are folded in — the raw
    rows are not touched again;
  * per-frame surviving counts are maintained as a running array, so a
    watermark's newly matching frames fall out of the same pass that
    updates the counts;
  * a clip whose post-append summary proves every visible row region-
    or time-disjoint (``CompiledPlan.row_disjoint`` — bbox, occupancy
    grid, frame span) drops its delta outright: those predicates are
    static, so rows failing them now fail them forever.

The fold is PURE PYTHON over the merge's shared per-delta lists
(``WatermarkDelta.finalize``): a delta is a few dozen rows, where each
numpy call costs more in dispatch than the whole loop costs in
arithmetic — the python fold is ~5x faster at delta scale and keeps
the per-watermark latency independent of how many clips (or how much
history) the store holds, which is what buys the >= 10x gap over
re-running the ad-hoc scan per watermark (BENCH_stream.json).

Why deltas are EXACT: with refinement banned on the stream path, raw
tracks are append-only, so a frame's surviving count under any fixed
(region, time, min_len) predicate is monotone non-decreasing in the
watermark — a frame that matches ``count >= k`` stays matched, and the
accumulated emissions at any watermark reconstruct bit-for-bit the
ad-hoc answer over the store at that watermark (differentially asserted
against ``plan.CompiledPlan.run`` and ``ref.reference_query`` at every
watermark, tests/test_stream.py).

Not supported (rejected at registration): ``Limit`` (its early-exit
answer is not monotone — a late-arriving earlier frame would displace
an already-emitted one) and class filters (a growing track can change
pattern class, so class membership is not monotone either).  Both still
work ad-hoc over open clips.
"""
from __future__ import annotations

import itertools
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Set, Tuple

from repro.data.video_synth import Clip
from repro.query.ops import Query
from repro.query.plan import CompiledPlan, QueryResult, compile_query
from repro.query.store import ClipKey, PackedTracks, clip_key
from repro.stream.state import WatermarkDelta

_ids = itertools.count()


@dataclass
class StandingDelta:
    """What one watermark advance changed for one standing query."""
    query_id: int
    key: ClipKey
    watermark: int
    new_frames: List[Tuple[int, int]] = field(default_factory=list)
    count_delta: int = 0
    duration_delta: float = 0.0
    tracks_delta: int = 0       # "tracks" aggregate only
    rows_scanned: int = 0       # raw delta rows examined (the counter)
    skipped: bool = False       # summary proved the delta irrelevant

    @property
    def empty(self) -> bool:
        return not self.new_frames and not self.tracks_delta


@dataclass
class _ClipState:
    """Per-(standing query, clip) incremental evaluation state.
    Plain-Python containers throughout — see the module docstring."""
    counts: List[int]                   # per-frame surviving counts
    emitted: Set[int]                   # frames already matched
    pending: Dict[int, List[int]] = field(default_factory=dict)
    qualified: Set[int] = field(default_factory=set)  # past min_len
    contributing: Set[int] = field(default_factory=set)
    delivered: Dict[int, int] = field(default_factory=dict)
    synced: int = 0     # watermark folded so far (fast-path sequencing)


class StandingQuery:
    """One registered query over a fixed clip list.  Thread-safe: the
    ingestor's notification and a reader's ``result()`` may race."""

    def __init__(self, q: Query, clips: Sequence[Clip],
                 name: str = "", history: int = 1024):
        plan = compile_query(q)
        if plan.limit is not None:
            raise ValueError(
                "standing queries do not compose with Limit: the "
                "limit scan's early-exit answer is not monotone under "
                "appends (run it ad-hoc instead)")
        if plan.classes is not None:
            raise ValueError(
                "standing queries do not support class filters: a "
                "growing track can change pattern class mid-stream")
        self.id = next(_ids)
        self.name = name or f"standing-{self.id}"
        self.q = q
        self.plan: CompiledPlan = plan
        self.clips = list(clips)
        self._pos: Dict[ClipKey, int] = {
            clip_key(c): i for i, c in enumerate(self.clips)}
        self._fps: Dict[ClipKey, int] = {
            clip_key(c): c.profile.fps for c in self.clips}
        self._frames: Dict[ClipKey, int] = {
            clip_key(c): c.n_frames for c in self.clips}
        self._scoped_out = {
            k for k, c in zip(self._pos, self.clips)
            if plan.datasets is not None
            and c.profile.name not in plan.datasets}
        self._state: Dict[ClipKey, _ClipState] = {}  # guarded-by: _lock
        self._lock = threading.Lock()
        # lifetime counters: every delivered row is exactly one of
        # scanned / summary-skipped
        self.rows_scanned = 0           # guarded-by: _lock
        self.rows_skipped = 0           # guarded-by: _lock
        self.clips_skipped = 0          # guarded-by: _lock
        from repro.obs.metrics import REGISTRY
        self._m_scanned = REGISTRY.counter("standing.rows_scanned")
        self._m_skipped = REGISTRY.counter("standing.rows_skipped")
        self._m_clips_skipped = REGISTRY.counter(
            "standing.clips_skipped")
        # recent per-watermark deltas — BOUNDED: the accumulated answer
        # lives in the per-clip counts/emitted state, so an always-on
        # stream must not grow memory per append (consumers wanting
        # every delta read them as they arrive from on_append)
        self.deltas: Deque[StandingDelta] = deque(maxlen=history)  # guarded-by: _lock

    # -- registration-time catch-up -------------------------------------------

    def bootstrap(self, service) -> List[StandingDelta]:
        """Catch up on clips already (partially) materialized when the
        query registers mid-stream: each clip's current packed rows are
        fed through the same delta path as one initial batch."""
        out = []
        for clip in self.clips:
            key = clip_key(clip)
            if key in self._scoped_out:
                continue
            try:
                store = service.store_for(clip)
            except KeyError:
                continue
            packed = store.get(clip)
            if packed is None:
                continue
            delta = WatermarkDelta(
                packed.watermark if packed.watermark is not None
                else packed.n_frames)
            from repro.stream.state import TrackDelta
            for i in range(packed.n_tracks):
                tr = packed.track(i)
                if not len(tr):
                    continue
                delta.tracks.append(
                    TrackDelta(int(tr[0, 5]), 0, len(tr), tr))
                delta.rows_delivered += len(tr)
            out.append(self.on_append(clip, packed, delta))
        return out

    # -- the incremental evaluation -------------------------------------------

    def on_append(self, clip: Clip, packed: PackedTracks,
                  delta: WatermarkDelta) -> Optional[StandingDelta]:
        """Fold one watermark's track deltas in; returns this query's
        delta (None when the clip is not subscribed)."""
        key = clip_key(clip)
        pos = self._pos.get(key)
        if pos is None or key in self._scoped_out:
            return None
        with self._lock:
            sd = StandingDelta(self.id, key, delta.watermark)
            if self.plan.row_disjoint(packed.summary):
                # every visible row fails a STATIC row predicate —
                # including this delta's rows (they are visible in this
                # summary), so dropping them is permanent-safe
                sd.skipped = True
                self.clips_skipped += 1
                self.rows_skipped += delta.rows_delivered
                self._m_clips_skipped.inc()
                self._m_skipped.inc(delta.rows_delivered)
                self.deltas.append(sd)
                return sd
            st = self._state.get(key)
            if st is None:
                st = _ClipState([0] * self._frames[key], set())
                self._state[key] = st
            self._fold(st, delta, sd, pos)
            self.rows_scanned += sd.rows_scanned
            self._m_scanned.inc(sd.rows_scanned)
            self.deltas.append(sd)
            return sd

    def _fold(self, st: _ClipState, delta: WatermarkDelta,
              sd: StandingDelta, pos: int) -> None:
        """Fold the delta's rows into the running counts — one pure-
        Python pass (region/time filter, count update, match emission
        fused).  The sequential fast path consumes the merge's SHARED
        lists directly; the slow path (a registration racing an append)
        re-slices per track against ``delivered``."""
        if delta.rows_list is not None \
                and st.synced == delta.prev_watermark:
            rows = delta.rows_list
            tids, lens, ns = delta.tid_list, delta.len_list, delta.n_list
            if tids:
                st.delivered.update(zip(tids, lens))
        else:                           # overlap-safe slow path
            rows, tids, lens, ns = [], [], [], []
            for td in delta.tracks:
                already = st.delivered.get(td.track_id, 0)
                if td.new_len <= already:
                    continue            # bootstrap overlap guard
                seg = td.rows[max(0, already - td.prev_len):].tolist()
                st.delivered[td.track_id] = td.new_len
                rows.extend(seg)
                tids.append(td.track_id)
                lens.append(td.new_len)
                ns.append(len(seg))
        st.synced = delta.watermark
        if not rows:
            return
        sd.rows_scanned = len(rows)
        plan = self.plan
        min_len, min_count = plan.min_len, plan.min_count
        region, trange = plan.region, plan.time_range
        if region is not None:
            x0, y0, x1, y1 = region.x0, region.y0, region.x1, region.y1
        if trange is not None:
            t0, t1 = trange.start, trange.end
        track_agg = plan.aggregate == "tracks"
        counts, emitted = st.counts, st.emitted
        qualified, pending = st.qualified, st.pending
        contributing = st.contributing
        hits: List[int] = []
        # the unfiltered count/frames/duration query over mature tracks
        # is the steady-state workload: one tight loop, no per-row
        # branches (each delta row is a count bump + match test)
        plain = region is None and trange is None and not track_agg
        i = 0
        for k, tid in enumerate(tids):
            n = ns[k]
            end = i + n
            q = lens[k] >= min_len
            if q and tid not in qualified:
                qualified.add(tid)
                parked = pending.pop(tid, None)
                if parked:              # flushed frames count — and the
                    if track_agg and tid not in contributing:
                        contributing.add(tid)
                        sd.tracks_delta += 1
                    for f in parked:
                        c = counts[f] + 1
                        counts[f] = c
                        if c >= min_count and f not in emitted:
                            emitted.add(f)
                            hits.append(f)
            if plain and q:
                for row in rows[i:end]:
                    f = int(row[0])
                    c = counts[f] + 1
                    counts[f] = c
                    if c >= min_count and f not in emitted:
                        emitted.add(f)
                        hits.append(f)
                i = end
                continue
            for row in rows[i:end]:
                if region is not None and not (
                        x0 <= row[1] <= x1 and y0 <= row[2] <= y1):
                    continue
                f = int(row[0])
                if trange is not None and (
                        f < t0 or (t1 is not None and f >= t1)):
                    continue
                if not q:               # young track: park the frame
                    pending.setdefault(tid, []).append(f)
                    continue
                if track_agg and tid not in contributing:
                    contributing.add(tid)
                    sd.tracks_delta += 1
                c = counts[f] + 1
                counts[f] = c
                if c >= min_count and f not in emitted:
                    emitted.add(f)
                    hits.append(f)
            i = end
        if hits:
            hits.sort()
            sd.new_frames = [(pos, f) for f in hits]
            sd.count_delta = len(hits)
            sd.duration_delta = len(hits) / max(self._fps[sd.key], 1)

    # -- accumulated answer ---------------------------------------------------

    def result(self) -> QueryResult:
        """The accumulated answer — shaped exactly like
        ``CompiledPlan.run`` over the same clips at the current
        watermarks (differentially asserted)."""
        res = QueryResult(n_clips=len(self.clips))
        with self._lock:
            n_match = 0
            seconds = 0.0
            total_tracks = 0
            frames: List[Tuple[int, int]] = []
            for clip in self.clips:
                key = clip_key(clip)
                st = self._state.get(key)
                if st is None:
                    continue
                hits = sorted(st.emitted)
                n_match += len(hits)
                seconds += len(hits) / max(self._fps[key], 1)
                total_tracks += len(st.contributing)
                frames.extend((self._pos[key], f) for f in hits)
        if self.plan.aggregate == "tracks":
            res.aggregates["tracks"] = total_tracks
        else:
            res.aggregates["count"] = n_match
            res.aggregates["duration_seconds"] = seconds
        if self.plan.aggregate == "frames":
            res.frames = frames
        return res
