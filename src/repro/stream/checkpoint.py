"""TrackerCheckpoint: the TRACK stage's cross-chunk state, made
serializable and resumable.

TRACK is the only pipeline stage with cross-chunk state — everything
else (decode, proxy, detect) is chunk-local and bit-identical for any
chunking (tests/test_executor.py).  That means a clip can be ingested
as N appended segments and produce EXACTLY the tracks of a one-shot
run, provided the tracker's state survives the segment boundary:

  * the active track set — per track: id, frames, boxes, miss count,
    and (recurrent tracker) the GRU hidden state;
  * the finished track list, in finish order (``result()`` emits
    finished + active, so ORDER is part of the bit-identity contract);
  * the next-id counter and, for the recurrent tracker, the last
    stepped frame (the ``t_elapsed`` anchor of the next step);
  * the frame cursor — the next frame index of θ's gap progression not
    yet decoded, so segment boundaries that fall between gap strides
    resume at the right frame.

``capture``/``restore`` snapshot a live ``SortTracker`` /
``RecurrentTracker``; ``to_arrays``/``from_arrays`` flatten the
checkpoint into a dict of numpy arrays for NPZ persistence
(``SegmentIngestor`` writes one sidecar per open clip, so an ingestor
in a NEW process resumes mid-stream bit-identically —
tests/test_stream.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.sort import SortTracker, Track
from repro.core.tracker import RecurrentTracker, _ActiveTrack

_KINDS = ("sort", "recurrent")


@dataclass
class TrackState:
    """One track's serializable state (both tracker flavors)."""
    track_id: int
    frames: List[int]
    boxes: List[np.ndarray]             # (4,) float32 each
    misses: int
    h: Optional[np.ndarray] = None      # GRU hidden (recurrent only)


@dataclass
class TrackerCheckpoint:
    """Everything needed to resume TRACK mid-stream.  ``counters`` and
    ``seconds`` carry the stream's accumulated ``RunResult``
    bookkeeping, so a resume that ROLLS BACK to the checkpoint (the
    store may be an append or two ahead when ``checkpoint_every > 1``
    or a crash hit between materialize and checkpoint) still seals
    with counters bit-identical to a one-shot ingest."""
    kind: str                           # "sort" | "recurrent"
    cursor: int                         # next gap-progression frame
    watermark: int                      # frames appended so far
    next_id: int
    last_frame: Optional[int]           # recurrent t_elapsed anchor
    finished: List[TrackState] = field(default_factory=list)
    active: List[TrackState] = field(default_factory=list)
    counters: Tuple[int, ...] = (0, 0, 0, 0)
    seconds: float = 0.0

    # -- live tracker <-> checkpoint ------------------------------------------

    @classmethod
    def capture(cls, tracker, cursor: int, watermark: int,
                counters: Sequence[int] = (0, 0, 0, 0),
                seconds: float = 0.0) -> "TrackerCheckpoint":
        if isinstance(tracker, RecurrentTracker):
            kind, last = "recurrent", tracker._last_frame
        elif isinstance(tracker, SortTracker):
            kind, last = "sort", None
        else:
            raise TypeError(f"cannot checkpoint {type(tracker).__name__}")

        def snap(t) -> TrackState:
            return TrackState(
                int(t.track_id), [int(f) for f in t.frames],
                [np.asarray(b, np.float32).copy() for b in t.boxes],
                int(t.misses),
                h=(np.asarray(t.h, np.float32).copy()
                   if kind == "recurrent" else None))

        return cls(kind, int(cursor), int(watermark),
                   int(tracker._next_id),
                   None if last is None else int(last),
                   [snap(t) for t in tracker.finished],
                   [snap(t) for t in tracker.active],
                   tuple(int(c) for c in counters), float(seconds))

    def restore(self, bank, params, options=None):
        """A live tracker continuing exactly from this state (the same
        construction path ``executor._RunContext`` uses).  ``options``
        (an ``ExecutorOptions`` or anything with ``device_assign`` /
        ``device_tracker`` attributes) picks the execution flavor — a
        scheduling choice, so a stream checkpointed under one flavor
        resumes bit-identically under any other."""
        if self.kind == "recurrent":
            if bank.tracker_params is None:
                raise ValueError("recurrent checkpoint needs a bank "
                                 "with tracker_params")
            if getattr(options, "device_tracker", False):
                from repro.core.tracker import DeviceTracker
                tracker = DeviceTracker(bank.cfg.tracker,
                                        bank.tracker_params)
            else:
                assign = "device" \
                    if getattr(options, "device_assign", False) \
                    else "host"
                tracker = RecurrentTracker(bank.cfg.tracker,
                                           bank.tracker_params,
                                           assign=assign)
            tracker._last_frame = self.last_frame

            def wake(s: TrackState):
                return _ActiveTrack(s.track_id,
                                    np.asarray(s.h, np.float32),
                                    list(s.frames),
                                    [b.copy() for b in s.boxes],
                                    s.misses)
        else:
            tracker = SortTracker()

            def wake(s: TrackState):
                return Track(s.track_id, list(s.frames),
                             [b.copy() for b in s.boxes], s.misses)
        tracker.finished = [wake(s) for s in self.finished]
        tracker.active = [wake(s) for s in self.active]
        tracker._next_id = self.next_id
        return tracker

    # -- NPZ flattening -------------------------------------------------------

    def to_arrays(self) -> Dict[str, np.ndarray]:
        """Flatten to fixed-name numpy arrays (``np.savez``-able).
        Tracks serialize finished-first, then active, with row data
        packed ``(N, 5)`` ``[frame, cx, cy, w, h]`` and per-track
        ``(id, misses, n_rows)`` meta."""
        tracks = self.finished + self.active
        meta = np.asarray(
            [_KINDS.index(self.kind), self.cursor, self.watermark,
             self.next_id,
             -1 if self.last_frame is None else self.last_frame,
             len(self.finished), len(self.active),
             *self.counters], np.int64)
        tmeta = np.asarray([(t.track_id, t.misses, len(t.frames))
                            for t in tracks], np.int64).reshape(-1, 3)
        rows = np.zeros((int(tmeta[:, 2].sum()) if len(tracks) else 0, 5),
                        np.float32)
        k = 0
        for t in tracks:
            n = len(t.frames)
            rows[k:k + n, 0] = t.frames
            if n:
                rows[k:k + n, 1:5] = np.stack(t.boxes)
            k += n
        out = {"meta": meta, "tmeta": tmeta, "rows": rows,
               "seconds": np.asarray([self.seconds], np.float64)}
        if self.kind == "recurrent":
            out["h"] = np.stack([t.h for t in tracks]) if tracks \
                else np.zeros((0, 0), np.float32)
        return out

    @classmethod
    def from_arrays(cls, arrays: Dict[str, np.ndarray]
                    ) -> "TrackerCheckpoint":
        meta = arrays["meta"]
        kind = _KINDS[int(meta[0])]
        n_finished = int(meta[5])
        tracks: List[TrackState] = []
        k = 0
        for i, (tid, misses, n) in enumerate(arrays["tmeta"]):
            rows = arrays["rows"][k:k + int(n)]
            k += int(n)
            tracks.append(TrackState(
                int(tid), [int(f) for f in rows[:, 0]],
                [rows[j, 1:5].astype(np.float32).copy()
                 for j in range(len(rows))],
                int(misses),
                h=(arrays["h"][i].astype(np.float32).copy()
                   if kind == "recurrent" else None)))
        counters = tuple(int(v) for v in meta[7:11]) \
            if len(meta) >= 11 else (0, 0, 0, 0)
        seconds = float(arrays["seconds"][0]) \
            if "seconds" in arrays else 0.0
        return cls(kind, int(meta[1]), int(meta[2]), int(meta[3]),
                   None if int(meta[4]) < 0 else int(meta[4]),
                   tracks[:n_finished], tracks[n_finished:],
                   counters, seconds)

    def save(self, path: str) -> None:
        tmp = path + ".tmp.npz"
        np.savez(tmp, **self.to_arrays())
        import os
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "TrackerCheckpoint":
        with np.load(path) as z:
            return cls.from_arrays({k: z[k] for k in z.files})
