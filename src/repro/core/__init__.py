"""The paper's primary contribution: the MultiScope pre-processing system.

Public API:
    tuner.setup / tuner.tune     — Figure 1 workflow (train + θ_best +
                                   greedy joint tuning)
    pipeline.run_clip            — execute one configuration θ (streaming
                                   stage-graph executor by default;
                                   engine="chunked" for the sequential
                                   scheduler, engine="frame" for the
                                   per-frame reference path)
    executor.run_clips           — multi-clip sweep with cross-clip
                                   decode prefetch and device round-robin
    engine.run_clip_chunked      — the PR-1 chunked engine entry point
    experiment.run_dataset       — the §4 evaluation protocol
    baselines                    — Chameleon / BlazeIt / Miris
"""
from repro.core.pipeline import (ModelBank, PipelineParams,  # noqa: F401
                                 run_clip, run_clip_frames)
