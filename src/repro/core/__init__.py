"""The paper's primary contribution: the MultiScope pre-processing system.

Public API:
    tuner.setup / tuner.tune     — Figure 1 workflow (train + θ_best +
                                   greedy joint tuning)
    pipeline.run_clip            — execute one configuration θ (staged
                                   chunked engine; engine="frame" for the
                                   per-frame reference path)
    engine.run_clip_chunked      — the chunked engine entry point
    experiment.run_dataset       — the §4 evaluation protocol
    baselines                    — Chameleon / BlazeIt / Miris
"""
from repro.core.pipeline import (ModelBank, PipelineParams,  # noqa: F401
                                 run_clip, run_clip_frames)
