"""The paper's primary contribution: the MultiScope pre-processing system.

Public API:
    tuner.setup / tuner.tune     — Figure 1 workflow (train + θ_best +
                                   greedy joint tuning)
    pipeline.run_clip            — execute one configuration θ
    experiment.run_dataset       — the §4 evaluation protocol
    baselines                    — Chameleon / BlazeIt / Miris
"""
from repro.core.pipeline import ModelBank, PipelineParams, run_clip  # noqa: F401
