"""Streaming clip executor: the pluggable stage-graph scheduler for the
chunked MultiScope pipeline.

PR 1 restructured one clip into chunks of B frames with four stages per
chunk; this module extracts those stages behind an explicit stage graph
so HOW the stages are scheduled is pluggable and independent of WHAT
each stage computes:

  DECODE  — render B frames at detector resolution, charging the
            decode-cost ledger (``pipeline.render_frame``);
  PROXY   — one fused ``proxy_plan`` kernel dispatch for the chunk
            (score + threshold + detector-grid mapping on device), then
            host window planning from the kernel's grids + plan stats
            (``windows.plan_from_mapped``; ``fused_plan=False`` keeps
            the legacy score-map round-trip through ``plan_chunk``);
  DETECT  — cross-frame size-class batches through the detector, window
            crops via the ``window_gather_batch`` Pallas kernel, batch
            dims padded to power-of-two buckets; with a shared
            ``BatchBroker`` the dispatch itself coalesces windows
            across every concurrent run (see ``BatchBroker``);
  TRACK   — detections feed the tracker strictly in frame order (the
            only stage with cross-chunk state), candidate crop
            embeddings batched per chunk (``tracker.embed_dets_chunk``).

Two schedulers drive the graph:

  * ``SequentialScheduler`` — every stage of chunk k completes before
    chunk k+1 starts: exactly the PR-1 chunked engine.
  * ``StreamingScheduler`` — DECODE (and, with double buffering, the
    device upload) for chunk k+1 runs on a background thread while
    chunk k is in PROXY/DETECT/TRACK on the caller's thread.  The
    hand-off queue is bounded by ``prefetch_depth``, so at most that
    many decoded chunks (and device buffers) are in flight.

Buffer ownership: the decoded host chunk is owned by its ``ChunkTask``;
the padded device copy (``frames_dev``) is uploaded either eagerly by
the decode worker (double buffering: the upload of chunk k+1 overlaps
chunk k's detector work) or lazily by DETECT, is only ever needed for
sub-frame window gathers, and is donated back (deleted) as soon as
DETECT finishes so at most ``prefetch_depth`` device buffers exist.

Sharding attaches at the chunk boundary: chunks are independent through
DETECT, so stages 1-3 round-robin across ``ExecutorOptions.devices``
(default: all local devices), and a ``jax.sharding.Mesh`` can be passed
instead to shard each chunk's batch axis via the
``repro.distributed.sharding.LogicalRules`` helpers.  TRACK is always
sequenced in frame order on the caller's thread, which is what keeps
the executor's tracks BIT-IDENTICAL to ``pipeline.run_clip_frames``
(asserted by tests/test_executor.py) for every chunk size, prefetch
setting, and device assignment.

The chunk size B is tuner-visible: ``PipelineParams.chunk_size`` (None
means ``DEFAULT_CHUNK``) is proposed by the tuner's scheduler module
for sparse/skip-heavy θ and flows through here, ``windows.plan_chunk``
and ``tracker.embed_dets_chunk`` bucketing.

``RunResult.seconds`` semantics are unchanged: process CPU time plus
the charged decode ledger.  Decode CPU actually spent is measured with
``time.thread_time`` in whichever thread renders, so the ledger
arithmetic is exact even when decode overlaps compute.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.detector import next_bucket, nms
from repro.core.pipeline import (CELL_PX, ModelBank, PipelineParams,
                                 RunResult, det_grid, downsample_chunk,
                                 make_sizeset, map_proxy_grid,
                                 render_frame)
from repro.core.tracker import RecurrentTracker, embed_dets_chunk
from repro.core.windows import (ChunkPlan, full_frame_plan, plan_chunk,
                                plan_from_mapped)
from repro.data.video_synth import Clip
from repro.obs.metrics import REGISTRY, RunProfile, drift_enabled
from repro.obs.recorder import crash_dump
from repro.obs.trace import TRACER

DEFAULT_CHUNK = 16     # frames per chunk (B) when θ does not say

STAGES = ("decode", "proxy", "detect", "track")


def effective_chunk(params: PipelineParams,
                    override: Optional[int] = None) -> int:
    """The chunk size B for one run: explicit override > θ's
    ``chunk_size`` > ``DEFAULT_CHUNK``."""
    if override is not None:
        return int(override)
    b = getattr(params, "chunk_size", None)
    return int(b) if b else DEFAULT_CHUNK


@dataclass
class ExecutorOptions:
    """Scheduling knobs — orthogonal to θ (they never change tracks).

    ``prefetch``       — decode chunk k+1 on a background thread while
                         chunk k is in proxy/detect/track;
    ``prefetch_depth`` — max decoded chunks in flight (bounds host and
                         device memory);
    ``decode_workers`` — size of the decode worker pool per run
                         (default 1: the single implicit thread).  With
                         N > 1 workers, chunks decode concurrently and
                         a reorder gate hands them to the compute
                         thread strictly in chunk order, so TRACK stays
                         frame-ordered and tracks stay bit-identical;
                         in-flight decoded chunks are bounded by
                         ``prefetch_depth + decode_workers`` (queue
                         plus at most one chunk held per worker at the
                         gate);
    ``double_buffer``  — upload ``frames_dev`` in the decode worker so
                         the copy overlaps the previous chunk's
                         detector work (only when a proxy is active:
                         all-full-frame plans never need the buffer);
    ``devices``        — stage 1-3 dispatch targets, round-robinned per
                         chunk (default: ``jax.local_devices()``);
    ``mesh``           — optional ``jax.sharding.Mesh``; when set, each
                         chunk's batch axis is sharded through
                         ``LogicalRules`` instead of whole-chunk
                         round-robin;
    ``chunk_size``     — override θ's B (engine compat path);
    ``decode_pool``    — an externally owned ``DecodePool``: decode jobs
                         are submitted to its persistent shared workers
                         instead of spawning per-run threads (per-run
                         reorder gates keep TRACK frame-ordered);
    ``share_decode_pool`` — let ``run_clips`` create ONE pool shared by
                         the two in-flight clips (the pool is sized
                         ``max(2, decode_workers)`` so cross-clip decode
                         overlap survives the sharing);
    ``batch_broker``   — an externally owned ``BatchBroker``: DETECT
                         dispatches route through it so windows from
                         every run sharing the broker coalesce into one
                         consolidated detector batch per size class
                         (tracks stay bit-identical per stream —
                         detector rows are per-sample independent);
    ``fused_plan``     — PROXY uses the fused ``proxy_plan`` kernel
                         (score + threshold + detector-grid mapping on
                         device, ``windows.plan_from_mapped`` on the
                         stats) instead of pulling the full score map to
                         the host.  Plans, and therefore tracks, are
                         bit-identical either way;
    ``device_assign``  — TRACK runs each per-frame step as ONE fused
                         ``kernels.track_step`` dispatch (GRU + match
                         logits + cost + JV assignment on device)
                         instead of the host numpy twins.  Tracks are
                         bit-identical (the fastmath contract);
    ``device_tracker`` — TRACK holds its state in device slot buffers
                         and executes a whole chunk as one ``lax.scan``
                         dispatch (``tracker.DeviceTracker``; implies
                         the device step).  Tracks are bit-identical;
    ``track_broker``   — an externally owned ``TrackBroker``: device
                         track steps from every run sharing the broker
                         coalesce into one batched ``track_step``
                         dispatch (the per-frame live-fleet regime;
                         chunk-resident runs without a broker use the
                         scan instead).  Per-stream tracks stay
                         bit-identical — the fused step restricts its
                         JV solve to the canonical ``assoc_side``
                         square, so batch padding never perturbs it.
    """
    prefetch: bool = True
    prefetch_depth: int = 2
    decode_workers: int = 1
    double_buffer: bool = True
    devices: Optional[Sequence] = None
    mesh: Optional[object] = None
    chunk_size: Optional[int] = None
    decode_pool: Optional["DecodePool"] = None
    share_decode_pool: bool = True
    batch_broker: Optional["BatchBroker"] = None
    fused_plan: bool = True
    device_assign: bool = False
    device_tracker: bool = False
    track_broker: Optional["TrackBroker"] = None


@dataclass
class ChunkTask:
    """One chunk's state as it flows through the stage graph."""
    index: int
    frame_ids: List[int]
    frames: Optional[np.ndarray] = None        # (B, H, W, 3) host pixels
    charged: float = 0.0                       # decode ledger for chunk
    frames_dev: Optional[object] = None        # padded device buffer
    plan: Optional[ChunkPlan] = None
    dets: Optional[List[np.ndarray]] = None    # per-frame detections


class _WorkerFailure:
    def __init__(self, exc: BaseException):
        self.exc = exc


# ---------------------------------------------------------------------------
# Cross-stream batch broker (PROXY -> DETECT boundary)
# ---------------------------------------------------------------------------

class BrokerCancelled(RuntimeError):
    """The stream's broker registration was dropped while a request was
    pending: its windows are discarded, other streams are unaffected."""


class _BrokerHandle:
    """One stream's registration with a ``BatchBroker``.  Created lazily
    by ``_RunContext`` on the stream's first DETECT dispatch (so a run
    that never reaches DETECT never delays other streams' flushes) and
    closed when the run finishes or is cancelled."""

    __slots__ = ("broker", "active")

    def __init__(self, broker: "BatchBroker"):
        self.broker = broker
        self.active = True

    def detect(self, detector, frames, conf, origins, scales,
               n_valid: int) -> List[np.ndarray]:
        return self.broker._detect(self, detector, frames, conf,
                                   origins, scales, n_valid)

    def close(self) -> None:
        self.broker.unregister(self)


class _BrokerRequest:
    __slots__ = ("handle", "detector", "frames", "conf", "origins",
                 "scales", "n", "t_enq", "done", "result", "error")

    def __init__(self, handle, detector, frames, conf, origins, scales,
                 n: int):
        self.handle = handle
        self.detector = detector
        self.frames = frames            # (>= n, h, w, 3); rows >= n pad
        self.conf = conf
        self.origins = list(origins)
        self.scales = list(scales)
        self.n = n
        self.t_enq = 0.0                # monotonic at enqueue
        self.done = False
        self.result: Optional[List[np.ndarray]] = None
        self.error: Optional[BaseException] = None


class BatchBroker:
    """Coalesce DETECT dispatches across concurrent executor runs.

    Each run (a ``SegmentIngestor`` append, one clip of ``run_clips``, a
    camera thread) registers a handle; its DETECT stage submits one
    request per size class and blocks for the routed-back results, which
    keeps TRACK order per stream exactly as without the broker.  Pending
    requests from all streams flush together: same-shape requests (one
    pow2 size-class bucket, the existing padding scheme) concatenate
    into ONE consolidated ``detect_batch`` call whose per-window results
    split back per request.  Detector conv outputs are per-sample
    independent of batch composition and each window's detections are
    decoded from its own rows, so per-stream tracks are BIT-IDENTICAL to
    the broker-off path (asserted by tests/test_broker.py).

    Flush policy — whichever waiting stream first observes a trigger
    performs the flush inline (no dedicated thread), and the detector
    dispatch itself runs with the condition variable RELEASED: streams
    reaching DETECT while a batch computes enqueue into the next batch
    instead of convoying behind the lock.  Triggers:

      * every registered stream has a request pending (nobody else can
        join this batch), or
      * pending windows reach ``max_batch`` (the consolidated bucket is
        full), or
      * a request has waited ``linger_ms`` (bounded latency: a stream
        whose peers are decoding — or yielded zero windows this chunk —
        never stalls behind them).  The 10ms default is well under a
        frame period and long enough for streams decoding concurrently
        to coalesce their chunks' windows.

    A failing stream's handle is closed by its executor, dropping its
    pending requests with ``BrokerCancelled`` while everyone else's
    flush proceeds; ``close()`` drains whatever is still pending.

    Stats (read by benchmarks): ``dispatches`` consolidated detector
    calls, ``windows_in`` real windows served, ``batch_fill`` per-call
    valid/bucket occupancy.
    """

    def __init__(self, max_batch: int = 64, linger_ms: float = 10.0):
        self.max_batch = int(max_batch)
        self.linger = float(linger_ms) / 1e3
        self._cv = threading.Condition()
        self._pending: List[_BrokerRequest] = []    # guarded-by: _cv
        self._registered = 0                        # guarded-by: _cv
        self._waiting = 0                           # guarded-by: _cv
        self._closed = False                        # guarded-by: _cv
        self.dispatches = 0                         # guarded-by: _cv
        self.windows_in = 0                         # guarded-by: _cv
        self.batch_fill: List[float] = []           # guarded-by: _cv
        # registry mirrors (cached: registry reset zeroes in place)
        self._m_disp = REGISTRY.counter("broker.detect.dispatches")
        self._m_units = REGISTRY.counter("broker.detect.units_in")
        self._m_fill = REGISTRY.histogram("broker.detect.fill")
        self._m_wait = REGISTRY.histogram("broker.detect.linger_wait_ms")
        self._m_depth = REGISTRY.gauge("broker.detect.queue_depth")

    # -- stream side ----------------------------------------------------------

    def register(self) -> _BrokerHandle:
        with self._cv:
            if self._closed:
                raise RuntimeError("BatchBroker is closed")
            self._registered += 1
            return _BrokerHandle(self)

    def unregister(self, handle: _BrokerHandle) -> None:
        with self._cv:
            if not handle.active:
                return
            handle.active = False
            self._registered -= 1
            for req in self._pending:
                if req.handle is handle:
                    req.error = BrokerCancelled(
                        "stream dropped with a request in flight")
                    req.done = True
            self._pending = [r for r in self._pending if not r.done]
            self._cv.notify_all()

    def close(self) -> None:
        """Drain-on-close: flush whatever is pending, then refuse new
        work.  Idempotent."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            batch, self._pending = self._pending, []
            if batch:
                stats = self._flush(batch)
                self._apply_stats(stats)
            self._cv.notify_all()

    def _detect(self, handle: _BrokerHandle, detector, frames, conf,
                origins, scales, n_valid: int) -> List[np.ndarray]:
        """Submit one size-class request and block for its results.
        ``frames``: (>= n_valid, h, w, 3) host or device rows; rows past
        ``n_valid`` are padding and are dropped before consolidation."""
        if n_valid == 0:
            return []
        req = _BrokerRequest(handle, detector, frames, conf, origins,
                             scales, n_valid)
        cv = self._cv
        cv.acquire()
        try:
            if self._closed:
                raise RuntimeError("BatchBroker is closed")
            if not handle.active:
                raise BrokerCancelled("handle already closed")
            # no notify on enqueue: this thread checks the flush trigger
            # itself before waiting, and every other waiter re-checks at
            # its own linger deadline — waking 15 peers per enqueue on a
            # single core is pure context-switch churn
            req.t_enq = time.monotonic()
            self._pending.append(req)
            self._waiting += 1
            self._m_depth.set(len(self._pending))
            try:
                deadline = req.t_enq + self.linger
                while not req.done:
                    if self._pending and (
                            self._should_flush()
                            or time.monotonic() >= deadline):
                        batch, self._pending = self._pending, []
                        # dispatch WITHOUT the lock: streams reaching
                        # DETECT while this batch computes enqueue into
                        # the next one instead of convoying behind it
                        cv.release()
                        try:
                            stats = self._flush(batch)
                        finally:
                            cv.acquire()
                        self._apply_stats(stats)
                        cv.notify_all()
                    elif self._pending:
                        cv.wait(timeout=max(
                            deadline - time.monotonic(), 1e-4))
                    else:
                        # our request rode out with another thread's
                        # in-flight flush; its completion (or a cancel)
                        # notifies under the lock
                        cv.wait()
            finally:
                self._waiting -= 1
        finally:
            cv.release()
        if req.error is not None:
            raise req.error
        return req.result

    # -- flush side -----------------------------------------------------------

    # holds-lock: _cv
    def _should_flush(self) -> bool:
        if not self._pending:
            return False
        if self._waiting >= self._registered:
            return True
        return sum(r.n for r in self._pending) >= self.max_batch

    # holds-lock: _cv
    def _apply_stats(self, stats: List[Tuple[int, int]]) -> None:
        """Fold per-dispatch (valid, bucket) counts into the public
        counters; called with the condition variable held (dispatches
        themselves can overlap across flushing threads)."""
        for total, bucket in stats:
            self.dispatches += 1
            self.windows_in += total
            self.batch_fill.append(total / bucket)
            self._m_disp.inc()
            self._m_units.inc(total)
            self._m_fill.observe(total / bucket)

    def _flush(self, batch: List[_BrokerRequest]
               ) -> List[Tuple[int, int]]:
        # how long the oldest rider lingered before this flush fired
        wait_ms = max(0.0, (time.monotonic()
                            - min(r.t_enq for r in batch)) * 1e3)
        self._m_wait.observe(wait_ms)
        fsp = None
        if TRACER.enabled:
            fsp = TRACER.open(
                "broker.detect.flush", "broker",
                args={"requests": len(batch),
                      "streams": len({id(r.handle) for r in batch}),
                      "windows": sum(r.n for r in batch),
                      "wait_ms": round(wait_ms, 3)})
        groups: Dict[tuple, List[_BrokerRequest]] = {}
        for req in batch:
            key = (id(req.detector), float(req.conf),
                   tuple(req.frames.shape[1:3]))
            groups.setdefault(key, []).append(req)
        stats: List[Tuple[int, int]] = []
        for reqs in groups.values():
            d0 = time.perf_counter_ns() if fsp is not None else 0
            try:
                stats.append(self._dispatch(reqs))
            except BaseException as exc:
                for r in reqs:
                    r.error = exc
                    r.done = True
            else:
                if fsp is not None:
                    total, bucket = stats[-1]
                    TRACER.emit(
                        "broker.detect.dispatch", "broker", ts=d0,
                        dur=time.perf_counter_ns() - d0, parent=fsp.sid,
                        args={"windows": total, "bucket": bucket,
                              "streams": len(reqs),
                              "fill": round(total / bucket, 3)})
        if fsp is not None:
            TRACER.close(fsp)
        return stats

    def _dispatch(self, reqs: List[_BrokerRequest]) -> Tuple[int, int]:
        detector = reqs[0].detector
        total = sum(r.n for r in reqs)
        bucket = next_bucket(total)
        if len(reqs) == 1 and reqs[0].frames.shape[0] == bucket:
            # lone already-bucketed request (a stream flushing alone at
            # its linger deadline): feed it through untouched — for
            # device-side crops this skips the host round-trip entirely,
            # making a solo-stream broker run cost the same as no broker
            r = reqs[0]
            dets = detector.detect_batch(r.frames, r.conf,
                                         origins=r.origins,
                                         scales=r.scales, n_valid=r.n)
            r.result = dets
            r.done = True
            return total, bucket
        parts = [r.frames[:r.n] for r in reqs]
        # consolidate in HOST memory even when parts are device arrays:
        # a jnp.concatenate here would specialize one XLA program per
        # distinct combination of part counts/shapes (unbounded across a
        # fleet), while the numpy stack keeps the jit universe to the
        # same pow2 detect buckets the solo path already compiles
        stack = np.zeros((bucket,) + tuple(parts[0].shape[1:]),
                         np.float32)
        ofs = 0
        for p in parts:
            stack[ofs:ofs + len(p)] = np.asarray(p)
            ofs += len(p)
        origins = [o for r in reqs for o in r.origins]
        scales = [s for r in reqs for s in r.scales]
        dets = detector.detect_batch(stack, reqs[0].conf,
                                     origins=origins, scales=scales,
                                     n_valid=total)
        ofs = 0
        for r in reqs:
            r.result = dets[ofs:ofs + r.n]
            ofs += r.n
            r.done = True
        return total, bucket


# ---------------------------------------------------------------------------
# Cross-stream track-step broker (TRACK stage, per-frame device regime)
# ---------------------------------------------------------------------------

class _TrackHandle:
    """One stream's registration with a ``TrackBroker``.  Attached to the
    stream's tracker as ``_track_handle`` by ``_RunContext`` and closed
    when the run finishes or is cancelled."""

    __slots__ = ("broker", "active")

    def __init__(self, broker: "TrackBroker"):
        self.broker = broker
        self.active = True

    def step(self, h_r, tbox_r, alive_r, te_gap_r, te_match, x, dbox,
             dvalid, thr, params, table, *, params_key):
        return self.broker._step(self, (h_r, tbox_r, alive_r, te_gap_r,
                                        te_match, x, dbox, dvalid),
                                 thr, params, table, params_key)

    def close(self) -> None:
        self.broker.unregister(self)


class _TrackRequest:
    __slots__ = ("handle", "arrs", "thr", "params", "table", "key",
                 "t_enq", "done", "result", "error")

    def __init__(self, handle, arrs, thr, params, table, key):
        self.handle = handle
        self.arrs = arrs                # the 8 (Q, ...) stream arrays
        self.thr = thr
        self.params = params
        self.table = table
        self.key = key                  # flush-group key
        self.t_enq = 0.0                # monotonic at enqueue
        self.done = False
        self.result = None
        self.error: Optional[BaseException] = None


class TrackBroker:
    """Coalesce per-frame device track steps across concurrent runs.

    The fused ``kernels.track_step`` batches over a leading K axis of
    independent streams; in the live per-frame regime (a fleet of
    ``SegmentIngestor`` cameras appending a frame or two at a time),
    each stream alone would dispatch K=1 steps.  A shared broker lets
    those steps ride one dispatch: each stream's ``assign="device"``
    tracker submits its step operands and blocks for the routed-back
    slice, so TRACK order per stream is exactly as without the broker.

    Same flush discipline as ``BatchBroker`` (whichever waiting stream
    first observes a trigger flushes inline with the lock released):
    every registered stream pending, ``max_streams`` pending, or a
    request older than ``linger_ms``.  Streams group by (tracker
    params, threshold, head dims); a group's slot buffers pad to the
    widest stream's Q and the batch axis pads to a pow2 bucket, both of
    which are bit-invariant for the real rows — the kernel restricts
    its JV solve to the canonical ``assoc_side`` square derived from
    the LIVE/VALID counts, so padding rows never perturb it (asserted
    by tests/test_device_tracker.py).

    Stats (read by benchmarks): ``dispatches`` consolidated kernel
    calls, ``steps_in`` real stream-steps served, ``stream_fill``
    per-call stream counts."""

    def __init__(self, max_streams: int = 16, linger_ms: float = 5.0):
        self.max_streams = int(max_streams)
        self.linger = float(linger_ms) / 1e3
        self._cv = threading.Condition()
        self._pending: List[_TrackRequest] = []     # guarded-by: _cv
        self._registered = 0                        # guarded-by: _cv
        self._waiting = 0                           # guarded-by: _cv
        self._closed = False                        # guarded-by: _cv
        self.dispatches = 0                         # guarded-by: _cv
        self.steps_in = 0                           # guarded-by: _cv
        self.stream_fill: List[int] = []            # guarded-by: _cv
        # registry mirrors (cached: registry reset zeroes in place)
        self._m_disp = REGISTRY.counter("broker.track.dispatches")
        self._m_units = REGISTRY.counter("broker.track.units_in")
        self._m_fill = REGISTRY.histogram("broker.track.fill")
        self._m_wait = REGISTRY.histogram("broker.track.linger_wait_ms")
        self._m_depth = REGISTRY.gauge("broker.track.queue_depth")

    # -- stream side ----------------------------------------------------------

    def register(self) -> _TrackHandle:
        with self._cv:
            if self._closed:
                raise RuntimeError("TrackBroker is closed")
            self._registered += 1
            return _TrackHandle(self)

    def unregister(self, handle: _TrackHandle) -> None:
        with self._cv:
            if not handle.active:
                return
            handle.active = False
            self._registered -= 1
            for req in self._pending:
                if req.handle is handle:
                    req.error = BrokerCancelled(
                        "stream dropped with a track step in flight")
                    req.done = True
            self._pending = [r for r in self._pending if not r.done]
            self._cv.notify_all()

    def close(self) -> None:
        """Drain-on-close: flush whatever is pending, then refuse new
        work.  Idempotent."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            batch, self._pending = self._pending, []
            if batch:
                stats = self._flush(batch)
                self._apply_stats(stats)
            self._cv.notify_all()

    def _step(self, handle: _TrackHandle, arrs, thr, params, table,
              params_key):
        Q, H = arrs[0].shape
        e = arrs[5].shape[1]
        key = (params_key, float(np.asarray(thr).reshape(-1)[0]), H, e)
        req = _TrackRequest(handle, arrs, thr, params, table, key)
        cv = self._cv
        cv.acquire()
        try:
            if self._closed:
                raise RuntimeError("TrackBroker is closed")
            if not handle.active:
                raise BrokerCancelled("handle already closed")
            req.t_enq = time.monotonic()
            self._pending.append(req)
            self._waiting += 1
            self._m_depth.set(len(self._pending))
            try:
                deadline = req.t_enq + self.linger
                while not req.done:
                    if self._pending and (
                            self._should_flush()
                            or time.monotonic() >= deadline):
                        batch, self._pending = self._pending, []
                        cv.release()
                        try:
                            stats = self._flush(batch)
                        finally:
                            cv.acquire()
                        self._apply_stats(stats)
                        cv.notify_all()
                    elif self._pending:
                        cv.wait(timeout=max(
                            deadline - time.monotonic(), 1e-4))
                    else:
                        cv.wait()
            finally:
                self._waiting -= 1
        finally:
            cv.release()
        if req.error is not None:
            raise req.error
        return req.result

    # -- flush side -----------------------------------------------------------

    # holds-lock: _cv
    def _should_flush(self) -> bool:
        if not self._pending:
            return False
        if self._waiting >= self._registered:
            return True
        return len(self._pending) >= self.max_streams

    # holds-lock: _cv
    def _apply_stats(self, stats: List[int]) -> None:
        for k in stats:
            self.dispatches += 1
            self.steps_in += k
            self.stream_fill.append(k)
            self._m_disp.inc()
            self._m_units.inc(k)
            self._m_fill.observe(float(k))

    def _flush(self, batch: List[_TrackRequest]) -> List[int]:
        wait_ms = max(0.0, (time.monotonic()
                            - min(r.t_enq for r in batch)) * 1e3)
        self._m_wait.observe(wait_ms)
        fsp = None
        if TRACER.enabled:
            fsp = TRACER.open(
                "broker.track.flush", "broker",
                args={"requests": len(batch),
                      "streams": len({id(r.handle) for r in batch}),
                      "wait_ms": round(wait_ms, 3)})
        groups: Dict[tuple, List[_TrackRequest]] = {}
        for req in batch:
            groups.setdefault(req.key, []).append(req)
        stats: List[int] = []
        for reqs in groups.values():
            d0 = time.perf_counter_ns() if fsp is not None else 0
            try:
                stats.append(self._dispatch(reqs))
            except BaseException as exc:
                for r in reqs:
                    r.error = exc
                    r.done = True
            else:
                if fsp is not None:
                    TRACER.emit(
                        "broker.track.dispatch", "broker", ts=d0,
                        dur=time.perf_counter_ns() - d0, parent=fsp.sid,
                        args={"streams": len(reqs)})
        if fsp is not None:
            TRACER.close(fsp)
        return stats

    def _dispatch(self, reqs: List[_TrackRequest]) -> int:
        from repro.kernels.track_step import track_step
        K = len(reqs)
        Kb = next_bucket(K)             # bound the jit universe to pow2
        Qm = max(r.arrs[0].shape[0] for r in reqs)
        # pad every stream to the widest slot bucket and stack: padding
        # rows are dead (alive = dvalid = 0), so the assoc_side-
        # restricted solve never sees them and real rows come back
        # bit-identical to a solo dispatch
        stacked = []
        for i, a in enumerate(zip(*(r.arrs for r in reqs))):
            tail = a[0].shape[1:]
            buf = np.zeros((Kb, Qm) + tail, np.float32)
            for k, part in enumerate(a):
                buf[k, :part.shape[0]] = part
            stacked.append(buf)
        r0 = reqs[0]
        out = track_step(*stacked, r0.thr, r0.params, r0.table)
        matched, h_upd, h_new = (np.asarray(o) for o in out)
        for k, r in enumerate(reqs):
            q = r.arrs[0].shape[0]
            r.result = (matched[k, :q], h_upd[k, :q], h_new[k, :q])
            r.done = True
        return K


class _RunContext:
    """Per-clip derived state shared by every stage.

    ``frame_ids`` (default: θ's full gap progression over the clip)
    restricts the run to an explicit frame list — the live-ingestion
    path (``repro.stream``) runs one appended SEGMENT of an open clip
    at a time.  ``tracker`` injects an existing tracker instead of a
    fresh one, so TRACK state (active tracks, GRU hidden state, id
    counter) carries across segment runs; the stage graph itself never
    knows whether it is running a whole clip or a resumed slice."""

    def __init__(self, bank: ModelBank, params: PipelineParams,
                 clip: Clip, options: ExecutorOptions,
                 device_offset: int = 0,
                 frame_ids: Optional[Sequence[int]] = None,
                 tracker: Optional[object] = None):
        self.bank = bank
        self.params = params
        self.clip = clip
        self.cfg = bank.cfg
        self.chunk = effective_chunk(params, options.chunk_size)
        self.W, self.H = params.det_res
        self.proxy = bank.proxies.get(params.proxy_res) \
            if params.proxy_res is not None else None
        self.sizeset = make_sizeset(bank, params)
        self.grid = det_grid(params.det_res)
        self.detector = bank.detectors[params.det_arch]
        if tracker is not None:
            self.tracker: object = tracker
        else:
            from repro.core.pipeline import make_tracker
            self.tracker = make_tracker(
                bank, params, device_assign=options.device_assign,
                device_tracker=options.device_tracker)
        self.batch_embed = isinstance(self.tracker, RecurrentTracker)
        # cross-stream track-step broker: attach a handle to any
        # device-assign recurrent tracker (injected trackers included —
        # the live fleet passes resumed trackers through ``start``)
        self._track_broker = options.track_broker
        self.track_handle: Optional[_TrackHandle] = None
        if self._track_broker is not None and self.batch_embed \
                and getattr(self.tracker, "assign", "host") == "device":
            self.track_handle = self._track_broker.register()
            self.tracker._track_handle = self.track_handle
        self.devices = list(options.devices) if options.devices \
            else jax.local_devices()
        self.device_offset = device_offset
        self.sharding = None
        if options.mesh is not None:
            from repro.distributed.sharding import LogicalRules
            rules = LogicalRules(options.mesh)
            self.sharding = rules.named_sharding(
                (self.chunk, self.H, self.W, 3),
                ("batch", None, None, None))
        # upload in the decode worker only when the buffer can actually
        # be used: sub-frame gathers require an active proxy, and the
        # previous chunk's plan is the cheap predictor of whether this
        # one will gather at all (skip-heavy θ would otherwise pay a
        # per-chunk host-to-device copy that DETECT deletes unused)
        self.predecode_upload = bool(options.double_buffer
                                     and self.proxy is not None)
        self.prev_chunk_gathered = False    # benign cross-thread read
        self.fused_plan = bool(options.fused_plan
                               and self.proxy is not None)
        self._broker = options.batch_broker
        self.broker_handle: Optional[_BrokerHandle] = None
        self.frame_ids = list(frame_ids) if frame_ids is not None \
            else list(range(0, clip.n_frames, params.gap))
        # ledger + RunResult counters, accumulated by TRACK (the only
        # stage that is strictly sequenced)
        self.charged = 0.0
        self.n_windows = 0
        self.full_frames = 0
        self.skipped = 0
        # per-stage wall/CPU + dispatch profile (obs.metrics.RunProfile:
        # the one assembly point for RunResult.stage_seconds); decode may
        # run on several workers, the profile carries the lock
        self.profile = RunProfile(STAGES)
        self._disp_track0 = int(getattr(self.tracker, "dispatches", 0))
        # observability: stream label for spans/gauges, plus the run's
        # root span (children emitted from worker threads parent to it
        # by explicit id)
        self.stream = f"{clip.profile.name}/{clip.split}{clip.clip_id}"
        self.run_span = None
        if TRACER.enabled:
            self.run_span = TRACER.open(
                "run", "executor", stream=self.stream,
                args={"frames": len(self.frame_ids),
                      "chunk": self.chunk})
        # per-frame proxy positive-cell fractions (drift monitoring
        # only; PROXY runs on the draining thread in chunk order, so
        # appends stay frame-ordered without a lock)
        self.proxy_fracs: Optional[List[float]] = \
            [] if drift_enabled() else None

    def broker(self) -> Optional[_BrokerHandle]:
        """The run's broker handle, registered lazily on the first
        DETECT dispatch (only streams that actually detect take part in
        the broker's all-streams-pending flush trigger).  DETECT runs on
        the draining thread only, so no lock is needed."""
        if self._broker is not None and self.broker_handle is None:
            self.broker_handle = self._broker.register()
        return self.broker_handle

    def close(self) -> None:
        """Release cross-run resources (the broker registrations);
        called by the executor when the run finishes or is cancelled."""
        if self.broker_handle is not None:
            self.broker_handle.close()
            self.broker_handle = None
        self._broker = None
        if self.track_handle is not None:
            if getattr(self.tracker, "_track_handle", None) \
                    is self.track_handle:
                self.tracker._track_handle = None
            self.track_handle.close()
            self.track_handle = None
        self._track_broker = None
        if self.run_span is not None and self.run_span.dur < 0:
            TRACER.close(self.run_span,
                         args={"windows": self.n_windows,
                               "skipped": self.skipped})

    def device_for(self, task: ChunkTask):
        return self.devices[(self.device_offset + task.index)
                            % len(self.devices)]

    def upload(self, task: ChunkTask):
        """Pad the chunk to B frames (one gather jit shape) and place it
        on this chunk's device / mesh sharding."""
        padded = np.zeros((self.chunk, self.H, self.W, 3), np.float32)
        padded[:task.frames.shape[0]] = task.frames
        if self.sharding is not None:
            return jax.device_put(padded, self.sharding)
        if len(self.devices) > 1:
            return jax.device_put(padded, self.device_for(task))
        return jnp.asarray(padded)


# ---------------------------------------------------------------------------
# The four stages
# ---------------------------------------------------------------------------

def stage_decode(ctx: _RunContext, task: ChunkTask) -> ChunkTask:
    """Render the chunk at detector resolution, charging the ledger.

    ``time.thread_time`` measures the CPU actually spent rendering in
    THIS thread, so the charge (ledger cost minus actual cost) stays
    exact whether decode runs inline or on the prefetch worker."""
    B = len(task.frame_ids)
    frames = np.empty((B, ctx.H, ctx.W, 3), np.float32)
    charged = 0.0
    for k, f in enumerate(task.frame_ids):
        t_r = time.thread_time()
        frame, cost = render_frame(ctx.clip, f, ctx.W, ctx.H)
        charged += cost - (time.thread_time() - t_r)
        frames[k] = frame
    task.frames = frames
    task.charged = charged
    if ctx.predecode_upload and ctx.prev_chunk_gathered:
        task.frames_dev = ctx.upload(task)
    return task


def stage_proxy(ctx: _RunContext, task: ChunkTask) -> ChunkTask:
    """Proxy-score the whole chunk in one dispatch and plan windows.

    The default path is the fused ``proxy_plan`` kernel: threshold and
    detector-grid mapping happen on device and only the mapped int8
    grids + per-frame plan stats cross to the host, where
    ``plan_from_mapped`` takes exact shortcuts on the stats.  The
    legacy path (``fused_plan=False``) pulls the score map back and
    maps/plans fully on the host; both produce bit-identical plans."""
    if ctx.proxy is not None:
        ctx.profile.dispatch("proxy")
        pframes = downsample_chunk(task.frames, ctx.proxy.resolution)
        if ctx.fused_plan:
            grids, stats = ctx.proxy.plan_batch(
                pframes, ctx.params.proxy_threshold, ctx.grid)
            task.plan = plan_from_mapped(grids, stats, ctx.sizeset,
                                         ctx.cfg.windows.max_windows,
                                         chunk_size=ctx.chunk)
        else:
            _, pos = ctx.proxy.scores_batch(pframes,
                                            ctx.params.proxy_threshold)
            grids = [map_proxy_grid(p, ctx.grid) for p in pos]
            task.plan = plan_chunk(grids, ctx.sizeset,
                                   ctx.cfg.windows.max_windows,
                                   chunk_size=ctx.chunk)
        if ctx.proxy_fracs is not None:
            # drift signal: positive-cell fraction per REAL frame (an
            # observer of grids the plan already computed — rows past
            # the chunk's frame count are padding)
            g = np.asarray(grids)[:len(task.frame_ids)]
            fracs = (g > 0).mean(axis=tuple(range(1, g.ndim)))
            ctx.proxy_fracs.extend(float(v) for v in fracs)
    else:
        task.plan = full_frame_plan(len(task.frame_ids), ctx.sizeset)
    return task


def stage_detect(ctx: _RunContext, task: ChunkTask) -> ChunkTask:
    """Cross-frame bucketed detection; reassemble per-frame detections
    in the exact order the per-frame path would have produced them."""
    detector = ctx.detector
    W, H = ctx.W, ctx.H
    plan, frames = task.plan, task.frames
    frames_dev = task.frames_dev
    per_window: Dict[Tuple[int, int], np.ndarray] = {}
    for size, entries in plan.by_size.items():
        pw, ph = size[0] * CELL_PX, size[1] * CELL_PX
        n = len(entries)
        origins = [(x * CELL_PX / W, y * CELL_PX / H)
                   for (_, x, y, _) in entries]
        scales = [(pw / W, ph / H)] * n
        broker = ctx.broker()
        ctx.profile.dispatch("detect")
        if (pw, ph) == (W, H):
            # full-frame windows: the crop is the frame itself
            stack = frames[[slot for (slot, _, _, _) in entries]]
            if broker is not None:
                dets = broker.detect(detector, stack,
                                     ctx.params.det_conf,
                                     origins, scales, n)
            else:
                dets = detector.detect_batch_bucketed(
                    stack, ctx.params.det_conf, origins=origins,
                    scales=scales)
        else:
            if frames_dev is None:       # lazy path (no double buffer)
                frames_dev = ctx.upload(task)
            tbl = np.zeros((next_bucket(n), 3), np.int32)
            for k, (slot, x, y, _) in enumerate(entries):
                tbl[k] = (slot, y, x)
            from repro.kernels.window_gather import window_gather_batch
            crops = window_gather_batch(frames_dev, tbl,
                                        win_h=ph, win_w=pw, cell=CELL_PX)
            # crops stay device-side: detect_batch feeds them straight
            # into the detector without a host round-trip
            if broker is not None:
                dets = broker.detect(detector, crops,
                                     ctx.params.det_conf,
                                     origins, scales, n)
            else:
                dets = detector.detect_batch(
                    crops, ctx.params.det_conf, origins=origins,
                    scales=scales, n_valid=n)
        for (slot, _, _, wi), d in zip(entries, dets):
            per_window[(slot, wi)] = d

    merged: List[np.ndarray] = []
    for slot, wins in enumerate(plan.windows):
        if not wins:
            merged.append(np.zeros((0, 5), np.float32))
        elif len(wins) == 1 and wins[0][2] == ctx.sizeset.full:
            # the per-frame fast path applies no cross-window NMS
            merged.append(per_window[(slot, 0)])
        else:
            by_size_frame: Dict[Tuple[int, int], List[int]] = {}
            for wi, (_, _, s) in enumerate(wins):
                by_size_frame.setdefault(s, []).append(wi)
            parts = [per_window[(slot, wi)]
                     for wis in by_size_frame.values() for wi in wis]
            merged.append(nms(np.concatenate(parts)))
    task.dets = merged
    # steer the decode worker's eager upload (a stale read just means
    # one lazy upload): this chunk gathered iff any size class was
    # sub-frame
    ctx.prev_chunk_gathered = any(
        (s[0] * CELL_PX, s[1] * CELL_PX) != (W, H)
        for s in plan.by_size)
    # donate the device buffer back: DETECT is its last consumer, and
    # freeing it here bounds in-flight device memory to prefetch_depth
    if frames_dev is not None:
        task.frames_dev = None
        try:
            frames_dev.delete()
        except Exception:
            pass
    return task


def stage_track(ctx: _RunContext, task: ChunkTask) -> ChunkTask:
    """Feed the tracker strictly in frame order; accumulate counters and
    the decode ledger.  The crop CNN runs once per chunk, and the whole
    chunk goes through ``step_chunk`` — a per-frame loop on the base
    tracker, ONE ``lax.scan`` dispatch on ``DeviceTracker``."""
    for wins in task.plan.windows:
        ctx.n_windows += len(wins)
        if len(wins) == 1 and wins[0][2] == ctx.sizeset.full:
            ctx.full_frames += 1
        if not wins:
            ctx.skipped += 1
    ctx.charged += task.charged
    if ctx.batch_embed:
        ctx.profile.dispatch("embed")
        embeds = embed_dets_chunk(ctx.bank.tracker_params,
                                  ctx.cfg.tracker, task.frames,
                                  task.dets,
                                  min_bucket=max(8, ctx.chunk // 2))
        ctx.tracker.step_chunk(task.frame_ids, task.dets, task.frames,
                               embeds=embeds)
    else:
        for k, f in enumerate(task.frame_ids):
            ctx.tracker.step(f, task.dets[k], task.frames[k])
    task.frames = None
    return task


DEFAULT_STAGES: Dict[str, Callable[[_RunContext, ChunkTask], ChunkTask]] \
    = {"decode": stage_decode, "proxy": stage_proxy,
       "detect": stage_detect, "track": stage_track}


def _timed(name: str, fn: Callable) -> Callable:
    """Wrap a stage so each call accumulates wall + thread-CPU seconds
    into the run's per-stage profile.  ``thread_time`` counts only the
    calling thread, so overlapped stages (decode on workers, compute on
    the draining thread) sum to honest per-stage CPU rather than
    double-counting each other.  With tracing on, the same interval is
    also emitted as a ``stage.{name}`` span parented to the run's root
    (explicitly — decode runs on worker threads whose thread-local span
    stack is empty)."""
    span_name = f"stage.{name}"

    def wrapper(ctx: _RunContext, task: ChunkTask) -> ChunkTask:
        t0 = time.perf_counter_ns()
        c0 = time.thread_time_ns()
        try:
            return fn(ctx, task)
        finally:
            dur = time.perf_counter_ns() - t0
            proc = time.thread_time_ns() - c0
            ctx.profile.note_stage(name, dur / 1e9, proc / 1e9)
            if TRACER.enabled:
                root = ctx.run_span
                TRACER.emit(span_name, "stage", ts=t0, dur=dur,
                            proc=proc, stream=ctx.stream,
                            chunk=task.index,
                            parent=root.sid if root is not None
                            else None)
    return wrapper


# ---------------------------------------------------------------------------
# Schedulers
# ---------------------------------------------------------------------------

class SequentialScheduler:
    """Reference scheduling: every stage of chunk k completes before
    chunk k+1 starts — the PR-1 chunked engine, stage graph edition."""

    def start(self, ctx: _RunContext, tasks: List[ChunkTask],
              stages: Dict[str, Callable]):
        return iter(tasks)

    def cancel(self, ctx: _RunContext, handle) -> None:
        pass                          # nothing runs ahead

    def drain(self, ctx: _RunContext, handle,
              stages: Dict[str, Callable]) -> None:
        for task in handle:
            for name in STAGES:
                task = stages[name](ctx, task)


class StreamingScheduler:
    """DECODE runs ahead on a pool of ``workers`` background threads
    with a bounded hand-off queue; PROXY/DETECT/TRACK run on the
    draining thread in chunk order.

    With one worker the queue itself preserves chunk order.  With a
    pool, workers claim chunk indices from a shared iterator and a
    reorder gate admits each decoded chunk to the queue only when every
    earlier chunk has been enqueued — so the draining thread (and with
    it TRACK) still sees chunks strictly in frame order, and tracks
    stay bit-identical to the single-thread schedule for any pool size
    (tests/test_executor.py).  A worker holds at most one decoded chunk
    while waiting at the gate, so in-flight host memory is bounded by
    ``depth + workers`` chunks."""

    def __init__(self, depth: int = 2, workers: int = 1):
        self.depth = max(1, int(depth))
        self.workers = max(1, int(workers))

    def start(self, ctx: _RunContext, tasks: List[ChunkTask],
              stages: Dict[str, Callable]):
        q: "queue.Queue" = queue.Queue(maxsize=self.depth)
        stop = threading.Event()
        it = iter(enumerate(tasks))
        it_lock = threading.Lock()
        gate = threading.Condition()
        state = {"next": 0, "failed": False}

        def worker():
            while not stop.is_set():
                with it_lock:
                    nxt = next(it, None)
                if nxt is None:
                    return
                i, task = nxt
                try:
                    decoded = stages["decode"](ctx, task)
                except BaseException as exc:    # surfaced by drain()
                    with gate:
                        state["failed"] = True
                        gate.notify_all()
                    q.put(_WorkerFailure(exc))
                    return
                with gate:
                    while state["next"] != i and not stop.is_set() \
                            and not state["failed"]:
                        gate.wait(0.05)
                    if stop.is_set() or state["failed"]:
                        return
                # this chunk's turn: the bounded put happens outside the
                # gate (it may block on a full queue), and successors
                # cannot pass until "next" advances below
                q.put(decoded)
                with gate:
                    state["next"] += 1
                    gate.notify_all()

        threads = [threading.Thread(target=worker, daemon=True,
                                    name=f"multiscope-decode-{k}")
                   for k in range(min(self.workers, max(len(tasks), 1)))]
        for th in threads:
            th.start()
        return q, threads, len(tasks), stop

    def cancel(self, ctx: _RunContext, handle) -> None:
        """Stop the decode workers and discard whatever they produced.
        A worker may be blocked in ``q.put`` on the full bounded queue,
        so keep consuming until every thread exits — a bare ``join``
        would deadlock.  (Gate waiters poll ``stop`` on a timeout.)"""
        q, threads, _, stop = handle
        stop.set()
        while any(th.is_alive() for th in threads):
            try:
                q.get(timeout=0.05)
            except queue.Empty:
                pass
        for th in threads:
            th.join()

    def drain(self, ctx: _RunContext, handle,
              stages: Dict[str, Callable]) -> None:
        q, threads, n, _ = handle
        try:
            for _ in range(n):
                item = q.get()
                if isinstance(item, _WorkerFailure):
                    raise item.exc
                task = item
                for name in STAGES[1:]:
                    task = stages[name](ctx, task)
        except BaseException:
            # a stage failed mid-stream: unblock the producers before
            # propagating, or a q.put on the full queue never returns
            self.cancel(ctx, handle)
            raise
        for th in threads:
            th.join()


class _PoolRun:
    """One run's state inside a shared ``DecodePool``: a bounded output
    queue plus a per-run reorder gate (chunks are admitted strictly in
    chunk order, whichever pool worker decoded them first)."""

    def __init__(self, ctx: "_RunContext", tasks: List[ChunkTask],
                 stages: Dict[str, Callable], depth: int):
        self.ctx = ctx
        self.tasks = tasks
        self.stages = stages
        self.q: "queue.Queue" = queue.Queue(maxsize=max(1, depth))
        self.gate = threading.Condition()
        self.next = 0               # chunk index admitted next
        self.remaining = len(tasks)  # jobs not yet enqueued or dropped
        self.failed = False
        self.cancelled = False

    def _account(self) -> None:
        with self.gate:
            self.remaining -= 1
            self.gate.notify_all()


class DecodePool:
    """Persistent decode workers shared by several in-flight runs.

    ``run_clips`` keeps (at most) two clips in flight; with per-run
    workers that is ``2 * decode_workers`` threads, churned on every
    clip boundary.  The pool owns ONE set of ``workers`` threads for
    its whole lifetime: each run submits its chunks as jobs on a shared
    FIFO, and a per-run reorder gate (``_PoolRun``) recovers chunk
    order before the bounded hand-off queue — so the draining thread,
    and with it TRACK, still sees every run's chunks strictly in frame
    order and tracks stay bit-identical to the dedicated-worker
    schedule for any pool size (tests/test_executor.py).

    Jobs of different runs interleave in submission order, which is
    exactly the decode order the two-in-flight ``run_clips`` loop
    wants: clip i's remaining chunks first, then clip i+1's.  A worker
    blocked on one run's full output queue parks with a timeout, so a
    ``cancel`` of that run (or its drain making progress) always
    releases it; cancelling a run drops its undecoded jobs on the floor
    as workers reach them.

    Discipline: runs sharing a pool must be DRAINED in submission order
    (or cancelled) — ``run_clips`` and the segment ingestor both do.  A
    later-submitted run drained first could starve behind an earlier
    run's full bounded queue that nobody is consuming.
    """

    def __init__(self, workers: int = 2):
        self.workers = max(1, int(workers))
        self._jobs: "queue.Queue" = queue.Queue()
        self._closed = False
        # /healthz backpressure signal: undecoded jobs on the shared
        # FIFO (qsize is advisory, which is all a health grade needs)
        self._m_queue_depth = REGISTRY.gauge(
            "executor.decode.queue_depth")
        self._threads = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"multiscope-pool-decode-{k}")
            for k in range(self.workers)]
        for th in self._threads:
            th.start()

    def submit(self, ctx: "_RunContext", tasks: List[ChunkTask],
               stages: Dict[str, Callable], depth: int) -> _PoolRun:
        if self._closed:
            # jobs enqueued after close would never run and the run's
            # drain would hang on an empty queue forever — fail fast
            raise RuntimeError("DecodePool is closed")
        run = _PoolRun(ctx, tasks, stages, depth)
        for i, task in enumerate(tasks):
            self._jobs.put((run, i, task))
        self._m_queue_depth.set(self._jobs.qsize())
        return run

    def cancel(self, run: _PoolRun) -> None:
        """Drop the run: undecoded jobs are discarded as workers reach
        them, and the output queue is drained so no shared worker stays
        blocked on it.  Returns once every job is accounted for."""
        with run.gate:
            run.cancelled = True
            run.gate.notify_all()
        while True:
            with run.gate:
                if run.remaining <= 0:
                    return
            try:
                run.q.get(timeout=0.02)
            except queue.Empty:
                pass

    def close(self) -> None:
        """Stop the workers (idempotent).  Outstanding runs must be
        drained or cancelled first."""
        if self._closed:
            return
        self._closed = True
        for _ in self._threads:
            self._jobs.put(None)
        for th in self._threads:
            th.join()

    # -- worker side ----------------------------------------------------------

    def _put(self, run: _PoolRun, item) -> None:
        while not run.cancelled:
            try:
                run.q.put(item, timeout=0.05)
                return
            except queue.Full:
                pass

    def _worker(self) -> None:
        while True:
            job = self._jobs.get()
            self._m_queue_depth.set(self._jobs.qsize())
            if job is None:
                return
            run, i, task = job
            try:
                self._decode_one(run, i, task)
            finally:
                run._account()

    def _decode_one(self, run: _PoolRun, i: int,
                    task: ChunkTask) -> None:
        if run.cancelled or run.failed:
            return                      # dropped job
        try:
            decoded = run.stages["decode"](run.ctx, task)
        except BaseException as exc:    # surfaced by drain()
            with run.gate:
                run.failed = True
                run.gate.notify_all()
            self._put(run, _WorkerFailure(exc))
            return
        with run.gate:
            while run.next != i and not run.cancelled and not run.failed:
                run.gate.wait(0.05)
            if run.cancelled or run.failed:
                return
        self._put(run, decoded)
        with run.gate:
            run.next += 1
            run.gate.notify_all()


class PooledStreamingScheduler:
    """The streaming schedule with decode on a shared ``DecodePool``
    instead of per-run threads.  Drain semantics (and therefore tracks)
    are identical to ``StreamingScheduler``."""

    def __init__(self, pool: DecodePool, depth: int = 2):
        self.pool = pool
        self.depth = max(1, int(depth))

    def start(self, ctx: "_RunContext", tasks: List[ChunkTask],
              stages: Dict[str, Callable]) -> _PoolRun:
        return self.pool.submit(ctx, tasks, stages, self.depth)

    def cancel(self, ctx: "_RunContext", run: _PoolRun) -> None:
        self.pool.cancel(run)

    def drain(self, ctx: "_RunContext", run: _PoolRun,
              stages: Dict[str, Callable]) -> None:
        try:
            for _ in range(len(run.tasks)):
                item = run.q.get()
                if isinstance(item, _WorkerFailure):
                    raise item.exc
                task = item
                for name in STAGES[1:]:
                    task = stages[name](ctx, task)
        except BaseException:
            # unblock any pool worker parked on this run's queue before
            # propagating (shared workers must outlive a failed run)
            self.pool.cancel(run)
            raise


# ---------------------------------------------------------------------------
# The executor
# ---------------------------------------------------------------------------

@dataclass
class _ActiveRun:
    """A clip whose DECODE may already be running ahead."""
    ctx: _RunContext
    handle: object


class ClipExecutor:
    """Execute θ over clips through the stage graph.

    ``stages`` lets a caller swap any stage implementation (the
    pluggable part); ``options`` picks the scheduler and device
    placement.  ``start``/``finish`` expose the two-phase form so
    ``run_clips`` can overlap clip i+1's decode with clip i's compute.
    """

    def __init__(self, bank: ModelBank, params: PipelineParams,
                 options: Optional[ExecutorOptions] = None,
                 stages: Optional[Dict[str, Callable]] = None,
                 scheduler=None):
        self.bank = bank
        self.params = params
        self.options = options or ExecutorOptions()
        self.stages = dict(DEFAULT_STAGES)
        if stages:
            self.stages.update(stages)
        self.stages = {name: _timed(name, fn)
                       for name, fn in self.stages.items()}
        if scheduler is not None:
            self.scheduler = scheduler
        elif self.options.decode_pool is not None and self.options.prefetch:
            self.scheduler = PooledStreamingScheduler(
                self.options.decode_pool, self.options.prefetch_depth)
        elif self.options.prefetch:
            self.scheduler = StreamingScheduler(
                self.options.prefetch_depth, self.options.decode_workers)
        else:
            self.scheduler = SequentialScheduler()

    def _tasks(self, ctx: _RunContext) -> List[ChunkTask]:
        ids = ctx.frame_ids
        return [ChunkTask(i, ids[c0:c0 + ctx.chunk])
                for i, c0 in enumerate(range(0, len(ids), ctx.chunk))]

    def start(self, clip: Clip, device_offset: int = 0, *,
              frame_ids: Optional[Sequence[int]] = None,
              tracker: Optional[object] = None) -> _ActiveRun:
        """Start a run.  ``frame_ids``/``tracker`` are the resume hooks
        used by the live-ingestion path (``repro.stream``): run only an
        explicit frame slice, feeding an existing tracker whose state
        carries across segment runs."""
        ctx = _RunContext(self.bank, self.params, clip, self.options,
                          device_offset=device_offset,
                          frame_ids=frame_ids, tracker=tracker)
        handle = self.scheduler.start(ctx, self._tasks(ctx), self.stages)
        return _ActiveRun(ctx, handle)

    def cancel(self, run: _ActiveRun) -> None:
        """Abandon a started run: stop its decode worker, drop its
        broker registration (pending broker requests are cancelled
        without affecting other streams) and release everything it
        buffered."""
        try:
            self.scheduler.cancel(run.ctx, run.handle)
        finally:
            run.ctx.close()

    def finish(self, run: _ActiveRun) -> RunResult:
        ctx = run.ctx
        t0 = time.process_time()
        try:
            self.scheduler.drain(ctx, run.handle, self.stages)
        except BaseException as exc:
            # black box: a no-op unless a FlightRecorder is installed
            crash_dump("executor.drain", exc,
                       extra={"stream": ctx.stream,
                              "frames": len(ctx.frame_ids),
                              "chunk": ctx.chunk})
            raise
        finally:
            ctx.close()
        tracks = ctx.tracker.result()
        if ctx.params.refine and ctx.bank.refiner is not None:
            tracks = [ctx.bank.refiner.refine(t) for t in tracks]
        seconds = time.process_time() - t0 + max(ctx.charged, 0.0)
        stage_seconds = ctx.profile.stage_seconds()
        track_disp = int(getattr(ctx.tracker, "dispatches", 0)) \
            - ctx._disp_track0 + ctx.profile.dispatches("embed")
        dispatches = {"proxy": ctx.profile.dispatches("proxy"),
                      "detect": ctx.profile.dispatches("detect"),
                      "track": track_disp}
        ctx.profile.disp["track"] = track_disp
        ctx.profile.publish()
        return RunResult(tracks, seconds, len(ctx.frame_ids),
                         ctx.n_windows, ctx.full_frames, ctx.skipped,
                         stage_seconds=stage_seconds,
                         dispatches=dispatches,
                         proxy_fracs=ctx.proxy_fracs)

    def run(self, clip: Clip) -> RunResult:
        return self.finish(self.start(clip))


def run_clip_streamed(bank: ModelBank, params: PipelineParams,
                      clip: Clip,
                      options: Optional[ExecutorOptions] = None
                      ) -> RunResult:
    """One clip through the streaming executor (prefetch on by
    default).  Tracks and counters are bit-identical to
    ``pipeline.run_clip_frames``."""
    return ClipExecutor(bank, params, options).run(clip)


def run_clips(bank: ModelBank, params: PipelineParams,
              clips: Sequence[Clip],
              options: Optional[ExecutorOptions] = None
              ) -> Tuple[List[RunResult], float]:
    """Multi-clip sweep (the experiment driver's test-split loop).

    Clips are independent through DETECT, so with prefetch enabled clip
    i+1's decode workers are started while clip i is still draining, and
    each clip's chunks round-robin the device list from a per-clip
    offset — on a multi-device mesh, consecutive clips land on
    different devices.  With ``options.share_decode_pool`` (the
    default) the two in-flight clips share ONE ``DecodePool`` of
    ``max(2, decode_workers)`` persistent workers with per-clip reorder
    gates — no thread churn at clip boundaries, and total decode
    threads are the pool size rather than ``2 * decode_workers``
    (tracks stay bit-identical for any pool size; an
    ``options.decode_pool`` supplied by the caller is reused as-is and
    left open).  TRACK state never crosses clips, and per-clip seconds
    keep the process-time + ledger semantics (decode CPU spent early is
    counted once, in whichever window it ran)."""
    opts = options or ExecutorOptions()
    own_pool: Optional[DecodePool] = None
    if opts.prefetch and len(clips) > 1 and opts.share_decode_pool \
            and opts.decode_pool is None:
        own_pool = DecodePool(max(2, opts.decode_workers))
        import dataclasses as _dc
        opts = _dc.replace(opts, decode_pool=own_pool)
    ex = ClipExecutor(bank, params, opts)
    results: List[RunResult] = []
    try:
        if not opts.prefetch or len(clips) <= 1:
            for i, clip in enumerate(clips):
                results.append(ex.finish(ex.start(clip, device_offset=i)))
            return results, sum(r.seconds for r in results)
        pending: List[_ActiveRun] = [ex.start(clips[0], device_offset=0)]
        try:
            for i in range(1, len(clips)):
                # one clip of decode lookahead: prefetch_depth chunks max
                pending.append(ex.start(clips[i], device_offset=i))
                results.append(ex.finish(pending.pop(0)))
            results.append(ex.finish(pending.pop(0)))
        except BaseException:
            # the failed clip's own worker was stopped by drain; clips
            # started ahead still have live workers that would otherwise
            # block forever holding decoded chunks and device buffers
            for run in pending:
                ex.cancel(run)
            raise
        return results, sum(r.seconds for r in results)
    finally:
        if own_pool is not None:
            own_pool.close()
