"""SORT heuristic tracker (Bewley et al. 2016, simplified): constant-
velocity prediction + IoU Hungarian matching.

Used (a) inside θ_best selection — the paper bootstraps proxy/tracker
training labels with SORT because the learned tracker does not exist yet —
and (b) as the tracking stage of the Chameleon baseline and the MultiScope
ablation's "+SORT" variant.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core.detector import iou_matrix
from repro.core.hungarian import hungarian, BIG


@dataclass
class Track:
    track_id: int
    frames: List[int] = field(default_factory=list)
    boxes: List[np.ndarray] = field(default_factory=list)   # (4,) world
    misses: int = 0

    def predict(self, frame: int) -> np.ndarray:
        """Constant-velocity extrapolation to ``frame``."""
        if len(self.boxes) < 2:
            return self.boxes[-1]
        dt = self.frames[-1] - self.frames[-2]
        if dt <= 0:
            return self.boxes[-1]
        vel = (self.boxes[-1][:2] - self.boxes[-2][:2]) / dt
        pred = self.boxes[-1].copy()
        pred[:2] = pred[:2] + vel * (frame - self.frames[-1])
        return pred

    def as_array(self) -> np.ndarray:
        """(n, 6) [frame, cx, cy, w, h, track_id]."""
        out = np.zeros((len(self.frames), 6), np.float32)
        out[:, 0] = self.frames
        out[:, 1:5] = np.stack(self.boxes)
        out[:, 5] = self.track_id
        return out


class SortTracker:
    def __init__(self, iou_threshold: float = 0.15, max_misses: int = 2,
                 min_hits: int = 2):
        self.iou_threshold = iou_threshold
        self.max_misses = max_misses
        self.min_hits = min_hits
        self.active: List[Track] = []
        self.finished: List[Track] = []
        self._next_id = 0

    def step(self, frame: int, dets: np.ndarray,
             pixels: Optional[np.ndarray] = None,
             det_embeds: Optional[np.ndarray] = None) -> None:
        """dets: (n, >=4) [cx, cy, w, h, ...] world units.  ``pixels``
        and ``det_embeds`` are accepted (and ignored) for interface
        parity with the recurrent tracker."""
        del pixels, det_embeds
        preds = np.stack([t.predict(frame) for t in self.active]) \
            if self.active else np.zeros((0, 4), np.float32)
        iou = iou_matrix(preds, dets[:, :4]) if len(dets) else \
            np.zeros((len(preds), 0), np.float32)
        cost = np.where(iou >= self.iou_threshold, 1.0 - iou, BIG)
        pairs = hungarian(cost)
        matched_t = set()
        matched_d = set()
        for ti, di in pairs:
            t = self.active[ti]
            t.frames.append(frame)
            t.boxes.append(dets[di, :4].astype(np.float32))
            t.misses = 0
            matched_t.add(ti)
            matched_d.add(di)
        # age out unmatched tracks
        survivors = []
        for ti, t in enumerate(self.active):
            if ti in matched_t:
                survivors.append(t)
                continue
            t.misses += 1
            if t.misses > self.max_misses:
                self.finished.append(t)
            else:
                survivors.append(t)
        self.active = survivors
        # new tracks for unmatched detections
        for di in range(len(dets)):
            if di in matched_d:
                continue
            t = Track(self._next_id)
            t.frames.append(frame)
            t.boxes.append(dets[di, :4].astype(np.float32))
            self.active.append(t)
            self._next_id += 1

    def result(self) -> List[np.ndarray]:
        """All tracks with >= min_hits detections, as (n, 6) arrays."""
        tracks = self.finished + self.active
        return [t.as_array() for t in tracks
                if len(t.frames) >= self.min_hits]
