"""θ_best selection and the joint greedy parameter tuner (§3.3, §3.5).

Workflow (Figure 1):
  1.  detectors are pre-trained (the paper's pretrained-YOLO stand-in);
  2.  θ_best = best-accuracy configuration, found by greedy descent:
      start at max resolution / native rate with the SORT tracker, then
      keep reducing resolution (then sampling rate) while validation
      accuracy does not drop;
  3.  θ_best outputs on the TRAIN split become labels for the proxy models
      and the recurrent tracker, and the source for window-size selection
      and the start/end refiner (no ground truth anywhere);
  4.  caching phase: the detection module measures (arch x resolution)
      time/accuracy; the proxy module caches per-resolution score grids on
      the validation set and derives (resolution, threshold) ->
      (est. runtime, recall) tables; the tracking module is analytic;
  5.  greedy loop: from θ_1 = θ_best, each iteration asks all three
      modules for a ~S=30% faster candidate, evaluates each candidate's
      real validation accuracy, keeps the best, and emits the
      speed-accuracy curve Θ.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.multiscope import PipelineConfig
from repro.core import pipeline as pl
from repro.core.detector import Detector
from repro.core.metrics import clip_count_accuracy
from repro.core.proxy import (ProxyModel, cells_from_detections,
                              proxy_loss, sweep_candidates)
from repro.core.refine import TrackRefiner
from repro.core.tracker import build_examples, train_tracker
from repro.core.train_models import _fit, train_detector
from repro.core.windows import (detector_time_model, group_cells,
                                select_window_sizes)
from repro.data.video_synth import Clip


@dataclass
class TunerPoint:
    params: pl.PipelineParams
    val_accuracy: float
    val_seconds: float
    module: str = "init"


@dataclass
class TunedSystem:
    bank: pl.ModelBank
    theta_best: pl.PipelineParams
    curve: List[TunerPoint]
    setup_seconds: Dict[str, float] = field(default_factory=dict)


_WARMED: set = set()


def _evaluate(bank: pl.ModelBank, params: pl.PipelineParams,
              clips: Sequence[Clip]) -> Tuple[float, float]:
    # warm jit caches on the first clip so compile time never pollutes
    # the measured runtime (the paper measures steady-state execution);
    # memoized per shape class (chunk size changes padded batch shapes,
    # so it is part of the class) so grid searches stay cheap
    key = (params.det_arch, params.det_res, params.proxy_res,
           params.tracker, params.chunk_size)
    if key not in _WARMED:
        _WARMED.add(key)
        pl.run_clip(bank, params, clips[0])
    results, seconds = pl.run_split(bank, params, clips)
    accs = [clip_count_accuracy(r.tracks, c)
            for r, c in zip(results, clips)]
    return float(np.mean(accs)), seconds


def _measure_det_times(bank: pl.ModelBank, cfg: PipelineConfig) -> None:
    import jax.numpy as jnp
    for arch, det in bank.detectors.items():
        for res in cfg.detector.resolutions:
            W, H = res
            frame = np.zeros((1, H, W, 3), np.float32)
            det.detect_batch(frame, 0.5)          # compile
            t0 = time.process_time()
            for _ in range(3):
                det.detect_batch(frame, 0.5)
            bank.det_times[(arch, res)] = (time.process_time() - t0) / 3


def setup(cfg: PipelineConfig, train_clips: Sequence[Clip],
          val_clips: Sequence[Clip], *, detector_steps: int = 400,
          proxy_steps: int = 120, tracker_steps: int = 1500,
          log: Callable[[str], None] = print) -> TunedSystem:
    timings: Dict[str, float] = {}

    # -- 1. detector pre-training ----------------------------------------------
    t0 = time.process_time()
    detectors = {}
    for arch in cfg.detector.archs:
        det, _ = train_detector(arch, train_clips,
                                list(cfg.detector.resolutions),
                                steps=detector_steps)
        detectors[arch] = det
    bank = pl.ModelBank(cfg, detectors)
    _measure_det_times(bank, cfg)
    timings["detector_train"] = time.process_time() - t0
    log(f"[setup] detectors trained in {timings['detector_train']:.1f}s")

    # -- 2. θ_best selection (§3.3) ---------------------------------------------
    t0 = time.process_time()
    arch = cfg.detector.archs[-1]          # deepest = most accurate start
    resolutions = list(cfg.detector.resolutions)
    conf = cfg.detector.confidences[1]   # 0.55
    EPS = 0.02           # eval-noise tolerance for "accuracy decreased"
    cur = pl.PipelineParams(det_arch=arch, det_res=resolutions[0],
                            det_conf=conf, gap=1, tracker="sort",
                            refine=False)
    best_cfg, best_acc_seen = cur, _evaluate(bank, cur, val_clips)[0]
    # resolution descent: stop at the first decrease, keep the ARGMAX
    # ("keep the resolution providing the best achieved accuracy", §3.3)
    acc = best_acc_seen
    for res in resolutions[1:]:
        cand = replace(cur, det_res=res)
        a, _ = _evaluate(bank, cand, val_clips)
        if a > best_acc_seen:
            best_cfg, best_acc_seen = cand, a
        if a < acc - EPS:
            break
        cur, acc = cand, a
    cur, acc = best_cfg, best_acc_seen
    # rate descent, same argmax semantics.  θ_best is also the LABELING
    # configuration (proxy/tracker training + refiner paths), so the
    # descent is capped at gap 2: sparser labels starve the trained
    # modules, and the tuner's tracking module explores higher gaps
    # during tuning anyway.
    for g in [g for g in cfg.tracker.gaps if 1 < g <= 2]:
        cand = replace(cur, gap=g)
        a, _ = _evaluate(bank, cand, val_clips)
        if a > best_acc_seen:
            best_cfg, best_acc_seen = cand, a
        if a < acc - EPS:
            break
        acc = a
    theta_best = best_cfg
    acc = best_acc_seen
    timings["theta_best"] = time.process_time() - t0
    log(f"[setup] θ_best = {theta_best.describe()} acc={acc:.3f} "
        f"({timings['theta_best']:.1f}s)")

    # -- 3. θ_best outputs on the train split ------------------------------------
    t0 = time.process_time()
    train_dets: List[Tuple[Clip, int, np.ndarray]] = []
    train_tracks: List[np.ndarray] = []
    tracks_by_clip: List[Tuple[Clip, List[np.ndarray]]] = []
    det = bank.detectors[theta_best.det_arch]
    for clip in train_clips:
        res = pl.run_clip(bank, theta_best, clip)
        train_tracks.extend(res.tracks)
        tracks_by_clip.append((clip, res.tracks))
        for f in range(0, clip.n_frames, theta_best.gap):
            frame = clip.render(f, *theta_best.det_res)
            dets = det.detect_batch(frame[None], theta_best.det_conf)[0]
            train_dets.append((clip, f, dets))
    timings["theta_best_labels"] = time.process_time() - t0

    # -- 4. proxy training on θ_best detections ----------------------------------
    t0 = time.process_time()
    import jax.numpy as jnp
    from repro.optim import adamw
    for res in cfg.proxy.resolutions:
        W, H = res
        hc, wc = H // cfg.proxy.cell, W // cfg.proxy.cell
        proxy = ProxyModel(cfg.proxy.cell, cfg.proxy.base_channels, res)
        frames, labels = [], []
        for clip, f, dets in train_dets:
            if len(dets) == 0 and np.random.default_rng(f).random() > 0.3:
                continue                      # paper trains on |D|>0 frames
            frames.append(clip.render(f, W, H))
            labels.append(cells_from_detections(dets, hc, wc))
        if not frames:
            continue
        frames = np.stack(frames)
        labels = np.stack(labels)
        rng = np.random.default_rng(0)

        def batches():
            for _ in range(proxy_steps):
                idx = rng.integers(len(frames), size=16)
                yield (jnp.asarray(frames[idx]), jnp.asarray(labels[idx]))

        params, _ = _fit(
            lambda p, fr, lb: proxy_loss(p, fr, lb, cfg.proxy.cell),
            proxy.params, batches(), lr=3e-3)
        proxy.params = params
        bank.proxies[res] = proxy
    timings["proxy_train"] = time.process_time() - t0
    log(f"[setup] {len(bank.proxies)} proxies trained in "
        f"{timings['proxy_train']:.1f}s")

    # -- 5. window-size set selection (§3.3) --------------------------------------
    t0 = time.process_time()
    grid = pl.det_grid(theta_best.det_res)
    grids = [cells_from_detections(d, grid[1], grid[0])
             for (_, _, d) in train_dets if len(d)]
    t_full = bank.det_times[(theta_best.det_arch, theta_best.det_res)]
    tm = detector_time_model(grid, t_full)
    bank.sizes_cells = select_window_sizes(
        grids[:60], grid, cfg.windows.k, tm,
        max_windows=cfg.windows.max_windows)
    bank.ref_grid = grid
    timings["window_sizes"] = time.process_time() - t0
    log(f"[setup] window sizes S = {bank.sizes_cells} "
        f"({timings['window_sizes']:.1f}s)")

    # -- 6. recurrent tracker training (§3.4) -------------------------------------
    t0 = time.process_time()

    def frame_getter_for(clip):
        # goes through the bounded LRU render cache instead of an
        # unbounded per-setup dict (same fix as experiment.run_dataset)
        def get(f):
            return pl.render_frame(clip, f, *theta_best.det_res)[0]
        return get

    examples = []
    for clip, tracks in tracks_by_clip:
        examples.extend(build_examples(
            tracks, frame_getter_for(clip), cfg.tracker.crop,
            clip_key=clip.clip_id))
    params, tr_losses = train_tracker(cfg.tracker, examples,
                                      steps=tracker_steps)
    bank.tracker_params = params
    timings["tracker_train"] = time.process_time() - t0
    log(f"[setup] tracker trained on {len(examples)} tracks in "
        f"{timings['tracker_train']:.1f}s")

    # -- 7. refiner ---------------------------------------------------------------
    bank.refiner = TrackRefiner(cfg.refine, train_tracks,
                                frame_scale=1.0 / theta_best.det_res[0])

    sys = TunedSystem(bank, theta_best, [], timings)
    return sys


# ---------------------------------------------------------------------------
# Module proposal caches (§3.5.1-3.5.3)
# ---------------------------------------------------------------------------

@dataclass
class DetectionCache:
    entries: Dict[Tuple[str, Tuple[int, int]], Tuple[float, float]]
    # (arch, res) -> (runtime secs on val, accuracy)

    def propose(self, cur: pl.PipelineParams, speedup: float
                ) -> Optional[pl.PipelineParams]:
        t_cur = self.entries.get((cur.det_arch, cur.det_res))
        if t_cur is None:
            return None
        budget = (1.0 - speedup) * t_cur[0]
        best = None
        for (arch, res), (t, a) in self.entries.items():
            if t <= budget and (best is None or a > best[0]):
                best = (a, arch, res)
        if best is None:
            return None
        return replace(cur, det_arch=best[1], det_res=best[2])


@dataclass
class ProxyCache:
    # (res, threshold) -> (est frame seconds, recall)
    entries: Dict[Tuple[Tuple[int, int], float], Tuple[float, float]]
    t_frame_full: float          # detector-only full-frame seconds

    def propose(self, cur: pl.PipelineParams, speedup: float
                ) -> Optional[pl.PipelineParams]:
        if cur.proxy_res is None:
            t_cur = self.t_frame_full
        else:
            t_cur = self.entries.get(
                (cur.proxy_res, cur.proxy_threshold),
                (self.t_frame_full, 0))[0]
        budget = (1.0 - speedup) * t_cur
        best = None
        for (res, th), (t, recall) in self.entries.items():
            if t <= budget and (best is None or recall > best[0]):
                best = (recall, res, th)
        if best is None:
            return None
        return replace(cur, proxy_res=best[1], proxy_threshold=best[2])


def build_caches(sys: TunedSystem, val_clips: Sequence[Clip],
                 log=print) -> Tuple[DetectionCache, ProxyCache]:
    bank, cfg = sys.bank, sys.bank.cfg
    theta = sys.theta_best
    det_entries = {}
    for arch in cfg.detector.archs:
        for res in cfg.detector.resolutions:
            cand = replace(theta, det_arch=arch, det_res=res)
            a, secs = _evaluate(bank, cand, val_clips)
            det_entries[(arch, res)] = (secs, a)
    # proxy cache: score grids cached per resolution, swept over thresholds
    proxy_entries = {}
    det = bank.detectors[theta.det_arch]
    grid = pl.det_grid(theta.det_res)
    # θ_best detections on val frames (recall reference)
    val_frames = []
    for clip in val_clips[:4]:
        for f in range(0, clip.n_frames, max(theta.gap, 2)):
            frame = clip.render(f, *theta.det_res)
            dets = det.detect_batch(frame[None], theta.det_conf)[0]
            val_frames.append((frame, dets))
    for res, proxy in bank.proxies.items():
        t_proxy = _time_proxy(proxy)
        score_grids = [proxy.scores(pl._downsample(fr, res), 0.5)[0]
                       for fr, _ in val_frames]
        # the paper's threshold sweep runs over these CACHED score
        # grids: the configured menu plus quantiles of the trained
        # proxy's actual score distribution, so calibration tracks what
        # the proxy learned instead of a fixed grid that may be
        # all-positive or all-negative for a given training run
        thresholds = sweep_candidates(score_grids,
                                      cfg.proxy.thresholds)
        for th in thresholds:
            covered = total = 0
            est_t = 0.0
            cand_params = replace(theta, proxy_res=res,
                                  proxy_threshold=th)
            sizeset = pl.make_sizeset(bank, cand_params)
            for (fr, dets), sg in zip(val_frames, score_grids):
                pos = (sg > th).astype(np.int8)
                cell_grid = pl.map_proxy_grid(pos, grid)
                windows = group_cells(cell_grid, sizeset,
                                      cfg.windows.max_windows)
                est_t += t_proxy + sizeset.est(windows)
                total += len(dets)
                covered += _covered(dets, windows, grid)
            recall = covered / max(total, 1)
            proxy_entries[(res, th)] = (est_t / max(len(val_frames), 1),
                                        recall)
    t_full = bank.det_times[(theta.det_arch, theta.det_res)]
    return (DetectionCache(det_entries),
            ProxyCache(proxy_entries, t_full))


def _covered(dets: np.ndarray, windows, grid) -> int:
    n = 0
    for d in dets:
        cx, cy = d[0], d[1]
        j = int(cx * grid[0])
        i = int(cy * grid[1])
        for (x, y, (w, h)) in windows:
            if x <= j < x + w and y <= i < y + h:
                n += 1
                break
    return n


def _time_proxy(proxy: ProxyModel) -> float:
    frame = np.zeros((proxy.resolution[1], proxy.resolution[0], 3),
                     np.float32)
    proxy.scores(frame, 0.5)
    t0 = time.process_time()
    for _ in range(3):
        proxy.scores(frame, 0.5)
    return (time.process_time() - t0) / 3


# ---------------------------------------------------------------------------
# The greedy loop (§3.5)
# ---------------------------------------------------------------------------

MAX_TUNED_CHUNK = 64      # B ceiling for the scheduler module


def propose_chunk(cur: pl.PipelineParams
                  ) -> Optional[pl.PipelineParams]:
    """Scheduler-module proposal: double the executor chunk size B.

    Sparse / skip-heavy θ (large gap, or proxy gating on) amortize the
    fixed per-chunk dispatch overhead — proxy dispatch, window
    planning, bucket padding — over more frames.  Tracks are
    bit-identical across B by construction, so the candidate can only
    win the greedy iteration on the runtime tiebreak, never by
    accuracy noise."""
    from repro.core.executor import DEFAULT_CHUNK
    B = cur.chunk_size or DEFAULT_CHUNK
    if B >= MAX_TUNED_CHUNK:
        return None
    if cur.gap < 2 and cur.proxy_res is None:
        return None                 # dense full-frame θ: B=16 is ample
    return replace(cur, chunk_size=B * 2)


def tune(sys: TunedSystem, val_clips: Sequence[Clip],
         log=print) -> List[TunerPoint]:
    cfg = sys.bank.cfg
    S = cfg.tuner.speedup_per_iter
    det_cache, proxy_cache = build_caches(sys, val_clips, log)
    cand_r = replace(sys.theta_best, tracker="recurrent", refine=True)
    acc_r, secs_r = _evaluate(sys.bank, cand_r, val_clips)
    cand_s = replace(sys.theta_best, tracker="sort", refine=True)
    acc_s, secs_s = _evaluate(sys.bank, cand_s, val_clips)
    if acc_r >= acc_s:
        cur, acc, secs = cand_r, acc_r, secs_r
    else:
        cur, acc, secs = cand_s, acc_s, secs_s
    curve = [TunerPoint(cur, acc, secs, "init")]
    log(f"[tune] init {cur.describe()} acc={acc:.3f} t={secs:.1f}s")
    gaps = list(cfg.tracker.gaps)
    for it in range(cfg.tuner.max_iters):
        candidates: List[Tuple[str, pl.PipelineParams]] = []
        c = det_cache.propose(cur, S)
        if c is not None and c != cur:
            candidates.append(("detection", c))
        c = proxy_cache.propose(cur, S)
        if c is not None and c != cur:
            candidates.append(("proxy", c))
        # tracking module: g_new = next pow2 >= g / (1-S)
        target = cur.gap / (1.0 - S)
        bigger = [g for g in gaps if g >= target]
        if bigger:
            candidates.append(("tracking", replace(cur, gap=bigger[0])))
        # scheduler module: larger executor chunks for sparse θ
        c = propose_chunk(cur)
        if c is not None:
            candidates.append(("scheduler", c))
        if not candidates:
            log("[tune] no module can propose a faster config; stop")
            break
        evals = []
        for mod, cand in candidates:
            a, t = _evaluate(sys.bank, cand, val_clips)
            log(f"[tune]  iter {it} {mod:10s} {cand.describe()} "
                f"acc={a:.3f} t={t:.1f}s")
            if mod == "scheduler" and t >= secs * 0.95:
                # a scheduler candidate is accuracy-IDENTICAL to cur by
                # construction, so an accuracy-sorted pick would adopt
                # it over every speed-for-accuracy trade regardless of
                # runtime; admit it only on a clear (>5%, beyond this
                # machine's timing noise) runtime win over the current
                # point
                continue
            evals.append((a, t, mod, cand))
        if not evals:
            log("[tune] no candidate improved; stop")
            break
        # best accuracy first, measured runtime breaks ties
        evals.sort(key=lambda e: (-e[0], e[1]))
        a, t, mod, cur = evals[0]
        secs = t
        curve.append(TunerPoint(cur, a, t, mod))
    sys.curve = curve
    return curve
