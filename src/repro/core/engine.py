"""Chunked execution engine — PR-1 compatibility surface over the
streaming executor.

PR 1 introduced the staged chunked engine: decode → proxy → windows →
detector → tracker in chunks of B frames, cross-frame size-class
batching with power-of-two bucket padding, window crops through the
``window_gather_batch`` Pallas kernel, and chunk-batched tracker crop
embeddings.  That stage logic now lives in ``repro.core.executor`` as
an explicit stage graph (DECODE / PROXY / DETECT / TRACK) with
pluggable schedulers; this module keeps the original entry point:

  * ``run_clip_chunked`` — the SEQUENTIAL scheduler (no prefetch, no
    double buffering): every stage of chunk k completes before chunk
    k+1 starts, exactly the PR-1 semantics.  Tracks are bit-identical
    to ``pipeline.run_clip_frames`` (tests/test_engine.py) AND to the
    streaming scheduler (tests/test_executor.py); only scheduling
    differs.

New code should use ``repro.core.executor`` directly
(``run_clip_streamed`` / ``run_clips`` / ``ClipExecutor``), which adds
async decode prefetch, double-buffered device uploads, and shard-aware
chunk dispatch on top of the same stages.
"""
from __future__ import annotations

from typing import Optional

from repro.core.executor import (DEFAULT_CHUNK, ClipExecutor,
                                 ExecutorOptions)
from repro.core.pipeline import ModelBank, PipelineParams, RunResult
from repro.data.video_synth import Clip


def run_clip_chunked(bank: ModelBank, params: PipelineParams, clip: Clip,
                     chunk_size: Optional[int] = None) -> RunResult:
    """Chunked counterpart of ``pipeline.run_clip_frames``: identical
    tracks and counters, a fraction of the dispatches.  ``chunk_size``
    overrides θ's ``PipelineParams.chunk_size`` (default B=16)."""
    opts = ExecutorOptions(prefetch=False, double_buffer=False,
                           chunk_size=chunk_size)
    return ClipExecutor(bank, params, opts).run(clip)
