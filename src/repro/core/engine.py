"""Staged chunked execution engine: cross-frame batching for the
MultiScope pipeline.

The per-frame reference path (``pipeline.run_clip_frames``) pays one
proxy dispatch and one detector dispatch *per size class per frame*.
This engine restructures one clip into chunks of B frames and runs four
stages per chunk:

  1. DECODE   — render B frames at detector resolution, charging the
                decode-cost ledger exactly as the per-frame path does;
  2. PROXY    — one batched ``proxy_scores`` dispatch for the whole chunk
                (the kernel already takes a batch dim), then host-side
                grid mapping;
  3. DETECT   — windows planned for the whole chunk on the host
                (``windows.plan_chunk``), then the detector runs on
                CROSS-FRAME batches grouped by size class.  Window crops
                are block gathers through the ``window_gather_batch``
                Pallas kernel (vmapped dynamic_slice off-TPU).  Batch
                counts are zero-padded to power-of-two buckets so jit
                specializations stay one per (arch, size class, bucket);
  4. TRACK    — detections feed the tracker in frame order; candidate
                detection embeddings are batched per chunk
                (``tracker.embed_dets_chunk``) and bucket-padded.

Because conv/matmul outputs are per-sample independent of batch size and
zero padding, the engine's tracks are BIT-IDENTICAL to the per-frame
path's (asserted by tests/test_engine.py); only the dispatch count
changes.  Timing semantics are unchanged: ``RunResult.seconds`` is
process time plus the charged decode ledger.

This staging is the structural prerequisite for async prefetch (stage 1
overlapping stage 3) and multi-device sharding (chunks across devices):
both slot in at the chunk boundary without touching per-frame logic.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.detector import next_bucket, nms
from repro.core.pipeline import (CELL_PX, ModelBank, PipelineParams,
                                 RunResult, det_grid, downsample_chunk,
                                 make_sizeset, map_proxy_grid,
                                 render_frame)
from repro.core.sort import SortTracker
from repro.core.tracker import RecurrentTracker, crop_embed_chunk
from repro.core.windows import (ChunkPlan, SizeSet, full_frame_plan,
                                plan_chunk)
from repro.data.video_synth import Clip
from repro.kernels.window_gather import window_gather_batch

DEFAULT_CHUNK = 16     # frames per chunk (B): one proxy dispatch each


def _detect_chunk(bank: ModelBank, params: PipelineParams,
                  frames: np.ndarray, chunk_size: int, plan: ChunkPlan,
                  sizeset: SizeSet) -> List[np.ndarray]:
    """Stage 3: run the detector on cross-frame batches grouped by size
    class; reassemble per-frame detections in the exact order the
    per-frame path would have produced them.  The chunk is uploaded to
    the device at most once (lazily — all-full-frame plans, e.g. with
    the proxy off, never pay it) and shared by every gather; it is
    zero-padded to ``chunk_size`` frames so the gather jit sees one
    (B, H, W, C) shape."""
    detector = bank.detectors[params.det_arch]
    W, H = params.det_res
    frames_dev = None
    per_window: Dict[Tuple[int, int], np.ndarray] = {}
    for size, entries in plan.by_size.items():
        pw, ph = size[0] * CELL_PX, size[1] * CELL_PX
        n = len(entries)
        origins = [(x * CELL_PX / W, y * CELL_PX / H)
                   for (_, x, y, _) in entries]
        scales = [(pw / W, ph / H)] * n
        if (pw, ph) == (W, H):
            # full-frame windows: the crop is the frame itself
            stack = frames[[slot for (slot, _, _, _) in entries]]
            dets = detector.detect_batch_bucketed(
                stack, params.det_conf, origins=origins, scales=scales)
        else:
            if frames_dev is None:
                padded = np.zeros((chunk_size, H, W, 3), np.float32)
                padded[:frames.shape[0]] = frames
                frames_dev = jnp.asarray(padded)
            tbl = np.zeros((next_bucket(n), 3), np.int32)
            for k, (slot, x, y, _) in enumerate(entries):
                tbl[k] = (slot, y, x)
            crops = window_gather_batch(frames_dev, tbl,
                                        win_h=ph, win_w=pw, cell=CELL_PX)
            # crops stay device-side: detect_batch feeds them straight
            # into the detector without a host round-trip
            dets = detector.detect_batch(
                crops, params.det_conf, origins=origins,
                scales=scales, n_valid=n)
        for (slot, _, _, wi), d in zip(entries, dets):
            per_window[(slot, wi)] = d

    merged: List[np.ndarray] = []
    for slot, wins in enumerate(plan.windows):
        if not wins:
            merged.append(np.zeros((0, 5), np.float32))
        elif len(wins) == 1 and wins[0][2] == sizeset.full:
            # the per-frame fast path applies no cross-window NMS
            merged.append(per_window[(slot, 0)])
        else:
            by_size_frame: Dict[Tuple[int, int], List[int]] = {}
            for wi, (_, _, s) in enumerate(wins):
                by_size_frame.setdefault(s, []).append(wi)
            parts = [per_window[(slot, wi)]
                     for wis in by_size_frame.values() for wi in wis]
            merged.append(nms(np.concatenate(parts)))
    return merged


def run_clip_chunked(bank: ModelBank, params: PipelineParams, clip: Clip,
                     chunk_size: int = DEFAULT_CHUNK) -> RunResult:
    """Chunked counterpart of ``pipeline.run_clip_frames``: identical
    tracks and counters, a fraction of the dispatches."""
    import time

    cfg = bank.cfg
    W, H = params.det_res
    proxy = bank.proxies.get(params.proxy_res) \
        if params.proxy_res is not None else None
    sizeset = make_sizeset(bank, params)
    grid = det_grid(params.det_res)
    if params.tracker == "recurrent" and bank.tracker_params is not None:
        tracker: object = RecurrentTracker(cfg.tracker,
                                           bank.tracker_params)
    else:
        tracker = SortTracker()
    batch_embed = isinstance(tracker, RecurrentTracker)

    frame_ids = list(range(0, clip.n_frames, params.gap))
    n_windows = full_frames = skipped = 0
    decode_charged = 0.0
    t0 = time.process_time()
    for c0 in range(0, len(frame_ids), chunk_size):
        ids = frame_ids[c0:c0 + chunk_size]
        B = len(ids)

        # stage 1: decode at detector resolution, charging the ledger
        frames = np.empty((B, H, W, 3), np.float32)
        for k, f in enumerate(ids):
            t_r = time.process_time()
            frame, cost = render_frame(clip, f, W, H)
            decode_charged += cost - (time.process_time() - t_r)
            frames[k] = frame
        # stage 2: proxy-score the whole chunk in one dispatch (the
        # nearest-neighbor downsample is one gather for the chunk)
        if proxy is not None:
            pframes = downsample_chunk(frames, proxy.resolution)
            _, pos = proxy.scores_batch(pframes, params.proxy_threshold)
            grids = [map_proxy_grid(p, grid) for p in pos]
            plan = plan_chunk(grids, sizeset, cfg.windows.max_windows)
        else:
            plan = full_frame_plan(B, sizeset)

        # stage 3: cross-frame bucketed detection
        dets_per_frame = _detect_chunk(bank, params, frames, chunk_size,
                                       plan, sizeset)

        for wins in plan.windows:
            n_windows += len(wins)
            if len(wins) == 1 and wins[0][2] == sizeset.full:
                full_frames += 1
            if not wins:
                skipped += 1

        # stage 4: tracker in frame order; the crop CNN runs once for
        # the whole chunk, te-dependent features derive host-side
        if batch_embed:
            embeds = crop_embed_chunk(bank.tracker_params, cfg.tracker,
                                      frames, dets_per_frame)
            for k, f in enumerate(ids):
                tracker.step(f, dets_per_frame[k], frames[k],
                             det_embeds=embeds[k])
        else:
            for k, f in enumerate(ids):
                tracker.step(f, dets_per_frame[k], frames[k])

    tracks = tracker.result()
    if params.refine and bank.refiner is not None:
        tracks = [bank.refiner.refine(t) for t in tracks]
    seconds = time.process_time() - t0 + max(decode_charged, 0.0)
    return RunResult(tracks, seconds, len(frame_ids), n_windows,
                     full_frames, skipped)
