"""Track start/end refinement (§3.4, Figure 4).

Tracks captured at reduced rates first/last appear somewhere mid-path;
instead of Miris' extra detector passes, MultiScope estimates the true
start/end from SIMILAR TRACKS in the training set:

  1. θ_best training-set tracks are resampled to N evenly spaced points
     and clustered with DBSCAN under the mean point-to-point distance;
  2. cluster centers (average paths) go into a spatial grid index keyed by
     the cells their endpoints' neighborhoods touch;
  3. at inference, a track looks up centers passing near its first/last
     detection, takes the k nearest clusters (a cluster of n tracks counts
     n times), and extends itself to the size-weighted median start/end.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.multiscope import RefineConfig


def resample_track(boxes: np.ndarray, n: int) -> np.ndarray:
    """boxes: (m, >=2) rows with [cx, cy, ...] -> (n, 2) evenly spaced
    points along the polyline (arc length)."""
    pts = boxes[:, :2].astype(np.float64)
    if len(pts) == 1:
        return np.repeat(pts, n, axis=0)
    seg = np.linalg.norm(np.diff(pts, axis=0), axis=1)
    cum = np.concatenate([[0.0], np.cumsum(seg)])
    total = cum[-1]
    if total <= 0:
        return np.repeat(pts[:1], n, axis=0)
    targets = np.linspace(0.0, total, n)
    # per-target segment index: j = #{k in [1, len(seg)-1] : cum[k] < d}
    # (what the old scan loop computed), one vectorized searchsorted
    # over the cumulative arc length; outputs are bit-identical because
    # the interpolation arithmetic below is unchanged
    j = np.searchsorted(cum[1:len(seg)], targets, side="left")
    segj = seg[j]
    with np.errstate(divide="ignore", invalid="ignore"):
        u = np.where(segj == 0.0, 0.0, (targets - cum[j]) / segj)
    return pts[j] * (1.0 - u)[:, None] + pts[j + 1] * u[:, None]


def track_distance(a: np.ndarray, b: np.ndarray) -> float:
    """Mean euclidean distance between corresponding resampled points."""
    return float(np.linalg.norm(a - b, axis=1).mean())


def dbscan_tracks(paths: List[np.ndarray], eps: float, min_pts: int
                  ) -> List[List[int]]:
    """DBSCAN over resampled tracks (distance = track_distance).  Returns
    clusters as lists of indices; noise points become singletons."""
    n = len(paths)
    if n == 0:
        return []
    stacked = np.stack(paths)                      # (n, N, 2)
    # pairwise mean distances (n small: hundreds)
    diff = stacked[:, None] - stacked[None]        # (n, n, N, 2)
    dist = np.linalg.norm(diff, axis=-1).mean(-1)  # (n, n)
    neighbors = [np.flatnonzero(dist[i] <= eps) for i in range(n)]
    core = [len(nb) >= min_pts for nb in neighbors]
    labels = np.full(n, -1, np.int64)
    cid = 0
    for i in range(n):
        if labels[i] != -1 or not core[i]:
            continue
        labels[i] = cid
        stack = list(neighbors[i])
        while stack:
            j = stack.pop()
            if labels[j] == -1:
                labels[j] = cid
                if core[j]:
                    stack.extend(neighbors[j])
        cid += 1
    clusters = [list(np.flatnonzero(labels == c)) for c in range(cid)]
    clusters += [[i] for i in np.flatnonzero(labels == -1)]
    return clusters


@dataclass
class PathCluster:
    center: np.ndarray           # (N, 2)
    size: int


class TrackRefiner:
    def __init__(self, cfg: RefineConfig, train_tracks: Sequence[np.ndarray],
                 frame_scale: float = 1.0):
        """train_tracks: θ_best tracks as (m, 6) [frame, cx, cy, w, h, id]
        arrays, world units.  eps/grid_cell in cfg are in PIXELS of a
        reference frame; frame_scale converts to world units (1/width)."""
        self.cfg = cfg
        n = cfg.n_points
        eps = cfg.dbscan_eps * frame_scale
        paths = [resample_track(t[:, 1:3], n) for t in train_tracks
                 if len(t) >= 2]
        clusters = dbscan_tracks(paths, eps, cfg.dbscan_min_pts)
        self.clusters: List[PathCluster] = []
        for idxs in clusters:
            center = np.mean([paths[i] for i in idxs], axis=0)
            self.clusters.append(PathCluster(center, len(idxs)))
        # spatial grid index over cluster-center points
        self.cell = cfg.grid_cell * frame_scale
        self.index: Dict[Tuple[int, int], List[int]] = defaultdict(list)
        for ci, c in enumerate(self.clusters):
            seen = set()
            for p in c.center:
                key = (int(p[0] // self.cell), int(p[1] // self.cell))
                if key not in seen:
                    seen.add(key)
                    self.index[key].append(ci)

    def _lookup(self, p: np.ndarray) -> List[int]:
        """Cluster ids whose center passes near point p (3x3 cells)."""
        kx, ky = int(p[0] // self.cell), int(p[1] // self.cell)
        out = []
        for dx in (-1, 0, 1):
            for dy in (-1, 0, 1):
                out.extend(self.index.get((kx + dx, ky + dy), ()))
        return sorted(set(out))

    def refine(self, track: np.ndarray) -> np.ndarray:
        """track: (m, 6) — returns the track with an extrapolated start
        and end row prepended/appended (median of kNN cluster endpoints,
        weighted by cluster size)."""
        if len(track) < 2 or not self.clusters:
            return track
        path = resample_track(track[:, 1:3], self.cfg.n_points)
        cand = sorted(set(self._lookup(path[0]) + self._lookup(path[-1])))
        if not cand:
            return track
        dists = [(track_distance(path, self.clusters[ci].center), ci)
                 for ci in cand]
        dists.sort()
        starts, ends, weights = [], [], []
        total = 0
        for d, ci in dists:
            c = self.clusters[ci]
            # orient the cluster center along the track's direction
            if np.linalg.norm(c.center[0] - path[0]) <= \
                    np.linalg.norm(c.center[-1] - path[0]):
                s, e = c.center[0], c.center[-1]
            else:
                s, e = c.center[-1], c.center[0]
            starts.append(s)
            ends.append(e)
            weights.append(c.size)
            total += c.size
            if total >= self.cfg.knn:
                break
        w = np.asarray(weights, np.float64)
        start = _weighted_median(np.stack(starts), w)
        end = _weighted_median(np.stack(ends), w)
        first, last = track[0].copy(), track[-1].copy()
        first[1:3] = start
        last[1:3] = end
        return np.concatenate([first[None], track, last[None]], axis=0)


def _weighted_median(pts: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Per-dimension weighted median of (n, 2) points."""
    out = np.empty(2)
    for d in range(2):
        order = np.argsort(pts[:, d])
        cw = np.cumsum(w[order])
        idx = np.searchsorted(cw, cw[-1] / 2.0)
        out[d] = pts[order[min(idx, len(order) - 1)], d]
    return out
