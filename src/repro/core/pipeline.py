"""The MultiScope execution pipeline (Figure 2): decode -> proxy ->
windows -> detector -> recurrent tracker -> refinement.

One ``PipelineParams`` instance is one tuner configuration θ; ``run_clip``
executes θ over a clip, measures real wall time (decode/render cost scales
with detector resolution, matching the paper's ffmpeg observation), and
returns extracted tracks.

Execution engines: ``run_clip`` dispatches to the STREAMING stage-graph
executor (``repro.core.executor``) by default — frames are decoded and
proxy-scored in chunks of B frames per dispatch (B = θ's tuner-visible
``chunk_size``), decode for chunk k+1 prefetches on a background thread
while chunk k is in proxy/detect, device uploads are double-buffered,
windows are planned for the whole chunk on the host, the detector runs
on cross-frame batches grouped by size class (batch counts padded to
power-of-two buckets so jit specializations stay one per (arch, size
class, bucket)), and detections feed the tracker in frame order with
candidate embeddings batched per chunk.  engine="chunked" runs the same
stages on the sequential scheduler (the PR-1 engine);
``run_clip_frames`` keeps the strictly per-frame reference path.  All
engines produce identical tracks (asserted by tests/test_engine.py and
tests/test_executor.py) and the same decode-cost ledger / ``RunResult``
counters.

Cell grid convention: the canonical positive-cell grid is the DETECTOR
resolution divided by ``cell_px`` (16 in the reduced pipeline, 32 at full
scale).  Proxy models run at their own lower resolution; their cell grids
are mapped onto the detector grid with max-pooling semantics (a detector
cell is positive if ANY overlapping proxy cell is positive).  The fixed
window-size set S is selected once in cell units at a reference detector
resolution and rescaled fractionally to others.  Window crops are block
DMAs through the ``window_gather`` Pallas kernel (vmapped dynamic_slice
off-TPU), never host-side slice loops.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.multiscope import PipelineConfig
from repro.core.detector import Detector, next_bucket, nms
from repro.kernels.window_gather import window_gather
from repro.core.proxy import ProxyModel
from repro.core.refine import TrackRefiner
from repro.core.sort import SortTracker
from repro.core.tracker import RecurrentTracker
from repro.core.windows import SizeSet, Window, group_cells
from repro.data.video_synth import Clip

CELL_PX = 16      # detector-grid cell edge at detector resolution (px)

# bounded LRU render cache: the tuner re-evaluates the same validation
# frames under many configurations; decode cost must still be CHARGED per
# run (the paper's decode-at-detector-resolution cost), so every call
# returns (frame, decode_seconds) and run_clip adds the charged cost to
# its timing ledger whether or not the pixels came from cache.  The
# executor's decode prefetch renders from a background thread, so cache
# access is locked and the recorded cost is THREAD CPU time (identical
# to process time in the single-threaded paths, and not polluted by
# concurrently running stages otherwise).
_RENDER_CACHE: "OrderedDict[Tuple, Tuple[np.ndarray, float]]" = \
    OrderedDict()
_RENDER_CACHE_MAX = 4096
_RENDER_LOCK = threading.Lock()


def render_frame(clip: "Clip", f: int, W: int, H: int
                 ) -> Tuple[np.ndarray, float]:
    """-> (frame, charged decode seconds)."""
    key = (clip.profile.name, clip.split, clip.clip_id, f, W, H)
    with _RENDER_LOCK:
        hit = _RENDER_CACHE.get(key)
        if hit is not None:
            _RENDER_CACHE.move_to_end(key)
            return hit
    t0 = time.thread_time()
    frame = clip.render(f, W, H)
    cost = time.thread_time() - t0
    with _RENDER_LOCK:
        _RENDER_CACHE[key] = (frame, cost)
        if len(_RENDER_CACHE) > _RENDER_CACHE_MAX:
            _RENDER_CACHE.popitem(last=False)
    return frame, cost


@dataclass(frozen=True)
class PipelineParams:
    """One point θ in the tuner's search space."""
    det_arch: str
    det_res: Tuple[int, int]                  # (W, H)
    det_conf: float
    gap: int = 1
    proxy_res: Optional[Tuple[int, int]] = None    # None -> no proxy
    proxy_threshold: float = 0.5
    tracker: str = "recurrent"                     # recurrent | sort
    refine: bool = True
    # frames per executor chunk (B); None -> executor.DEFAULT_CHUNK.
    # Scheduling-only: tracks are bit-identical across B, so the tuner's
    # scheduler module proposes larger chunks for sparse/skip-heavy θ
    # purely on runtime.
    chunk_size: Optional[int] = None

    def describe(self) -> str:
        p = "off" if self.proxy_res is None else \
            f"{self.proxy_res[0]}x{self.proxy_res[1]}@{self.proxy_threshold}"
        b = "" if self.chunk_size is None else f" B={self.chunk_size}"
        return (f"det={self.det_arch}@{self.det_res[0]}x{self.det_res[1]}"
                f" conf={self.det_conf} gap={self.gap} proxy={p}"
                f" trk={self.tracker}{b}")


@dataclass
class ModelBank:
    """Everything trained offline for one dataset."""
    cfg: PipelineConfig
    detectors: Dict[str, Detector]
    proxies: Dict[Tuple[int, int], ProxyModel] = field(default_factory=dict)
    tracker_params: Optional[dict] = None
    sizes_cells: Optional[List[Tuple[int, int]]] = None  # S at ref grid
    ref_grid: Optional[Tuple[int, int]] = None           # (wc, hc) of ref
    det_times: Dict = field(default_factory=dict)        # (arch,W,H)->s
    win_times: Dict = field(default_factory=dict)        # (arch,size)->s
    refiner: Optional[TrackRefiner] = None


def make_tracker(bank: ModelBank, params: PipelineParams,
                 device_assign: bool = False,
                 device_tracker: bool = False):
    """θ's tracker instance — THE selection rule (recurrent iff θ asks
    for it and the bank has trained tracker params, SORT otherwise).
    Every execution path (per-frame reference, executor stage graph,
    live segment ingest) must construct trackers through here, or the
    stream's segment-append == one-shot bit-identity contract breaks
    on the day one copy diverges.

    ``device_assign``/``device_tracker`` mirror ``ExecutorOptions``:
    the per-frame step as one fused kernel dispatch, or the whole-chunk
    ``lax.scan`` tracker (``DeviceTracker``).  Both produce tracks
    BIT-identical to the host tracker, so they are scheduling knobs
    like the rest of the options — never part of θ."""
    if params.tracker == "recurrent" and bank.tracker_params is not None:
        if device_tracker:
            from repro.core.tracker import DeviceTracker
            return DeviceTracker(bank.cfg.tracker, bank.tracker_params)
        return RecurrentTracker(
            bank.cfg.tracker, bank.tracker_params,
            assign="device" if device_assign else "host")
    return SortTracker()


def det_grid(res: Tuple[int, int]) -> Tuple[int, int]:
    W, H = res
    return W // CELL_PX, H // CELL_PX


def map_proxy_grid(pos: np.ndarray, grid: Tuple[int, int]) -> np.ndarray:
    """(hp, wp) proxy grid -> (hc, wc) detector grid, max-pool semantics.

    A detector cell (i, j) is positive iff ANY proxy cell in the
    (possibly overlapping) source span [ys_i, ye_i) x [xs_j, xe_j) is.
    Vectorized with a 2D integral image: span-any == span-count > 0."""
    wc, hc = grid
    hp, wp = pos.shape
    ys = np.minimum((np.arange(hc) * hp) // hc, hp - 1)
    ye = np.minimum(((np.arange(hc) + 1) * hp + hp - 1) // hc, hp)
    ye = np.maximum(ye, ys + 1)
    xs = np.minimum((np.arange(wc) * wp) // wc, wp - 1)
    xe = np.minimum(((np.arange(wc) + 1) * wp + wp - 1) // wc, wp)
    xe = np.maximum(xe, xs + 1)
    acc = np.zeros((hp + 1, wp + 1), np.int64)
    acc[1:, 1:] = np.cumsum(np.cumsum(pos != 0, axis=0), axis=1)
    cnt = acc[ye[:, None], xe[None, :]] - acc[ys[:, None], xe[None, :]] \
        - acc[ye[:, None], xs[None, :]] + acc[ys[:, None], xs[None, :]]
    return (cnt > 0).astype(np.int8)


def scale_sizes(sizes_cells: Sequence[Tuple[int, int]],
                ref_grid: Tuple[int, int], grid: Tuple[int, int]
                ) -> List[Tuple[int, int]]:
    """Rescale the cell-unit size set fractionally to another grid; the
    first entry is forced to the new full frame."""
    rw, rh = ref_grid
    wc, hc = grid
    out: List[Tuple[int, int]] = [(wc, hc)]
    for (w, h) in sizes_cells[1:]:
        sw = max(1, min(wc, int(round(w * wc / rw))))
        sh = max(1, min(hc, int(round(h * hc / rh))))
        if (sw, sh) not in out:
            out.append((sw, sh))
    return out


def measure_window_time(bank: ModelBank, arch: str,
                        size: Tuple[int, int]) -> float:
    """MEASURED detector seconds for one window size (the paper times
    each of the k fixed sizes after initializing the detector at them)."""
    key = (arch, size)
    if key not in bank.win_times:
        import time as _t
        det = bank.detectors[arch]
        frame = np.zeros((1, size[1] * CELL_PX, size[0] * CELL_PX, 3),
                         np.float32)
        det.detect_batch(frame, 0.5)          # jit warm
        t0 = _t.process_time()
        for _ in range(3):
            det.detect_batch(frame, 0.5)
        bank.win_times[key] = (_t.process_time() - t0) / 3
    return bank.win_times[key]


def make_sizeset(bank: ModelBank, params: PipelineParams) -> SizeSet:
    """Size set + MEASURED per-size detector times for this θ."""
    grid = det_grid(params.det_res)
    if bank.sizes_cells is None:
        sizes = [grid]
    else:
        sizes = scale_sizes(bank.sizes_cells, bank.ref_grid, grid)
    times = {s: measure_window_time(bank, params.det_arch, s)
             for s in sizes}
    return SizeSet(sizes, times)


def _downsample_indices(shape_hw: Tuple[int, int], res: Tuple[int, int]
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Nearest-neighbor (ys, xs) index vectors — the ONE formula both
    the per-frame and chunked proxy paths must share (the engines' track
    bit-identity depends on it)."""
    W, H = res
    ys = (np.arange(H) * shape_hw[0]) // H
    xs = (np.arange(W) * shape_hw[1]) // W
    return ys, xs


def _downsample(frame: np.ndarray, res: Tuple[int, int]) -> np.ndarray:
    """Nearest-neighbor resize (host-side, cheap)."""
    ys, xs = _downsample_indices(frame.shape[:2], res)
    return frame[np.ix_(ys, xs)]


def downsample_chunk(frames: np.ndarray, res: Tuple[int, int]
                     ) -> np.ndarray:
    """Batched ``_downsample``: (B, H, W, 3) -> (B, h, w, 3) in one
    gather, identical per-frame values."""
    ys, xs = _downsample_indices(frames.shape[1:3], res)
    return frames[:, ys[:, None], xs[None, :]]


@dataclass
class RunResult:
    tracks: List[np.ndarray]
    seconds: float
    frames_processed: int
    detector_windows: int        # total windows run through the detector
    full_frames: int             # of which full-frame applications
    skipped_frames: int          # frames with zero windows
    # per-stage profile, populated by the executor (None on the
    # per-frame reference path): stage -> {"wall": s, "process": s},
    # where "process" is CPU actually spent in the stage's thread(s)
    stage_seconds: Optional[Dict[str, Dict[str, float]]] = None
    # device dispatches per stage ("proxy" plan/score calls, "detect"
    # detector batches, "track" tracker kernel + crop-CNN calls)
    dispatches: Optional[Dict[str, int]] = None
    # per-frame proxy positive-cell fractions, collected by the executor
    # only while drift monitoring is enabled (obs.enable_drift); the
    # ingestor's per-stream DriftMonitor consumes them
    proxy_fracs: Optional[List[float]] = None


def detect_with_windows(bank: ModelBank, params: PipelineParams,
                        frame: np.ndarray, sizeset: SizeSet,
                        proxy: Optional[ProxyModel],
                        max_windows: int) -> Tuple[np.ndarray, List[Window]]:
    """Proxy-gated detection on one frame.  Returns (dets, windows)."""
    detector = bank.detectors[params.det_arch]
    grid = det_grid(params.det_res)
    if proxy is None:
        dets = detector.detect_batch(frame[None], params.det_conf)[0]
        return dets, [(0, 0, (grid[0], grid[1]))]
    pframe = _downsample(frame, proxy.resolution)
    _, pos = proxy.scores(pframe, params.proxy_threshold)
    cell_grid = map_proxy_grid(pos, grid)
    windows = group_cells(cell_grid, sizeset, max_windows)
    if not windows:
        return np.zeros((0, 5), np.float32), []
    full = sizeset.full
    if len(windows) == 1 and windows[0][2] == full:
        dets = detector.detect_batch(frame[None], params.det_conf)[0]
        return dets, windows
    # batch windows by size class (the paper's fixed-size batching);
    # crops are block gathers through the window_gather kernel, with the
    # batch dim bucket-padded so jit stays one entry per (size, bucket)
    by_size: Dict[Tuple[int, int], List[Window]] = {}
    for wdw in windows:
        by_size.setdefault(wdw[2], []).append(wdw)
    all_dets = []
    W, H = params.det_res
    for size, wins in by_size.items():
        pw, ph = size[0] * CELL_PX, size[1] * CELL_PX
        n = len(wins)
        tbl = np.zeros((next_bucket(n), 2), np.int32)
        for k, (x, y, _) in enumerate(wins):
            tbl[k] = (y, x)
        crops = window_gather(frame, tbl, win_h=ph, win_w=pw,
                              cell=CELL_PX)
        origins = [(x * CELL_PX / W, y * CELL_PX / H)
                   for (x, y, _) in wins]
        scales = [(pw / W, ph / H)] * n
        # crops stay device-side; detect_batch accepts them directly
        dets = detector.detect_batch(crops, params.det_conf,
                                     origins=origins, scales=scales,
                                     n_valid=n)
        all_dets.extend(dets)
    merged = np.concatenate(all_dets) if all_dets else \
        np.zeros((0, 5), np.float32)
    return nms(merged), windows


def run_clip(bank: ModelBank, params: PipelineParams, clip: Clip,
             engine: str = "streaming") -> RunResult:
    """Execute θ over a clip.  engine:

      * "streaming" (default) — the stage-graph executor in
        ``repro.core.executor`` with async decode prefetch and
        double-buffered device uploads;
      * "chunked"             — the same stage graph on the sequential
        scheduler (the PR-1 engine);
      * "frame"               — the strictly per-frame reference path.

    All three produce identical tracks and counters (asserted by
    tests/test_engine.py and tests/test_executor.py)."""
    if engine == "streaming":
        from repro.core.executor import run_clip_streamed
        return run_clip_streamed(bank, params, clip)
    if engine == "chunked":
        from repro.core.engine import run_clip_chunked
        return run_clip_chunked(bank, params, clip)
    if engine != "frame":
        raise ValueError(f"unknown engine {engine!r} (expected "
                         "'streaming', 'chunked' or 'frame')")
    return run_clip_frames(bank, params, clip)


def run_clip_frames(bank: ModelBank, params: PipelineParams, clip: Clip
                    ) -> RunResult:
    """The strictly per-frame reference path: one proxy dispatch and one
    detector dispatch per size class PER FRAME."""
    cfg = bank.cfg
    W, H = params.det_res
    proxy = bank.proxies.get(params.proxy_res) \
        if params.proxy_res is not None else None
    sizeset = make_sizeset(bank, params)
    tracker = make_tracker(bank, params)
    n_windows = full_frames = skipped = processed = 0
    decode_charged = 0.0
    t0 = time.process_time()
    for f in range(0, clip.n_frames, params.gap):
        # thread_time brackets match render_frame's cost clock: a
        # process_time bracket would also count OTHER threads' CPU
        # (e.g. a concurrent executor's decode worker) and push the
        # charge negative
        t_r = time.thread_time()
        frame, cost = render_frame(clip, f, W, H)   # decode @ det res
        decode_charged += cost - (time.thread_time() - t_r)
        dets, windows = detect_with_windows(
            bank, params, frame, sizeset, proxy, cfg.windows.max_windows)
        n_windows += len(windows)
        if len(windows) == 1 and windows[0][2] == sizeset.full:
            full_frames += 1
        if not windows:
            skipped += 1
        tracker.step(f, dets, frame)
        processed += 1
    tracks = tracker.result()
    if params.refine and bank.refiner is not None:
        tracks = [bank.refiner.refine(t) for t in tracks]
    seconds = time.process_time() - t0 + max(decode_charged, 0.0)
    return RunResult(tracks, seconds, processed, n_windows, full_frames,
                     skipped)


def run_split(bank: ModelBank, params: PipelineParams,
              clips: Sequence[Clip], engine: str = "streaming"
              ) -> Tuple[List[RunResult], float]:
    """Run θ over a whole split.  The streaming engine dispatches the
    split through ``executor.run_clips`` so clip i+1's decode overlaps
    clip i's compute (and clips round-robin devices on a multi-device
    host); other engines run clips back to back."""
    if engine == "streaming":
        from repro.core.executor import run_clips
        return run_clips(bank, params, clips)
    results = [run_clip(bank, params, c, engine=engine) for c in clips]
    return results, sum(r.seconds for r in results)
