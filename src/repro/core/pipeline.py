"""The MultiScope execution pipeline (Figure 2): decode -> proxy ->
windows -> detector -> recurrent tracker -> refinement.

One ``PipelineParams`` instance is one tuner configuration θ; ``run_clip``
executes θ over a clip, measures real wall time (decode/render cost scales
with detector resolution, matching the paper's ffmpeg observation), and
returns extracted tracks.

Cell grid convention: the canonical positive-cell grid is the DETECTOR
resolution divided by ``cell_px`` (16 in the reduced pipeline, 32 at full
scale).  Proxy models run at their own lower resolution; their cell grids
are mapped onto the detector grid with max-pooling semantics (a detector
cell is positive if ANY overlapping proxy cell is positive).  The fixed
window-size set S is selected once in cell units at a reference detector
resolution and rescaled fractionally to others.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.multiscope import PipelineConfig
from repro.core.detector import Detector, nms
from repro.core.proxy import ProxyModel
from repro.core.refine import TrackRefiner
from repro.core.sort import SortTracker
from repro.core.tracker import RecurrentTracker
from repro.core.windows import SizeSet, Window, group_cells
from repro.data.video_synth import Clip

CELL_PX = 16      # detector-grid cell edge at detector resolution (px)

# bounded render cache: the tuner re-evaluates the same validation frames
# under many configurations; decode cost must still be CHARGED per run
# (the paper's decode-at-detector-resolution cost), so every call returns
# (frame, decode_seconds) and run_clip adds the charged cost to its timing
# ledger whether or not the pixels came from cache.
_RENDER_CACHE: Dict[Tuple, Tuple[np.ndarray, float]] = {}
_RENDER_CACHE_MAX = 4096


def render_frame(clip: "Clip", f: int, W: int, H: int
                 ) -> Tuple[np.ndarray, float]:
    """-> (frame, charged decode seconds)."""
    key = (clip.profile.name, clip.split, clip.clip_id, f, W, H)
    hit = _RENDER_CACHE.get(key)
    if hit is not None:
        return hit
    t0 = time.process_time()
    frame = clip.render(f, W, H)
    cost = time.process_time() - t0
    if len(_RENDER_CACHE) < _RENDER_CACHE_MAX:
        _RENDER_CACHE[key] = (frame, cost)
    return frame, cost


@dataclass(frozen=True)
class PipelineParams:
    """One point θ in the tuner's search space."""
    det_arch: str
    det_res: Tuple[int, int]                  # (W, H)
    det_conf: float
    gap: int = 1
    proxy_res: Optional[Tuple[int, int]] = None    # None -> no proxy
    proxy_threshold: float = 0.5
    tracker: str = "recurrent"                     # recurrent | sort
    refine: bool = True

    def describe(self) -> str:
        p = "off" if self.proxy_res is None else \
            f"{self.proxy_res[0]}x{self.proxy_res[1]}@{self.proxy_threshold}"
        return (f"det={self.det_arch}@{self.det_res[0]}x{self.det_res[1]}"
                f" conf={self.det_conf} gap={self.gap} proxy={p}"
                f" trk={self.tracker}")


@dataclass
class ModelBank:
    """Everything trained offline for one dataset."""
    cfg: PipelineConfig
    detectors: Dict[str, Detector]
    proxies: Dict[Tuple[int, int], ProxyModel] = field(default_factory=dict)
    tracker_params: Optional[dict] = None
    sizes_cells: Optional[List[Tuple[int, int]]] = None  # S at ref grid
    ref_grid: Optional[Tuple[int, int]] = None           # (wc, hc) of ref
    det_times: Dict = field(default_factory=dict)        # (arch,W,H)->s
    win_times: Dict = field(default_factory=dict)        # (arch,size)->s
    refiner: Optional[TrackRefiner] = None


def det_grid(res: Tuple[int, int]) -> Tuple[int, int]:
    W, H = res
    return W // CELL_PX, H // CELL_PX


def map_proxy_grid(pos: np.ndarray, grid: Tuple[int, int]) -> np.ndarray:
    """(hp, wp) proxy grid -> (hc, wc) detector grid, max-pool semantics."""
    wc, hc = grid
    hp, wp = pos.shape
    out = np.zeros((hc, wc), np.int8)
    ys = np.minimum((np.arange(hc) * hp) // hc, hp - 1)
    ye = np.minimum(((np.arange(hc) + 1) * hp + hp - 1) // hc, hp)
    xs = np.minimum((np.arange(wc) * wp) // wc, wp - 1)
    xe = np.minimum(((np.arange(wc) + 1) * wp + wp - 1) // wc, wp)
    for i in range(hc):
        row = pos[ys[i]:max(ye[i], ys[i] + 1)]
        for j in range(wc):
            if row[:, xs[j]:max(xe[j], xs[j] + 1)].any():
                out[i, j] = 1
    return out


def scale_sizes(sizes_cells: Sequence[Tuple[int, int]],
                ref_grid: Tuple[int, int], grid: Tuple[int, int]
                ) -> List[Tuple[int, int]]:
    """Rescale the cell-unit size set fractionally to another grid; the
    first entry is forced to the new full frame."""
    rw, rh = ref_grid
    wc, hc = grid
    out: List[Tuple[int, int]] = [(wc, hc)]
    for (w, h) in sizes_cells[1:]:
        sw = max(1, min(wc, int(round(w * wc / rw))))
        sh = max(1, min(hc, int(round(h * hc / rh))))
        if (sw, sh) not in out:
            out.append((sw, sh))
    return out


def measure_window_time(bank: ModelBank, arch: str,
                        size: Tuple[int, int]) -> float:
    """MEASURED detector seconds for one window size (the paper times
    each of the k fixed sizes after initializing the detector at them)."""
    key = (arch, size)
    if key not in bank.win_times:
        import time as _t
        det = bank.detectors[arch]
        frame = np.zeros((1, size[1] * CELL_PX, size[0] * CELL_PX, 3),
                         np.float32)
        det.detect_batch(frame, 0.5)          # jit warm
        t0 = _t.process_time()
        for _ in range(3):
            det.detect_batch(frame, 0.5)
        bank.win_times[key] = (_t.process_time() - t0) / 3
    return bank.win_times[key]


def make_sizeset(bank: ModelBank, params: PipelineParams) -> SizeSet:
    """Size set + MEASURED per-size detector times for this θ."""
    grid = det_grid(params.det_res)
    if bank.sizes_cells is None:
        sizes = [grid]
    else:
        sizes = scale_sizes(bank.sizes_cells, bank.ref_grid, grid)
    times = {s: measure_window_time(bank, params.det_arch, s)
             for s in sizes}
    return SizeSet(sizes, times)


def _downsample(frame: np.ndarray, res: Tuple[int, int]) -> np.ndarray:
    """Nearest-neighbor resize (host-side, cheap)."""
    W, H = res
    ys = (np.arange(H) * frame.shape[0]) // H
    xs = (np.arange(W) * frame.shape[1]) // W
    return frame[np.ix_(ys, xs)]


@dataclass
class RunResult:
    tracks: List[np.ndarray]
    seconds: float
    frames_processed: int
    detector_windows: int        # total windows run through the detector
    full_frames: int             # of which full-frame applications
    skipped_frames: int          # frames with zero windows


def detect_with_windows(bank: ModelBank, params: PipelineParams,
                        frame: np.ndarray, sizeset: SizeSet,
                        proxy: Optional[ProxyModel],
                        max_windows: int) -> Tuple[np.ndarray, List[Window]]:
    """Proxy-gated detection on one frame.  Returns (dets, windows)."""
    detector = bank.detectors[params.det_arch]
    grid = det_grid(params.det_res)
    if proxy is None:
        dets = detector.detect_batch(frame[None], params.det_conf)[0]
        return dets, [(0, 0, (grid[0], grid[1]))]
    pframe = _downsample(frame, proxy.resolution)
    _, pos = proxy.scores(pframe, params.proxy_threshold)
    cell_grid = map_proxy_grid(pos, grid)
    windows = group_cells(cell_grid, sizeset, max_windows)
    if not windows:
        return np.zeros((0, 5), np.float32), []
    full = sizeset.full
    if len(windows) == 1 and windows[0][2] == full:
        dets = detector.detect_batch(frame[None], params.det_conf)[0]
        return dets, windows
    # batch windows by size class (the paper's fixed-size batching)
    by_size: Dict[Tuple[int, int], List[Window]] = {}
    for wdw in windows:
        by_size.setdefault(wdw[2], []).append(wdw)
    all_dets = []
    W, H = params.det_res
    for size, wins in by_size.items():
        pw, ph = size[0] * CELL_PX, size[1] * CELL_PX
        crops = np.stack([
            frame[y * CELL_PX:y * CELL_PX + ph,
                  x * CELL_PX:x * CELL_PX + pw]
            for (x, y, _) in wins])
        origins = [(x * CELL_PX / W, y * CELL_PX / H)
                   for (x, y, _) in wins]
        scales = [(pw / W, ph / H)] * len(wins)
        dets = detector.detect_batch(crops, params.det_conf,
                                     origins=origins, scales=scales)
        all_dets.extend(dets)
    merged = np.concatenate(all_dets) if all_dets else \
        np.zeros((0, 5), np.float32)
    return nms(merged), windows


def run_clip(bank: ModelBank, params: PipelineParams, clip: Clip
             ) -> RunResult:
    cfg = bank.cfg
    W, H = params.det_res
    proxy = bank.proxies.get(params.proxy_res) \
        if params.proxy_res is not None else None
    sizeset = make_sizeset(bank, params)
    if params.tracker == "recurrent" and bank.tracker_params is not None:
        tracker = RecurrentTracker(cfg.tracker, bank.tracker_params)
    else:
        tracker = SortTracker()
    n_windows = full_frames = skipped = processed = 0
    decode_charged = 0.0
    t0 = time.process_time()
    for f in range(0, clip.n_frames, params.gap):
        t_r = time.process_time()
        frame, cost = render_frame(clip, f, W, H)   # decode @ det res
        decode_charged += cost - (time.process_time() - t_r)
        dets, windows = detect_with_windows(
            bank, params, frame, sizeset, proxy, cfg.windows.max_windows)
        n_windows += len(windows)
        if len(windows) == 1 and windows[0][2] == sizeset.full:
            full_frames += 1
        if not windows:
            skipped += 1
        tracker.step(f, dets, frame)
        processed += 1
    tracks = tracker.result()
    if params.refine and bank.refiner is not None:
        tracks = [bank.refiner.refine(t) for t in tracks]
    seconds = time.process_time() - t0 + max(decode_charged, 0.0)
    return RunResult(tracks, seconds, processed, n_windows, full_frames,
                     skipped)


def run_split(bank: ModelBank, params: PipelineParams,
              clips: Sequence[Clip]) -> Tuple[List[RunResult], float]:
    results = [run_clip(bank, params, c) for c in clips]
    return results, sum(r.seconds for r in results)
