"""BlazeIt baseline (Kang et al. 2019, adapted per §4).

Query-agnostic mode (NoScope-like): a frame-level CLASSIFICATION proxy
(small CNN -> P(frame contains any object)) gates full-frame detection;
frames under the threshold are skipped entirely.  On busy datasets this
yields only the trivial configurations (process everything / skip
everything) — exactly the paper's observation.

Limit-query mode (§4.2, Table 2): a REGRESSION proxy estimates the object
count in a region on every frame; the query phase applies the detector on
frames in descending proxy-score order until it has found the requested
number of matching frames (min spacing enforced).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pipeline as pl
from repro.core.metrics import clip_count_accuracy
from repro.core.proxy import _n_levels
from repro.core.detector import _apply_conv, _conv
from repro.core.sort import SortTracker
from repro.core.tuner import TunerPoint
from repro.core.train_models import _fit
from repro.data.video_synth import Clip
from repro.models.common import ParamBuilder, build


def def_frame_scorer(pb: ParamBuilder, base: int = 8) -> None:
    """Tiny frame-level CNN -> one scalar (classification or count)."""
    cin = 3
    for i, c in enumerate((base, base * 2, base * 4)):
        _conv(pb, f"enc{i}", cin, c)
        cin = c
    _conv(pb, "head", cin, 1, k=1)


@jax.jit
def frame_score(params, frames):
    x = frames
    for i in range(3):
        x = jax.nn.relu(_apply_conv(params[f"enc{i}"], x, stride=2))
    return _apply_conv(params["head"], x).mean(axis=(1, 2, 3))


def _scorer_loss_cls(params, frames, labels):
    s = frame_score(params, frames)
    y = labels.astype(jnp.float32)
    bce = jnp.maximum(s, 0) - s * y + jnp.log1p(jnp.exp(-jnp.abs(s)))
    return bce.mean()


def _scorer_loss_reg(params, frames, counts):
    s = frame_score(params, frames)
    return jnp.abs(s - counts.astype(jnp.float32)).mean()


@dataclass
class BlazeItBaseline:
    bank: pl.ModelBank
    proxy_res: Tuple[int, int] = (64, 48)
    name: str = "blazeit"
    cls_params: Optional[dict] = None
    reg_params: Optional[dict] = None

    # -- training --------------------------------------------------------------
    def train(self, train_dets: Sequence[Tuple[Clip, int, np.ndarray]],
              steps: int = 150,
              region: Optional[Tuple[float, float, float, float]] = None,
              ) -> None:
        """train_dets: θ_best (clip, frame, detections) labels."""
        W, H = self.proxy_res
        frames = np.stack([c.render(f, W, H) for c, f, _ in train_dets])
        has = np.asarray([float(len(d) > 0) for _, _, d in train_dets])
        counts = np.asarray([
            float(_count_in_region(d, region)) for _, _, d in train_dets])
        rng = np.random.default_rng(0)

        def batches(labels):
            def it():
                for _ in range(steps):
                    idx = rng.integers(len(frames), size=16)
                    yield (jnp.asarray(frames[idx]),
                           jnp.asarray(labels[idx]))
            return it()

        p0 = build(def_frame_scorer, "init", seed=1)
        self.cls_params, _ = _fit(_scorer_loss_cls, p0, batches(has),
                                  lr=3e-3)
        p1 = build(def_frame_scorer, "init", seed=2)
        self.reg_params, _ = _fit(_scorer_loss_reg, p1, batches(counts),
                                  lr=3e-3)

    # -- query-agnostic track extraction ----------------------------------------
    def run_clip(self, params: pl.PipelineParams, clip: Clip,
                 threshold: float) -> pl.RunResult:
        detector = self.bank.detectors[params.det_arch]
        W, H = params.det_res
        tracker = SortTracker()
        skipped = 0
        t0 = time.process_time()
        charged = 0.0
        for f in range(clip.n_frames):
            t_r = time.process_time()
            frame, cost = pl.render_frame(clip, f, W, H)
            charged += cost - (time.process_time() - t_r)
            small = pl._downsample(frame, self.proxy_res)
            score = jax.nn.sigmoid(frame_score(
                self.cls_params, jnp.asarray(small[None])))[0]
            if float(score) < threshold:
                skipped += 1
                continue
            dets = detector.detect_batch(frame[None], params.det_conf)[0]
            tracker.step(f, dets)
        tracks = tracker.result()
        secs = time.process_time() - t0 + max(charged, 0.0)
        return pl.RunResult(tracks, secs, clip.n_frames - skipped,
                            clip.n_frames - skipped,
                            clip.n_frames - skipped, skipped)

    def select(self, val_clips: Sequence[Clip],
               thresholds=(0.0, 0.2, 0.4, 0.6, 0.8, 0.95)
               ) -> List[TunerPoint]:
        cfg = self.bank.cfg
        params = pl.PipelineParams(
            det_arch=cfg.detector.archs[-1],
            det_res=cfg.detector.resolutions[0],
            det_conf=cfg.detector.confidences[1], gap=1, tracker="sort")
        points = []
        for th in thresholds:
            accs, secs = [], 0.0
            for clip in val_clips:
                r = self.run_clip(params, clip, th)
                accs.append(clip_count_accuracy(r.tracks, clip))
                secs += r.seconds
            pt = TunerPoint(params, float(np.mean(accs)), secs,
                            f"th={th}")
            points.append(pt)
        from repro.core.baselines.chameleon import pareto
        return pareto(points)

    # -- limit query (§4.2) ------------------------------------------------------
    def limit_query(self, clips: Sequence[Clip],
                    params: pl.PipelineParams, *, want: int,
                    min_count: int, region, min_spacing: int
                    ) -> Dict[str, object]:
        """Find ``want`` frames with >= min_count objects in ``region``.

        Returns dict with found frames, preprocessing/query times, and
        detector invocations."""
        W, H = params.det_res
        detector = self.bank.detectors[params.det_arch]
        # pre-processing: regression proxy over EVERY frame (decode at
        # proxy resolution — cheap, like BlazeIt's 64x64 decode)
        t0 = time.process_time()
        scores = []
        for ci, clip in enumerate(clips):
            for f in range(clip.n_frames):
                small = clip.render(f, *self.proxy_res)
                s = float(frame_score(self.reg_params,
                                      jnp.asarray(small[None]))[0])
                scores.append((s, ci, f))
        pre_s = time.process_time() - t0
        # query phase: detector in descending-score order
        t0 = time.process_time()
        scores.sort(key=lambda x: -x[0])
        found: List[Tuple[int, int]] = []
        n_det = 0
        for s, ci, f in scores:
            if len(found) >= want:
                break
            if any(c == ci and abs(f - g) < min_spacing
                   for c, g in found):
                continue
            frame = clips[ci].render(f, W, H)
            dets = detector.detect_batch(frame[None], params.det_conf)[0]
            n_det += 1
            if _count_in_region(dets, region) >= min_count:
                found.append((ci, f))
        query_s = time.process_time() - t0
        return {"found": found, "pre_seconds": pre_s,
                "query_seconds": query_s, "detector_frames": n_det}


def _count_in_region(dets: np.ndarray, region) -> int:
    if len(dets) == 0:
        return 0
    if region is None:
        return len(dets)
    x0, y0, x1, y1 = region
    m = ((dets[:, 0] >= x0) & (dets[:, 0] <= x1)
         & (dets[:, 1] >= y0) & (dets[:, 1] <= y1))
    return int(m.sum())
