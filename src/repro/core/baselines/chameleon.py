"""Chameleon baseline (Jiang et al. 2018, adapted per §4): optimizes the
object-detector input resolution and sampling rate over a grid, with the
SORT tracker — the "tune resolution and rate" reference point.

Parameter selection (per the paper's protocol, using the count-label
metric): evaluate the (arch x resolution x gap) grid on the validation
set and keep the Pareto-optimal points.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import List, Sequence, Tuple

import numpy as np

from repro.core import pipeline as pl
from repro.core.metrics import clip_count_accuracy
from repro.core.tuner import TunerPoint, _evaluate
from repro.data.video_synth import Clip


def pareto(points: List[TunerPoint]) -> List[TunerPoint]:
    """Keep points not dominated in (faster, more accurate)."""
    out = []
    for p in points:
        dominated = any(
            q.val_seconds <= p.val_seconds
            and q.val_accuracy >= p.val_accuracy and q is not p
            and (q.val_seconds < p.val_seconds
                 or q.val_accuracy > p.val_accuracy)
            for q in points)
        if not dominated:
            out.append(p)
    return sorted(out, key=lambda p: p.val_seconds)


@dataclass
class ChameleonBaseline:
    bank: pl.ModelBank
    name: str = "chameleon"

    def select(self, val_clips: Sequence[Clip]) -> List[TunerPoint]:
        cfg = self.bank.cfg
        points = []
        for arch in cfg.detector.archs:
            for res in cfg.detector.resolutions:
                for gap in cfg.tracker.gaps:
                    params = pl.PipelineParams(
                        det_arch=arch, det_res=res,
                        det_conf=cfg.detector.confidences[1], gap=gap,
                        tracker="sort", refine=False)
                    a, t = _evaluate(self.bank, params, val_clips)
                    points.append(TunerPoint(params, a, t, "grid"))
        return pareto(points)
