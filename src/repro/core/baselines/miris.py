"""Miris baseline (Bastani et al. 2020, adapted per §4): variable-rate
tracking with a PAIRWISE matcher.

Two deliberate limitations vs MultiScope's recurrent tracker (§3.4):
  * the matcher compares detections in two consecutive processed frames
    at a time (we instantiate the tracker model with prefix length 1, so
    the GRU state carries exactly one detection — the paper's GNN-pairwise
    analogue);
  * rate is VARIABLE: processing starts at the maximum gap; when matching
    confidence drops below the error tolerance q (or active tracks go
    unmatched), the gap halves for the next step; confident steps double
    it back.  The tolerance q is the speed-accuracy knob.

Query-agnostic mode: the predicate selects ALL tracks (paper §4).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.multiscope import TrackerConfig
from repro.core import pipeline as pl
from repro.core.metrics import clip_count_accuracy
from repro.core.tracker import (RecurrentTracker, TrackExample,
                                train_tracker)
from repro.core.tuner import TunerPoint
from repro.data.video_synth import Clip


@dataclass
class MirisBaseline:
    bank: pl.ModelBank
    name: str = "miris"
    pair_params: Optional[dict] = None

    def train(self, examples: Sequence[TrackExample],
              steps: int = 1500) -> None:
        """Pairwise matcher = tracker trained with prefix length 1."""
        self.pair_params, _ = train_tracker(
            self.bank.cfg.tracker, list(examples), steps=steps,
            max_prefix=1)

    def run_clip(self, params: pl.PipelineParams, clip: Clip,
                 tolerance: float) -> pl.RunResult:
        cfg = self.bank.cfg
        detector = self.bank.detectors[params.det_arch]
        W, H = params.det_res
        tracker = RecurrentTracker(cfg.tracker, self.pair_params)
        g_max = max(cfg.tracker.gaps)
        gap = g_max
        f = 0
        processed = 0
        charged = 0.0
        t0 = time.process_time()
        while f < clip.n_frames:
            t_r = time.process_time()
            frame, cost = pl.render_frame(clip, f, W, H)
            charged += cost - (time.process_time() - t_r)
            dets = detector.detect_batch(frame[None], params.det_conf)[0]
            before = {id(t): len(t.frames) for t in tracker.active}
            n_active = len(tracker.active)
            tracker.step(f, dets, frame)
            processed += 1
            # confidence heuristic: fraction of previously active tracks
            # that matched this step
            matched = sum(1 for t in tracker.active
                          if id(t) in before
                          and len(t.frames) > before[id(t)])
            conf = matched / n_active if n_active else 1.0
            if conf < tolerance and gap > 1:
                gap = max(1, gap // 2)          # drop rate, look closer
            elif conf >= tolerance and gap < g_max:
                gap = min(g_max, gap * 2)
            f += gap
        tracks = tracker.result()
        secs = time.process_time() - t0 + max(charged, 0.0)
        return pl.RunResult(tracks, secs, processed, processed,
                            processed, 0)

    def select(self, val_clips: Sequence[Clip],
               tolerances=(0.0, 0.25, 0.5, 0.75, 0.9, 1.0)
               ) -> List[TunerPoint]:
        cfg = self.bank.cfg
        params = pl.PipelineParams(
            det_arch=cfg.detector.archs[-1],
            det_res=cfg.detector.resolutions[0],
            det_conf=cfg.detector.confidences[1], gap=1,
            tracker="recurrent")
        points = []
        for q in tolerances:
            accs, secs = [], 0.0
            for clip in val_clips:
                r = self.run_clip(params, clip, q)
                accs.append(clip_count_accuracy(r.tracks, clip))
                secs += r.seconds
            points.append(TunerPoint(params, float(np.mean(accs)), secs,
                                     f"q={q}"))
        from repro.core.baselines.chameleon import pareto
        return pareto(points)
