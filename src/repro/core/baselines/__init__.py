from repro.core.baselines.chameleon import ChameleonBaseline  # noqa: F401
from repro.core.baselines.blazeit import BlazeItBaseline  # noqa: F401
from repro.core.baselines.miris import MirisBaseline  # noqa: F401
