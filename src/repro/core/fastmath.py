"""Bitwise-matched host/device elementwise math for the tracker twins.

The recurrent tracker runs its small heads twice: in numpy on the host
(``RecurrentTracker``'s ``_*_np`` twins) and in jnp inside the fused
``kernels.track_step`` kernel.  The repo's correctness bar is BIT
equality between the two, which ordinary ``np.tanh`` vs XLA ``tanh``
cannot give (different polynomial approximations), and which plain
``a * b + c`` cannot give either (XLA CPU contracts the multiply-add
into a hardware fma; numpy rounds twice).

This module pins one shared algorithm per function and gives each a
``np_*`` (host) and ``jx_*`` (traced) flavor constructed to produce
identical f32 bits:

* ``fmadd`` — the only multiply-feeding-an-add pattern either flavor is
  allowed to write.  The jnp flavor is literally ``a * b + c`` (XLA
  contracts it to a single-rounding fma); the numpy flavor emulates that
  fma exactly in f64 via Boldo-Melquiond round-to-odd (the 24+24-bit
  product is exact in f64; a TwoSum residual decides the odd-rounding
  nudge before the final f32 cast).
* ``exp`` — Cody-Waite range reduction + the Cephes ``expf`` degree-5
  polynomial, every step either an ``fmadd`` or an exact op (floor,
  clip, power-of-two scale built by integer exponent bit-twiddling).
* ``sigmoid`` — ``1 / (1 + exp(-x))`` with the input clamped to
  [-30, 30] so ``exp`` stays comfortably normal (no subnormal/FTZ
  divergence) and the ``1 + e`` add never meets a rounded product.
* ``tanh`` — ``2 * sigmoid(2x) - 1``: both multiplies are by powers of
  two (exact), so even if XLA contracts ``2*s - 1`` into an fma the
  result is unchanged.
* ``log1p_int`` — the tracker only ever takes ``log1p`` of integer
  frame gaps, so a 4096-entry f32 table (computed once in f64) replaces
  the libm call; gaps beyond the table clamp to the last entry.
* ``matmul`` — BLAS ``@`` and XLA's ``dot`` disagree bitwise in a
  shape-dependent way (blocked SIMD accumulation vs Eigen kernels), so
  neither may appear on a bit-matched path.  The pinned algorithm is
  the sequential double-rounded rank-1 accumulation over k (multiply,
  round, add, round — no fma): numpy's ``einsum`` with
  ``optimize=False`` computes exactly that order in C, and the jnp
  flavor reproduces it with a ``fori_loop`` of adds over rank-1
  products materialized OUTSIDE the loop (the while-loop boundary is
  what stops XLA contracting the multiply into the adds; an
  ``optimization_barrier`` does not).  Single-column weights are
  padded to 8 columns internally — einsum switches to a SIMD dot
  reduction at width 1 — and the result sliced back.

Safe outside this module (verified exact / bit-identical np vs XLA CPU):
plain mul, div, add, sub, min/max/clip, comparisons, ``where``,
integer ops, and a bias add on a ``matmul`` result (the add meets a
loop output, not a multiply).  NOT safe: any other ``mul`` whose
result feeds an ``add``/``sub`` on the traced side — route it through
``fmadd`` or reformulate (e.g. the GRU blend ``(1-z)*h + z*c`` becomes
the single-multiply ``h + z*(c-h)``) — and any ``@`` / ``jnp.dot``.
"""
from __future__ import annotations

import numpy as np

_LOG2E = np.float32(1.44269504088896341)
# Cody-Waite split of ln2 (Cephes expf): ln2 ~= LN2_HI + LN2_LO
_LN2_HI = np.float32(0.693359375)
_LN2_LO = np.float32(-2.12194440e-4)
# Cephes expf minimax polynomial on [-0.5 ln2, 0.5 ln2]
_EXP_POLY = tuple(np.float32(c) for c in (
    1.9875691500e-4, 1.3981999507e-3, 8.3334519073e-3,
    4.1665795894e-2, 1.6666665459e-1, 5.0000001201e-1))
# clip keeps 2^k a normal f32 (k in [-126, 127]) and the final scale
# exact; sigmoid's tighter clamp is what the tracker actually relies on
_EXP_LO = np.float32(-87.0)
_EXP_HI = np.float32(88.0)
_SIG_CLAMP = np.float32(30.0)
_ONE = np.float32(1.0)
_TWO = np.float32(2.0)
_HALF = np.float32(0.5)

LOG1P_TABLE_SIZE = 4096
LOG1P_TABLE = np.log1p(
    np.arange(LOG1P_TABLE_SIZE, dtype=np.float64)).astype(np.float32)


# ---------------------------------------------------------------------------
# numpy flavor (host)
# ---------------------------------------------------------------------------

def np_fmadd(a, b, c) -> np.ndarray:
    """Exact f32 fma(a, b, c) — bit-identical to XLA CPU's contracted
    ``a * b + c``.  f64 holds the 24x24-bit product exactly; TwoSum
    recovers the residual of the f64 add, and round-to-odd on the f64
    intermediate makes the final f32 cast single-rounded."""
    a64 = np.asarray(a, np.float64)
    b64 = np.asarray(b, np.float64)
    c64 = np.asarray(c, np.float64)
    p = a64 * b64                       # exact
    s = p + c64
    bv = s - p
    err = (p - (s - bv)) + (c64 - bv)   # exact: s + err == p + c
    s = np.ascontiguousarray(np.broadcast_to(s, err.shape))
    bits = s.view(np.int64)
    fix = (err != 0) & ((bits & 1) == 0) & np.isfinite(s)
    dirn = np.where(err > 0, np.float64(np.inf), np.float64(-np.inf))
    s = np.where(fix, np.nextafter(s, dirn), s)
    return s.astype(np.float32)


def _np_pow2(k: np.ndarray) -> np.ndarray:
    ki = k.astype(np.int32)
    return np.ascontiguousarray((ki + np.int32(127)) << np.int32(23)) \
        .view(np.float32)


def np_exp(x: np.ndarray) -> np.ndarray:
    x = np.clip(np.asarray(x, np.float32), _EXP_LO, _EXP_HI)
    k = np.floor(np_fmadd(x, _LOG2E, _HALF))
    r = np_fmadd(k, -_LN2_HI, x)
    r = np_fmadd(k, -_LN2_LO, r)
    p = np_fmadd(_EXP_POLY[0], r, _EXP_POLY[1])
    for c in _EXP_POLY[2:]:
        p = np_fmadd(p, r, c)
    s = np_fmadd(p, r * r, r) + _ONE
    return (s * _np_pow2(k)).astype(np.float32)


def np_sigmoid(x: np.ndarray) -> np.ndarray:
    x = np.clip(np.asarray(x, np.float32), -_SIG_CLAMP, _SIG_CLAMP)
    return _ONE / (_ONE + np_exp(-x))


def np_tanh(x: np.ndarray) -> np.ndarray:
    return _TWO * np_sigmoid(_TWO * np.asarray(x, np.float32)) - _ONE


def np_log1p_int(te: np.ndarray) -> np.ndarray:
    """log1p of integer-valued nonnegative f32 (frame gaps)."""
    idx = np.clip(np.asarray(te).astype(np.int32), 0,
                  LOG1P_TABLE_SIZE - 1)
    return LOG1P_TABLE[idx]


def np_matmul(a: np.ndarray, w: np.ndarray) -> np.ndarray:
    """(n, k) @ (k, m) with the pinned sequential-over-k accumulation
    (double rounding per term, ascending k) — bit-identical to
    ``jx_matmul``.  NOT BLAS: ``einsum(optimize=False)`` runs the naive
    C loops in exactly that order."""
    a = np.asarray(a, np.float32)
    w = np.asarray(w, np.float32)
    if w.shape[1] == 1:
        wp = np.zeros((w.shape[0], 8), np.float32)
        wp[:, :1] = w
        return np.einsum("ik,kh->ih", a, wp, optimize=False)[:, :1]
    return np.einsum("ik,kh->ih", a, w, optimize=False)


# ---------------------------------------------------------------------------
# jnp flavor (jit / pallas bodies) — same algorithms, traced ops
# ---------------------------------------------------------------------------

def jx_fmadd(a, b, c):
    # XLA CPU contracts this into one fma; keep it the ONLY
    # mul-feeding-add pattern on the traced side
    return a * b + c


def _jx_pow2(k):
    import jax
    import jax.numpy as jnp
    ki = k.astype(jnp.int32)
    return jax.lax.bitcast_convert_type((ki + 127) << 23, jnp.float32)


def jx_exp(x):
    import jax.numpy as jnp
    x = jnp.clip(x.astype(jnp.float32), _EXP_LO, _EXP_HI)
    k = jnp.floor(jx_fmadd(x, _LOG2E, _HALF))
    r = jx_fmadd(k, -_LN2_HI, x)
    r = jx_fmadd(k, -_LN2_LO, r)
    p = jx_fmadd(_EXP_POLY[0], r, _EXP_POLY[1])
    for c in _EXP_POLY[2:]:
        p = jx_fmadd(p, r, c)
    s = jx_fmadd(p, r * r, r) + _ONE
    return s * _jx_pow2(k)


def jx_sigmoid(x):
    import jax.numpy as jnp
    x = jnp.clip(x.astype(jnp.float32), -_SIG_CLAMP, _SIG_CLAMP)
    return _ONE / (_ONE + jx_exp(-x))


def jx_tanh(x):
    return _TWO * jx_sigmoid(_TWO * x) - _ONE


def jx_matmul(a, w):
    """Traced twin of ``np_matmul``: rank-1 products for every k are
    materialized in ONE multiply, then a ``fori_loop`` accumulates them
    in ascending k.  The loop boundary keeps the multiply and the adds
    in separate computations, so XLA cannot contract them into fmas
    (which would skip the per-term product rounding einsum performs)."""
    import jax
    import jax.numpy as jnp
    if w.shape[1] == 1:
        return jx_matmul(a, jnp.pad(w, ((0, 0), (0, 7))))[:, :1]
    prods = a.T[:, :, None] * w[:, None, :]          # (k, n, m)
    def body(kk, acc):
        return acc + jax.lax.dynamic_index_in_dim(prods, kk, 0,
                                                  keepdims=False)
    return jax.lax.fori_loop(
        0, a.shape[1], body,
        jnp.zeros((a.shape[0], w.shape[1]), jnp.float32))


def jx_log1p_int(te, table=None):
    """Traced twin of ``np_log1p_int``.  Pallas kernel bodies must pass
    the table in as a loaded ref value; plain jit contexts may omit it
    (the module constant is embedded)."""
    import jax.numpy as jnp
    if table is None:
        table = LOG1P_TABLE
    idx = jnp.clip(te.astype(jnp.int32), 0, LOG1P_TABLE_SIZE - 1)
    return jnp.take(jnp.asarray(table), idx, axis=0)
