"""Recurrent reduced-rate tracker (§3.4).

Model = three components, per the paper:
  1. detection-level features: a small CNN over the detection's image crop,
     concatenated with the 4D box and the t_elapsed temporal feature
     (frames since the previous detection — what makes one model robust
     across every sampling gap g);
  2. track-level features: a GRU over the prefix's detection features
     (kept INCREMENTALLY at inference: one GRU step per appended
     detection, so reduced-rate tracking costs O(1) per track per frame);
  3. a matching MLP scoring (track feature, detection feature) pairs;
     Hungarian assignment on the score matrix, with a threshold below
     which a detection starts a new track.

Training (gap-randomized, §3.4): examples are sampled from θ_best tracks;
each example subsamples a track at a random gap g ~ G (one detection every
>= g frames), uses the last subsampled detection as the positive candidate
and same-frame detections of OTHER tracks as distractors, and trains the
pair score with BCE (calibrated probabilities -> the same threshold serves
Hungarian costs and new-track decisions).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.multiscope import TrackerConfig
from repro.core import fastmath as fm
from repro.core.hungarian import BIG, hungarian_device_np
from repro.models.common import ParamBuilder, build
from repro.optim import adamw

BOX_FEATS = 6      # cx, cy, w, h, t_elapsed/8, log1p(t_elapsed)
REL_FEATS = 6      # dcx, dcy, dcx/te, dcy/te, dw, dh (candidate vs track)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def def_tracker(pb: ParamBuilder, cfg: TrackerConfig) -> None:
    C = cfg.crop
    e = cfg.embed_dim
    with pb.scope("crop_cnn"):
        pb.param("w0", (3, 3, 3, e // 2), (None,) * 4,
                 scale=1.0 / np.sqrt(27))
        pb.param("b0", (e // 2,), (None,), init="zeros")
        pb.param("w1", (3, 3, e // 2, e), (None,) * 4,
                 scale=1.0 / np.sqrt(9 * e // 2))
        pb.param("b1", (e,), (None,), init="zeros")
        flat = (C // 4) * (C // 4) * e
        pb.param("wd", (flat, e), (None, None))
        pb.param("bd", (e,), (None,), init="zeros")
    with pb.scope("det_proj"):
        pb.param("w", (e + BOX_FEATS, e), (None, None))
        pb.param("b", (e,), (None,), init="zeros")
    with pb.scope("gru"):
        h, f = cfg.rnn_dim, e
        pb.param("wz", (f + h, h), (None, None))
        pb.param("wr", (f + h, h), (None, None))
        pb.param("wh", (f + h, h), (None, None))
        pb.param("bz", (h,), (None,), init="zeros")
        pb.param("br", (h,), (None,), init="zeros")
        pb.param("bh", (h,), (None,), init="zeros")
    with pb.scope("match"):
        pb.param("w0", (cfg.rnn_dim + e + REL_FEATS, cfg.match_hidden),
                 (None, None))
        pb.param("b0", (cfg.match_hidden,), (None,), init="zeros")
        pb.param("w1", (cfg.match_hidden, 1), (None, None))
        pb.param("b1", (1,), (None,), init="zeros")


def init_tracker(cfg: TrackerConfig, seed: int = 0):
    return build(functools.partial(def_tracker, cfg=cfg), "init",
                 seed=seed)


# ---------------------------------------------------------------------------
# Forward pieces (fixed-shape jit)
# ---------------------------------------------------------------------------

def _conv(x, w, b, stride):
    y = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return jax.nn.relu(y + b)


@jax.jit
def crop_embed(params, crops):
    """crops: (N, C, C, 3) -> (N, e) crop-CNN features.

    The te-INDEPENDENT part of the detection embedding: inference
    computes it once per detection (batched per chunk by the engine) and
    derives every te-dependent embedding from it host-side."""
    p = params["crop_cnn"]
    x = _conv(crops, p["w0"], p["b0"], 2)
    x = _conv(x, p["w1"], p["b1"], 2)
    x = x.reshape(x.shape[0], -1)
    # repro-lint: disable=bit-contract -- crop CNN runs upstream of the host/device split: one impl, both paths consume its output
    return jnp.tanh(x @ p["wd"] + p["bd"])


@jax.jit
def embed_dets(params, crops, boxes, t_elapsed):
    """crops: (N, C, C, 3); boxes: (N, 4); t_elapsed: (N,) -> (N, e)."""
    x = crop_embed(params, crops)
    te = t_elapsed.astype(jnp.float32)
    extra = jnp.stack([boxes[:, 0], boxes[:, 1], boxes[:, 2], boxes[:, 3],
                       te / 8.0, jnp.log1p(te)], axis=1)
    d = jnp.concatenate([x, extra], axis=1)
    dp = params["det_proj"]
    # repro-lint: disable=bit-contract -- train-only head; inference twins are _det_feats_np (host) / kernels.track_step (device)
    return jnp.tanh(d @ dp["w"] + dp["b"])


@jax.jit
def gru_step(params, h, feat):
    """h: (..., H); feat: (..., e) -> new h."""
    g = params["gru"]
    hf = jnp.concatenate([feat, h], axis=-1)
    # repro-lint: disable=bit-contract -- train-only head; inference twins are _gru_np (host) / kernels.track_step (device)
    z = jax.nn.sigmoid(hf @ g["wz"] + g["bz"])
    # repro-lint: disable=bit-contract -- train-only head; inference twins are _gru_np (host) / kernels.track_step (device)
    r = jax.nn.sigmoid(hf @ g["wr"] + g["br"])
    hf2 = jnp.concatenate([feat, r * h], axis=-1)
    # repro-lint: disable=bit-contract -- train-only head; inference twins are _gru_np (host) / kernels.track_step (device)
    cand = jnp.tanh(hf2 @ g["wh"] + g["bh"])
    return (1 - z) * h + z * cand


def _rel_features(track_boxes, det_boxes, te):
    """track_boxes: (T, 4); det_boxes: (N, 4); te: (N,) -> (T, N, 6)."""
    d = det_boxes[None, :, :] - track_boxes[:, None, :]      # (T, N, 4)
    tesafe = jnp.maximum(te, 1.0)[None, :, None]
    return jnp.concatenate([
        d[..., :2], d[..., :2] / tesafe, d[..., 2:]], axis=-1)


@jax.jit
def match_logits(params, track_h, track_boxes, det_feats, det_boxes, te):
    """track_h: (T, H); track_boxes: (T, 4) last box per track;
    det_feats: (N, e); det_boxes: (N, 4); te: (N,) -> (T, N) logits."""
    m = params["match"]
    T, N = track_h.shape[0], det_feats.shape[0]
    rel = _rel_features(track_boxes, det_boxes, te)
    pair = jnp.concatenate([
        jnp.broadcast_to(track_h[:, None], (T, N, track_h.shape[1])),
        jnp.broadcast_to(det_feats[None], (T, N, det_feats.shape[1])),
        rel,
    ], axis=-1)
    # repro-lint: disable=bit-contract -- train-only head; inference twins are _match_np (host) / kernels.track_step (device)
    hid = jnp.tanh(pair @ m["w0"] + m["b0"])
    # repro-lint: disable=bit-contract -- train-only head; inference twins are _match_np (host) / kernels.track_step (device)
    return (hid @ m["w1"] + m["b1"])[..., 0]


@jax.jit
def _train_loss(params, crops, boxes, te, prefix_mask, cand_mask, labels,
                last_box):
    """One batch of listwise examples.

    crops/boxes/te: (B, L + K, C, C, 3)/(B, L+K, 4)/(B, L+K) — first L
    slots are the prefix detections (masked by prefix_mask (B, L)), the
    remaining K are candidates (masked by cand_mask (B, K));
    labels: (B, K) {0,1} (the true continuation has 1).
    """
    B, LK = boxes.shape[:2]
    feats = embed_dets(params, crops.reshape(B * LK, *crops.shape[2:]),
                       boxes.reshape(B * LK, 4), te.reshape(B * LK))
    feats = feats.reshape(B, LK, -1)
    L = prefix_mask.shape[1]
    K = cand_mask.shape[1]
    pre, cand = feats[:, :L], feats[:, L:]
    H = params["gru"]["bz"].shape[0]

    def scan_body(h, x):
        f, m = x
        h2 = gru_step(params, h, f)
        h = jnp.where(m[:, None] > 0, h2, h)
        return h, None

    h0 = jnp.zeros((B, H), jnp.float32)
    hT, _ = jax.lax.scan(scan_body, h0,
                         (jnp.moveaxis(pre, 1, 0),
                          jnp.moveaxis(prefix_mask, 1, 0)))
    # score each candidate against its own example's track feature,
    # with relative-motion features vs the prefix's LAST box
    m = params["match"]
    cboxes = boxes[:, L:]                               # (B, K, 4)
    cte = jnp.maximum(te[:, L:], 1.0)[..., None]
    d = cboxes - last_box[:, None, :]
    rel = jnp.concatenate([d[..., :2], d[..., :2] / cte, d[..., 2:]],
                          axis=-1)
    pair = jnp.concatenate(
        [jnp.broadcast_to(hT[:, None], (B, K, H)), cand, rel], axis=-1)
    # repro-lint: disable=bit-contract -- training loss; never on the serving path
    hid = jnp.tanh(pair @ m["w0"] + m["b0"])
    # repro-lint: disable=bit-contract -- training loss; never on the serving path
    logits = (hid @ m["w1"] + m["b1"])[..., 0]          # (B, K)
    y = labels.astype(jnp.float32)
    bce = jnp.maximum(logits, 0) - logits * y \
        + jnp.log1p(jnp.exp(-jnp.abs(logits)))  # repro-lint: disable=bit-contract -- training loss; never on the serving path
    return (bce * cand_mask).sum() / jnp.maximum(cand_mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Crop extraction (host)
# ---------------------------------------------------------------------------

def extract_crop(frame: np.ndarray, box: np.ndarray, crop: int
                 ) -> np.ndarray:
    """Nearest-neighbor resample of the box region to (crop, crop, 3)."""
    return extract_crops(frame, np.asarray(box)[None], crop)[0]


def extract_crops(frame: np.ndarray, boxes: np.ndarray, crop: int
                  ) -> np.ndarray:
    """Batched ``extract_crop``: (n, >=4) boxes -> (n, crop, crop, 3),
    one vectorized gather per frame instead of one per detection."""
    H, W = frame.shape[:2]
    n = len(boxes)
    if n == 0:
        return np.zeros((0, crop, crop, 3), frame.dtype)
    b = np.asarray(boxes)[:, :4]
    x0, x1 = (b[:, 0] - b[:, 2] / 2) * W, (b[:, 0] + b[:, 2] / 2) * W
    y0, y1 = (b[:, 1] - b[:, 3] / 2) * H, (b[:, 1] + b[:, 3] / 2) * H
    xs = np.clip(np.linspace(x0, x1, crop, axis=1).astype(np.int64),
                 0, W - 1)
    ys = np.clip(np.linspace(y0, y1, crop, axis=1).astype(np.int64),
                 0, H - 1)
    return frame[ys[:, :, None], xs[:, None, :]]


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------

@dataclass
class TrackExample:
    """One θ_best track on one clip, with crops pre-extracted."""
    frames: np.ndarray           # (n,)
    boxes: np.ndarray            # (n, 4)
    crops: np.ndarray            # (n, C, C, 3)
    clip_key: int = 0            # same-clip grouping for hard negatives


def build_examples(tracks: Sequence[np.ndarray],
                   frame_getter, crop: int,
                   clip_key: int = 0) -> List[TrackExample]:
    """tracks: (n, 6) [frame, cx, cy, w, h, id] arrays; frame_getter(f)
    -> rendered frame."""
    out = []
    for tr in tracks:
        if len(tr) < 3:
            continue
        crops = np.stack([
            extract_crop(frame_getter(int(f)), b, crop)
            for f, b in zip(tr[:, 0], tr[:, 1:5])])
        out.append(TrackExample(tr[:, 0].astype(np.int64), tr[:, 1:5],
                                crops, clip_key))
    return out


def train_tracker(cfg: TrackerConfig, examples: List[TrackExample],
                  steps: int = 1500, batch: int = 32, seed: int = 0,
                  lr: float = 3e-3, max_prefix: int = 6, n_cand: int = 6):
    params = init_tracker(cfg, seed)
    opt = adamw(lr=lr, weight_decay=0.0)
    state = opt.init(params)
    vg = jax.jit(jax.value_and_grad(_train_loss))
    rng = np.random.default_rng(seed)
    C = cfg.crop
    gaps = cfg.gaps
    losses = []
    if not examples:
        return params, losses

    def sample_example():
        ex = examples[rng.integers(len(examples))]
        g = int(gaps[rng.integers(len(gaps))])
        # subsample at gap g: next det >= g frames after the previous
        idx = [0]
        for i in range(1, len(ex.frames)):
            if ex.frames[i] - ex.frames[idx[-1]] >= g:
                idx.append(i)
        if len(idx) < 2:
            return None
        split = int(rng.integers(1, len(idx)))
        prefix, pos = idx[:split], idx[split]
        prefix = prefix[-max_prefix:]
        pos_frame = int(ex.frames[pos])
        # distractors: same-frame detections of other tracks; SAME-CLIP
        # tracks preferred (hard negatives — nearby objects in the same
        # scene) with random-clip fallback
        negs = []
        same = [o for o in examples
                if o is not ex and o.clip_key == ex.clip_key]
        pools = (same, examples)
        for pool in pools:
            for _ in range(3 * (n_cand - 1)):
                if len(negs) >= n_cand - 1 or not pool:
                    break
                other = pool[rng.integers(len(pool))]
                if other is ex:
                    continue
                j = np.searchsorted(other.frames, pos_frame)
                j = min(j, len(other.frames) - 1)
                # same-clip negatives must actually overlap in time
                if pool is same and abs(int(other.frames[j])
                                        - pos_frame) > 8:
                    continue
                negs.append((other, j))
            if len(negs) >= n_cand - 1:
                break
        return ex, prefix, pos, negs

    L, K = max_prefix, n_cand
    for step in range(steps):
        crops = np.zeros((batch, L + K, C, C, 3), np.float32)
        boxes = np.zeros((batch, L + K, 4), np.float32)
        te = np.zeros((batch, L + K), np.float32)
        pmask = np.zeros((batch, L), np.float32)
        cmask = np.zeros((batch, K), np.float32)
        labels = np.zeros((batch, K), np.float32)
        last_box = np.zeros((batch, 4), np.float32)
        b = 0
        while b < batch:
            s = sample_example()
            if s is None:
                continue
            ex, prefix, pos, negs = s
            off = L - len(prefix)
            prev_f = None
            for slot, i in enumerate(prefix):
                crops[b, off + slot] = ex.crops[i]
                boxes[b, off + slot] = ex.boxes[i]
                te[b, off + slot] = 0 if prev_f is None else \
                    ex.frames[i] - prev_f
                pmask[b, off + slot] = 1
                prev_f = ex.frames[i]
            last_box[b] = ex.boxes[prefix[-1]]
            t_gap = float(ex.frames[pos] - ex.frames[prefix[-1]])
            crops[b, L] = ex.crops[pos]
            boxes[b, L] = ex.boxes[pos]
            te[b, L] = t_gap
            cmask[b, 0] = 1
            labels[b, 0] = 1
            for slot, (other, j) in enumerate(negs):
                crops[b, L + 1 + slot] = other.crops[j]
                boxes[b, L + 1 + slot] = other.boxes[j]
                te[b, L + 1 + slot] = t_gap
                cmask[b, 1 + slot] = 1
            b += 1
        loss, g = vg(params, jnp.asarray(crops), jnp.asarray(boxes),
                     jnp.asarray(te), jnp.asarray(pmask),
                     jnp.asarray(cmask), jnp.asarray(labels),
                     jnp.asarray(last_box))
        params, state = opt.update(g, state, params)
        losses.append(float(loss))
    return params, losses


# ---------------------------------------------------------------------------
# Inference
# ---------------------------------------------------------------------------

@dataclass
class _ActiveTrack:
    track_id: int
    h: np.ndarray                # GRU state
    frames: List[int]
    boxes: List[np.ndarray]
    misses: int = 0

    def as_array(self) -> np.ndarray:
        out = np.zeros((len(self.frames), 6), np.float32)
        out[:, 0] = self.frames
        out[:, 1:5] = np.stack(self.boxes)
        out[:, 5] = self.track_id
        return out


def _pad(n: int, mult: int = 8) -> int:
    return max(mult, ((n + mult - 1) // mult) * mult)


def _host_params(params) -> Dict[str, np.ndarray]:
    """One-time numpy copies of the SMALL heads (det_proj, gru, match).

    Inference runs these host-side: per-frame work is a handful of tiny
    matmuls on <= max_tracks rows, where jit dispatch + device_put costs
    orders of magnitude more than the math.  The crop CNN (the only real
    compute) stays on the accelerator via ``crop_embed``."""
    out = {}
    for scope in ("det_proj", "gru", "match"):
        for k, v in params[scope].items():
            out[f"{scope}/{k}"] = np.asarray(v)
    return out


class RecurrentTracker:
    """Online inference: incremental GRU states + Hungarian matching.

    Split execution: the crop CNN (``crop_embed``) runs batched on the
    accelerator — once per chunk under the chunked engine, once per frame
    on the reference path — while the te-dependent projection, GRU steps
    and the matching MLP run host-side in numpy (same host/accelerator
    split as Hungarian itself).  Both engines call the same code, so
    their tracks are bit-identical.

    Every host head routes through ``repro.core.fastmath``'s ``np_*``
    flavors and association through ``hungarian_device_np`` (the f32 JV
    twin of the Pallas solver), which makes the host step BIT-IDENTICAL
    to the fused device step (``kernels.track_step``): with
    ``assign="device"`` the whole per-frame step — detection features,
    match logits, cost assembly, JV assignment and both GRU batches —
    runs as ONE kernel dispatch and the host merely replays the
    returned events onto its track objects.  ``DeviceTracker`` extends
    that to one dispatch per CHUNK.
    """

    def __init__(self, cfg: TrackerConfig, params, max_misses: int = 2,
                 min_hits: int = 2, assign: str = "host"):
        assert assign in ("host", "device")
        self.cfg = cfg
        self.params = params
        self.np_params = _host_params(params)
        self.max_misses = max_misses
        self.min_hits = min_hits
        self.assign = assign
        self.active: List[_ActiveTrack] = []
        self.finished: List[_ActiveTrack] = []
        self._next_id = 0
        self._last_frame: Optional[int] = None
        # device-step operands (lazy: host-only trackers never pack)
        self._packed = None
        self._thr = np.full((1, 1), cfg.match_threshold, np.float32)
        # cross-stream TrackBroker handle, attached by the executor
        self._track_handle = None
        # device dispatches issued by this tracker (crop CNN per-frame
        # fallback + track-step kernels); read by the TRACK stage timer
        self.dispatches = 0

    def _device_operands(self):
        if self._packed is None:
            from repro.kernels.track_step import pack_params
            from repro.kernels.track_step.ops import LOG1P_TABLE_2D
            self._packed = (pack_params(self.np_params), LOG1P_TABLE_2D)
        return self._packed

    # -- host-side heads (numpy twins of the ``kernels.track_step``
    #    pieces, minus the crop CNN; every transcendental/multiply-add
    #    routes through fastmath so host == device bit-for-bit) ----------

    def _det_feats_np(self, x: np.ndarray, boxes: np.ndarray,
                      te: np.ndarray) -> np.ndarray:
        """x: (N, e) crop embeddings -> (N, e) detection features."""
        p = self.np_params
        te = np.asarray(te, np.float32)
        extra = np.stack([boxes[:, 0], boxes[:, 1], boxes[:, 2],
                          boxes[:, 3], te * np.float32(0.125),
                          fm.np_log1p_int(te)],
                         axis=1).astype(np.float32)
        d = np.concatenate([x, extra], axis=1)
        return fm.np_tanh(fm.np_matmul(d, p["det_proj/w"])
                          + p["det_proj/b"])

    def _gru_np(self, h: np.ndarray, feat: np.ndarray) -> np.ndarray:
        p = self.np_params
        hf = np.concatenate([feat, h], axis=-1)
        z = fm.np_sigmoid(fm.np_matmul(hf, p["gru/wz"]) + p["gru/bz"])
        r = fm.np_sigmoid(fm.np_matmul(hf, p["gru/wr"]) + p["gru/br"])
        hf2 = np.concatenate([feat, r * h], axis=-1)
        cand = fm.np_tanh(fm.np_matmul(hf2, p["gru/wh"]) + p["gru/bh"])
        # single-multiply blend == the kernel's h + z*(cand - h)
        return fm.np_fmadd(z, cand - h, h)

    def _match_np(self, hs: np.ndarray, tboxes: np.ndarray,
                  feats: np.ndarray, dboxes: np.ndarray,
                  te: np.ndarray) -> np.ndarray:
        p = self.np_params
        T, N = hs.shape[0], feats.shape[0]
        d = dboxes[None, :, :] - tboxes[:, None, :]
        tesafe = np.maximum(te, np.float32(1.0))[None, :, None]
        rel = np.concatenate([d[..., :2], d[..., :2] / tesafe,
                              d[..., 2:]], axis=-1)
        pair = np.concatenate([
            np.broadcast_to(hs[:, None], (T, N, hs.shape[1])),
            np.broadcast_to(feats[None], (T, N, feats.shape[1])),
            rel,
        ], axis=-1)
        hid = fm.np_tanh(fm.np_matmul(pair.reshape(T * N, -1),
                                      p["match/w0"]) + p["match/b0"])
        return (fm.np_matmul(hid, p["match/w1"])
                + p["match/b1"]).reshape(T, N)

    def step(self, frame_idx: int, dets: np.ndarray,
             frame: np.ndarray,
             det_embeds: Optional[np.ndarray] = None) -> None:
        """dets: (n, >=4) world-unit detections; frame: rendered pixels.

        det_embeds: optional precomputed (n, embed_dim) CROP embeddings
        (``crop_embed`` outputs — one accelerator dispatch per CHUNK
        instead of per frame); te-dependent features are derived from
        them host-side, so the same embeddings serve both the matching
        candidates and the GRU updates."""
        cfg = self.cfg
        n = len(dets)
        te_scalar = 0.0 if self._last_frame is None else \
            float(frame_idx - self._last_frame)
        self._last_frame = frame_idx
        C = cfg.crop
        if det_embeds is not None:
            x = det_embeds
        elif n > 0:
            crops = extract_crops(frame, dets, C)
            npad = _pad(n)
            crops_p = np.zeros((npad, C, C, 3), np.float32)
            crops_p[:n] = crops
            self.dispatches += 1
            x = np.asarray(crop_embed(self.params,
                                      jnp.asarray(crops_p)))[:n]
        else:
            x = np.zeros((0, cfg.embed_dim), np.float32)
        boxes = dets[:, :4].astype(np.float32) if n > 0 else \
            np.zeros((0, 4), np.float32)

        T = len(self.active)
        use_dev = self.assign == "device" and n > 0
        h_upd = h_new = None
        if use_dev:
            pairs, h_upd, h_new = self._device_step(
                frame_idx, te_scalar, x, boxes)
        else:
            pairs = []
            if T > 0 and n > 0:
                feats = self._det_feats_np(
                    x, boxes, np.full((n,), te_scalar, np.float32))
                hs = np.stack([t.h for t in self.active])
                tboxes = np.stack([t.boxes[-1] for t in self.active])
                te_arr = np.full((n,), max(te_scalar, 1.0), np.float32)
                logits = self._match_np(hs, tboxes, feats, boxes,
                                        te_arr)
                probs = fm.np_sigmoid(logits)
                cost = np.where(
                    probs >= np.float32(cfg.match_threshold),
                    np.float32(1.0) - probs, np.float32(BIG))
                pairs = hungarian_device_np(cost)

        matched_t, matched_d = set(), set()
        upd_feats, upd_tracks = [], []
        for ti, di in pairs:
            t = self.active[ti]
            # GRU update uses the WITHIN-TRACK gap
            gap = float(frame_idx - t.frames[-1])
            upd_tracks.append(t)
            upd_feats.append((di, gap))
            if use_dev:
                t.h = np.asarray(h_upd[ti], np.float32)
            t.frames.append(frame_idx)
            t.boxes.append(dets[di, :4].astype(np.float32))
            t.misses = 0
            matched_t.add(ti)
            matched_d.add(di)
        # age out unmatched
        survivors = []
        for ti, t in enumerate(self.active):
            if ti in matched_t:
                survivors.append(t)
                continue
            t.misses += 1
            if t.misses > self.max_misses:
                self.finished.append(t)
            else:
                survivors.append(t)
        self.active = survivors

        # GRU advance: matched-track updates (t_elapsed = within-track
        # gap, h = track state) and new-track starts (t_elapsed = 0,
        # h = 0) reuse the crop embeddings — no second CNN pass.  On
        # the device path both GRU batches already ran inside the
        # fused kernel; the loop merely scatters the returned rows.
        new_idx = [di for di in range(n) if di not in matched_d]
        n_upd = len(upd_tracks)
        m = n_upd + len(new_idx)
        if m > 0:
            if use_dev:
                for di in new_idx:
                    t = _ActiveTrack(self._next_id,
                                     np.asarray(h_new[di], np.float32),
                                     [frame_idx],
                                     [dets[di, :4].astype(np.float32)])
                    self.active.append(t)
                    self._next_id += 1
            else:
                rows = [di for di, _ in upd_feats] + new_idx
                te_u = np.asarray([g for _, g in upd_feats]
                                  + [0.0] * len(new_idx), np.float32)
                hs_p = np.zeros((m, self.cfg.rnn_dim), np.float32)
                for k, t in enumerate(upd_tracks):
                    hs_p[k] = t.h
                f_u = self._det_feats_np(x[rows], boxes[rows], te_u)
                h_out = self._gru_np(hs_p, f_u)
                for k, t in enumerate(upd_tracks):
                    t.h = h_out[k]
                for k, di in enumerate(new_idx):
                    t = _ActiveTrack(self._next_id, h_out[n_upd + k],
                                     [frame_idx],
                                     [dets[di, :4].astype(np.float32)])
                    self.active.append(t)
                    self._next_id += 1
        # cap active set (static max_tracks capacity)
        if len(self.active) > self.cfg.max_tracks:
            self.active.sort(key=lambda t: -len(t.frames))
            self.finished.extend(self.active[self.cfg.max_tracks:])
            self.active = self.active[:self.cfg.max_tracks]

    def _device_step(self, frame_idx: int, te_scalar: float,
                     x: np.ndarray, boxes: np.ndarray):
        """One whole tracker step as ONE fused kernel dispatch.

        Packs the active set and the frame's detections into the
        kernel's pow2 slot square (live tracks as the row prefix in
        active-list order, detections as the column prefix), runs
        ``kernels.track_step`` — or submits to the cross-stream
        ``TrackBroker`` when one is attached — and returns (pairs,
        h_upd rows per track row, h_new rows per det column).  Bit-
        identical to the host twins at ANY slot count: the kernel
        restricts its JV solve to the canonical ``assoc_side`` square
        the host solves (f32 JV is not padding-invariant)."""
        from repro.core.detector import next_bucket

        T, n = len(self.active), len(boxes)
        e = self.cfg.embed_dim
        H = self.cfg.rnn_dim
        Q = next_bucket(max(T, n, 1), min_bucket=8)
        h_r = np.zeros((Q, H), np.float32)
        tbox_r = np.zeros((Q, 4), np.float32)
        alive_r = np.zeros((Q,), np.float32)
        te_gap_r = np.zeros((Q,), np.float32)
        for ti, t in enumerate(self.active):
            h_r[ti] = t.h
            tbox_r[ti] = t.boxes[-1]
            alive_r[ti] = 1.0
            te_gap_r[ti] = frame_idx - t.frames[-1]
        te_match = np.full((Q,), te_scalar, np.float32)
        x_p = np.zeros((Q, e), np.float32)
        x_p[:n] = x
        dbox = np.zeros((Q, 4), np.float32)
        dbox[:n] = boxes
        dvalid = np.zeros((Q,), np.float32)
        dvalid[:n] = 1.0
        params, table = self._device_operands()
        self.dispatches += 1
        if self._track_handle is not None:
            matched, h_upd, h_new = self._track_handle.step(
                h_r, tbox_r, alive_r, te_gap_r, te_match, x_p, dbox,
                dvalid, self._thr, params, table,
                params_key=id(self.params))
        else:
            from repro.kernels.track_step import track_step
            out = track_step(h_r[None], tbox_r[None], alive_r[None],
                             te_gap_r[None], te_match[None], x_p[None],
                             dbox[None], dvalid[None], self._thr,
                             params, table)
            matched, h_upd, h_new = (np.asarray(o[0]) for o in out)
        pairs = [(ti, int(matched[ti])) for ti in range(T)
                 if matched[ti] >= 0]
        return pairs, h_upd, h_new

    def step_chunk(self, frame_ids: Sequence[int],
                   dets_per_frame: Sequence[np.ndarray],
                   frames: Sequence[np.ndarray],
                   embeds: Optional[Sequence[np.ndarray]] = None
                   ) -> None:
        """Feed one chunk in frame order.  The base tracker simply
        loops ``step`` (host math, or one kernel dispatch per frame
        with ``assign="device"``); ``DeviceTracker`` overrides this
        with a single chunk-scan dispatch."""
        for k, f in enumerate(frame_ids):
            self.step(int(f), dets_per_frame[k], frames[k],
                      det_embeds=None if embeds is None else embeds[k])

    def result(self) -> List[np.ndarray]:
        tracks = self.finished + self.active
        return [t.as_array() for t in tracks
                if len(t.frames) >= self.min_hits]


# sorting key for dead slots: past any live track's recency rank
_BIGK = np.int32(1 << 30)


@functools.partial(jax.jit, static_argnames=("max_misses", "max_tracks"))
def _device_chunk_scan(carry, fidx, x, dbox, dvalid, thr, params, table,
                       *, max_misses: int, max_tracks: int):
    """Whole-chunk tracker recurrence: ``lax.scan`` over B frames, one
    fused ``kernels.track_step`` call per step, entirely on device.

    carry (slot space, Q slots): h (Q, H), tbox (Q, 4), alive (Q,) f32,
    last_f/misses/length/order (Q,) i32, next_key i32 (the next
    active-list rank to issue), last_g i32 (previously processed frame,
    -1 for none).  Inputs: fidx (B,) i32; x (B, Q, e); dbox (B, Q, 4);
    dvalid (B, Q) with each frame's detections as a column prefix.

    ``order`` encodes the host tracker's active-LIST position (matched
    tracks keep their rank, new tracks append, a max_tracks overflow
    re-sorts by track length); each step gathers slots into rank order,
    so the kernel sees exactly the rows the per-frame path would build
    and every step stays bit-identical to ``RecurrentTracker.step``.

    Returns per-frame events for the host replay: matched det column
    per slot (or -1), assigned slot per det column (Q for none), and
    the post-step h per slot."""
    from repro.kernels.track_step import track_step

    Q = carry[0].shape[0]
    slot = jnp.arange(Q, dtype=jnp.int32)

    def body(c, inp):
        h, tbox, alive, last_f, misses, length, order, next_key, \
            last_g = c
        f, xk, dbk, dvk = inp
        live = alive > 0
        te_m = jnp.where(last_g < 0, 0, f - last_g).astype(jnp.float32)
        perm = jnp.argsort(jnp.where(live, order, _BIGK + slot))
        alive_r = alive[perm]
        te_gap_r = jnp.where(alive_r > 0,
                             (f - last_f[perm]).astype(jnp.float32),
                             np.float32(0))
        matched_r, h_upd_r, h_new = (o[0] for o in track_step(
            h[perm][None], tbox[perm][None], alive_r[None],
            te_gap_r[None], jnp.full((Q,), te_m)[None], xk[None],
            dbk[None], dvk[None], thr, params, table))
        # back to slot space; apply matched-track updates
        m_slot = jnp.full((Q,), -1, jnp.int32).at[perm].set(matched_r)
        is_m = m_slot >= 0
        mcol = jnp.clip(m_slot, 0, Q - 1)
        h = jnp.where(is_m[:, None],
                      jnp.zeros_like(h).at[perm].set(h_upd_r), h)
        tbox = jnp.where(is_m[:, None], dbk[mcol], tbox)
        last_f = jnp.where(is_m, f, last_f)
        length = jnp.where(is_m, length + 1, length)
        misses = jnp.where(is_m, 0, misses)
        # age out unmatched live tracks
        aged = live & ~is_m
        misses = jnp.where(aged, misses + 1, misses)
        alive = jnp.where(aged & (misses > max_misses),
                          np.float32(0), alive)
        # unmatched detections start new tracks in ascending free slots,
        # ranks appended after every existing track (host list append)
        det_hit = jnp.zeros((Q + 1,), jnp.int32).at[
            jnp.where(matched_r >= 0, matched_r, Q)].set(1)[:Q]
        new_mask = (dvk > 0) & (det_hit == 0)
        free = alive <= 0
        free_rank = jnp.cumsum(free.astype(jnp.int32)) - 1
        slot_for_rank = jnp.full((Q,), Q, jnp.int32).at[
            jnp.where(free, free_rank, Q)].set(slot, mode="drop")
        new_rank = jnp.cumsum(new_mask.astype(jnp.int32)) - 1
        tgt = jnp.where(new_mask,
                        slot_for_rank[jnp.clip(new_rank, 0, Q - 1)], Q)
        alive = alive.at[tgt].set(1.0, mode="drop")
        h = h.at[tgt].set(h_new, mode="drop")
        tbox = tbox.at[tgt].set(dbk, mode="drop")
        last_f = last_f.at[tgt].set(f, mode="drop")
        misses = misses.at[tgt].set(0, mode="drop")
        length = length.at[tgt].set(1, mode="drop")
        order = order.at[tgt].set(next_key + new_rank, mode="drop")
        next_key = next_key + new_mask.astype(jnp.int32).sum()
        # capacity overflow: keep the max_tracks longest tracks (stable
        # on list order — the host's in-place sort) and renumber ranks
        n_alive = (alive > 0).astype(jnp.int32).sum()
        over = n_alive > max_tracks
        perm2 = jnp.lexsort((jnp.where(alive > 0, order, _BIGK + slot),
                             jnp.where(alive > 0, -length, _BIGK)))
        pos = jnp.zeros((Q,), jnp.int32).at[perm2].set(slot)
        alive = jnp.where(over & (alive > 0) & (pos >= max_tracks),
                          np.float32(0), alive)
        order = jnp.where(over, pos, order)
        next_key = jnp.where(over, max_tracks, next_key)
        return ((h, tbox, alive, last_f, misses, length, order,
                 next_key, f), (m_slot, tgt, h))

    _, ys = jax.lax.scan(body, carry, (fidx, x, dbox, dvalid))
    return ys


class DeviceTracker(RecurrentTracker):
    """Chunk-scan tracker: ONE device dispatch per chunk.

    Same tracks, bit for bit, as ``RecurrentTracker`` — the fused step
    kernel shares its math with the host twins via ``fastmath`` — but
    the per-frame recurrence runs as a ``lax.scan`` over the chunk with
    track state held in a padded slot buffer on device, so B frames
    cost one dispatch instead of B host round trips.  The host
    materializes track objects once per chunk by replaying the scan's
    (matched, new-slot, h) event stream.

    With a cross-stream ``TrackBroker`` handle attached the per-frame
    fused step is used instead (the broker batches steps ACROSS
    streams, which a per-stream scan cannot), so the live per-frame
    regime still shares dispatches."""

    def __init__(self, cfg: TrackerConfig, params, max_misses: int = 2,
                 min_hits: int = 2, assign: str = "device"):
        super().__init__(cfg, params, max_misses=max_misses,
                         min_hits=min_hits, assign="device")

    def step_chunk(self, frame_ids: Sequence[int],
                   dets_per_frame: Sequence[np.ndarray],
                   frames: Sequence[np.ndarray],
                   embeds: Optional[Sequence[np.ndarray]] = None
                   ) -> None:
        B = len(frame_ids)
        if B == 0:
            return
        if self._track_handle is not None:
            super().step_chunk(frame_ids, dets_per_frame, frames,
                               embeds)
            return
        cfg = self.cfg
        if embeds is None:
            self.dispatches += 1
            embeds = embed_dets_chunk(self.params, cfg, frames,
                                      dets_per_frame)
        from repro.core.detector import next_bucket
        T = len(self.active)
        D = max((len(d) for d in dets_per_frame), default=0)
        Q = next_bucket(max(T, cfg.max_tracks) + D, min_bucket=8)
        H, e = cfg.rnn_dim, cfg.embed_dim
        h0 = np.zeros((Q, H), np.float32)
        tbox0 = np.zeros((Q, 4), np.float32)
        alive0 = np.zeros((Q,), np.float32)
        lastf0 = np.zeros((Q,), np.int32)
        miss0 = np.zeros((Q,), np.int32)
        len0 = np.zeros((Q,), np.int32)
        order0 = np.zeros((Q,), np.int32)
        for i, t in enumerate(self.active):
            h0[i] = t.h
            tbox0[i] = t.boxes[-1]
            alive0[i] = 1.0
            lastf0[i] = t.frames[-1]
            miss0[i] = t.misses
            len0[i] = len(t.frames)
            order0[i] = i
        last_g0 = np.int32(-1 if self._last_frame is None
                           else self._last_frame)
        fidx = np.asarray([int(f) for f in frame_ids], np.int32)
        x = np.zeros((B, Q, e), np.float32)
        dbox = np.zeros((B, Q, 4), np.float32)
        dvalid = np.zeros((B, Q), np.float32)
        for k in range(B):
            n = len(dets_per_frame[k])
            if n:
                x[k, :n] = embeds[k]
                dbox[k, :n] = np.asarray(
                    dets_per_frame[k], np.float32)[:, :4]
                dvalid[k, :n] = 1.0
        params, table = self._device_operands()
        self.dispatches += 1
        m_ev, new_ev, h_ev = _device_chunk_scan(
            (h0, tbox0, alive0, lastf0, miss0, len0, order0,
             np.int32(T), last_g0),
            fidx, x, dbox, dvalid, self._thr, params, table,
            max_misses=self.max_misses, max_tracks=cfg.max_tracks)
        m_ev = np.asarray(m_ev)
        new_ev = np.asarray(new_ev)
        h_ev = np.asarray(h_ev)

        # replay the event stream onto host track objects; ``slots``
        # stays parallel to ``self.active``
        slots = list(range(T))
        for k in range(B):
            f = int(frame_ids[k])
            dets = dets_per_frame[k]
            ms, hs = m_ev[k], h_ev[k]
            keep_t: List[_ActiveTrack] = []
            keep_s: List[int] = []
            for t, s in zip(self.active, slots):
                di = int(ms[s])
                if di >= 0:
                    t.h = hs[s].copy()
                    t.frames.append(f)
                    t.boxes.append(dets[di, :4].astype(np.float32))
                    t.misses = 0
                    keep_t.append(t)
                    keep_s.append(s)
                else:
                    t.misses += 1
                    if t.misses > self.max_misses:
                        self.finished.append(t)
                    else:
                        keep_t.append(t)
                        keep_s.append(s)
            self.active, slots = keep_t, keep_s
            for di in range(len(dets)):
                s = int(new_ev[k][di])
                if s < Q:
                    t = _ActiveTrack(self._next_id, hs[s].copy(), [f],
                                     [dets[di, :4].astype(np.float32)])
                    self.active.append(t)
                    slots.append(s)
                    self._next_id += 1
            if len(self.active) > cfg.max_tracks:
                ranked = sorted(zip(self.active, slots),
                                key=lambda ts: -len(ts[0].frames))
                self.finished.extend(
                    t for t, _ in ranked[cfg.max_tracks:])
                self.active = [t for t, _ in ranked[:cfg.max_tracks]]
                slots = [s for _, s in ranked[:cfg.max_tracks]]
            self._last_frame = f


def embed_dets_chunk(params, cfg: TrackerConfig,
                     frames: Sequence[np.ndarray],
                     dets_per_frame: Sequence[np.ndarray],
                     min_bucket: int = 8) -> List[np.ndarray]:
    """Run the crop CNN over every detection in a CHUNK in one
    bucket-padded ``crop_embed`` dispatch (the executor's TRACK-stage
    batching).  Returns per-frame (n_i, embed_dim) crop embeddings,
    bit-identical to per-frame ``RecurrentTracker.step`` computation
    (conv outputs are per-sample independent of batch padding).

    ``min_bucket`` is the bucket floor; the executor scales it with the
    chunk size B so the set of distinct power-of-two buckets — and with
    it the number of ``crop_embed`` jit specializations — stays bounded
    as the tuner proposes larger chunks."""
    C = cfg.crop
    counts = [len(d) for d in dets_per_frame]
    total = sum(counts)
    if total == 0:
        return [np.zeros((0, cfg.embed_dim), np.float32)
                for _ in counts]
    from repro.core.detector import next_bucket
    npad = next_bucket(total, min_bucket=min_bucket)
    crops = np.zeros((npad, C, C, 3), np.float32)
    k = 0
    for frame, dets in zip(frames, dets_per_frame):
        if len(dets):
            crops[k:k + len(dets)] = extract_crops(frame, dets, C)
            k += len(dets)
    x = np.asarray(crop_embed(params, jnp.asarray(crops)))
    out = []
    k = 0
    for n in counts:
        out.append(x[k:k + n])
        k += n
    return out


# PR-1 name for ``embed_dets_chunk`` (same signature, kept for compat)
crop_embed_chunk = embed_dets_chunk
