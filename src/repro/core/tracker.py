"""Recurrent reduced-rate tracker (§3.4).

Model = three components, per the paper:
  1. detection-level features: a small CNN over the detection's image crop,
     concatenated with the 4D box and the t_elapsed temporal feature
     (frames since the previous detection — what makes one model robust
     across every sampling gap g);
  2. track-level features: a GRU over the prefix's detection features
     (kept INCREMENTALLY at inference: one GRU step per appended
     detection, so reduced-rate tracking costs O(1) per track per frame);
  3. a matching MLP scoring (track feature, detection feature) pairs;
     Hungarian assignment on the score matrix, with a threshold below
     which a detection starts a new track.

Training (gap-randomized, §3.4): examples are sampled from θ_best tracks;
each example subsamples a track at a random gap g ~ G (one detection every
>= g frames), uses the last subsampled detection as the positive candidate
and same-frame detections of OTHER tracks as distractors, and trains the
pair score with BCE (calibrated probabilities -> the same threshold serves
Hungarian costs and new-track decisions).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.multiscope import TrackerConfig
from repro.core.hungarian import hungarian, BIG
from repro.models.common import ParamBuilder, build
from repro.optim import adamw

BOX_FEATS = 6      # cx, cy, w, h, t_elapsed/8, log1p(t_elapsed)
REL_FEATS = 6      # dcx, dcy, dcx/te, dcy/te, dw, dh (candidate vs track)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def def_tracker(pb: ParamBuilder, cfg: TrackerConfig) -> None:
    C = cfg.crop
    e = cfg.embed_dim
    with pb.scope("crop_cnn"):
        pb.param("w0", (3, 3, 3, e // 2), (None,) * 4,
                 scale=1.0 / np.sqrt(27))
        pb.param("b0", (e // 2,), (None,), init="zeros")
        pb.param("w1", (3, 3, e // 2, e), (None,) * 4,
                 scale=1.0 / np.sqrt(9 * e // 2))
        pb.param("b1", (e,), (None,), init="zeros")
        flat = (C // 4) * (C // 4) * e
        pb.param("wd", (flat, e), (None, None))
        pb.param("bd", (e,), (None,), init="zeros")
    with pb.scope("det_proj"):
        pb.param("w", (e + BOX_FEATS, e), (None, None))
        pb.param("b", (e,), (None,), init="zeros")
    with pb.scope("gru"):
        h, f = cfg.rnn_dim, e
        pb.param("wz", (f + h, h), (None, None))
        pb.param("wr", (f + h, h), (None, None))
        pb.param("wh", (f + h, h), (None, None))
        pb.param("bz", (h,), (None,), init="zeros")
        pb.param("br", (h,), (None,), init="zeros")
        pb.param("bh", (h,), (None,), init="zeros")
    with pb.scope("match"):
        pb.param("w0", (cfg.rnn_dim + e + REL_FEATS, cfg.match_hidden),
                 (None, None))
        pb.param("b0", (cfg.match_hidden,), (None,), init="zeros")
        pb.param("w1", (cfg.match_hidden, 1), (None, None))
        pb.param("b1", (1,), (None,), init="zeros")


def init_tracker(cfg: TrackerConfig, seed: int = 0):
    return build(functools.partial(def_tracker, cfg=cfg), "init",
                 seed=seed)


# ---------------------------------------------------------------------------
# Forward pieces (fixed-shape jit)
# ---------------------------------------------------------------------------

def _conv(x, w, b, stride):
    y = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return jax.nn.relu(y + b)


@jax.jit
def crop_embed(params, crops):
    """crops: (N, C, C, 3) -> (N, e) crop-CNN features.

    The te-INDEPENDENT part of the detection embedding: inference
    computes it once per detection (batched per chunk by the engine) and
    derives every te-dependent embedding from it host-side."""
    p = params["crop_cnn"]
    x = _conv(crops, p["w0"], p["b0"], 2)
    x = _conv(x, p["w1"], p["b1"], 2)
    x = x.reshape(x.shape[0], -1)
    return jnp.tanh(x @ p["wd"] + p["bd"])


@jax.jit
def embed_dets(params, crops, boxes, t_elapsed):
    """crops: (N, C, C, 3); boxes: (N, 4); t_elapsed: (N,) -> (N, e)."""
    x = crop_embed(params, crops)
    te = t_elapsed.astype(jnp.float32)
    extra = jnp.stack([boxes[:, 0], boxes[:, 1], boxes[:, 2], boxes[:, 3],
                       te / 8.0, jnp.log1p(te)], axis=1)
    d = jnp.concatenate([x, extra], axis=1)
    dp = params["det_proj"]
    return jnp.tanh(d @ dp["w"] + dp["b"])


@jax.jit
def gru_step(params, h, feat):
    """h: (..., H); feat: (..., e) -> new h."""
    g = params["gru"]
    hf = jnp.concatenate([feat, h], axis=-1)
    z = jax.nn.sigmoid(hf @ g["wz"] + g["bz"])
    r = jax.nn.sigmoid(hf @ g["wr"] + g["br"])
    hf2 = jnp.concatenate([feat, r * h], axis=-1)
    cand = jnp.tanh(hf2 @ g["wh"] + g["bh"])
    return (1 - z) * h + z * cand


def _rel_features(track_boxes, det_boxes, te):
    """track_boxes: (T, 4); det_boxes: (N, 4); te: (N,) -> (T, N, 6)."""
    d = det_boxes[None, :, :] - track_boxes[:, None, :]      # (T, N, 4)
    tesafe = jnp.maximum(te, 1.0)[None, :, None]
    return jnp.concatenate([
        d[..., :2], d[..., :2] / tesafe, d[..., 2:]], axis=-1)


@jax.jit
def match_logits(params, track_h, track_boxes, det_feats, det_boxes, te):
    """track_h: (T, H); track_boxes: (T, 4) last box per track;
    det_feats: (N, e); det_boxes: (N, 4); te: (N,) -> (T, N) logits."""
    m = params["match"]
    T, N = track_h.shape[0], det_feats.shape[0]
    rel = _rel_features(track_boxes, det_boxes, te)
    pair = jnp.concatenate([
        jnp.broadcast_to(track_h[:, None], (T, N, track_h.shape[1])),
        jnp.broadcast_to(det_feats[None], (T, N, det_feats.shape[1])),
        rel,
    ], axis=-1)
    hid = jnp.tanh(pair @ m["w0"] + m["b0"])
    return (hid @ m["w1"] + m["b1"])[..., 0]


@jax.jit
def _train_loss(params, crops, boxes, te, prefix_mask, cand_mask, labels,
                last_box):
    """One batch of listwise examples.

    crops/boxes/te: (B, L + K, C, C, 3)/(B, L+K, 4)/(B, L+K) — first L
    slots are the prefix detections (masked by prefix_mask (B, L)), the
    remaining K are candidates (masked by cand_mask (B, K));
    labels: (B, K) {0,1} (the true continuation has 1).
    """
    B, LK = boxes.shape[:2]
    feats = embed_dets(params, crops.reshape(B * LK, *crops.shape[2:]),
                       boxes.reshape(B * LK, 4), te.reshape(B * LK))
    feats = feats.reshape(B, LK, -1)
    L = prefix_mask.shape[1]
    K = cand_mask.shape[1]
    pre, cand = feats[:, :L], feats[:, L:]
    H = params["gru"]["bz"].shape[0]

    def scan_body(h, x):
        f, m = x
        h2 = gru_step(params, h, f)
        h = jnp.where(m[:, None] > 0, h2, h)
        return h, None

    h0 = jnp.zeros((B, H), jnp.float32)
    hT, _ = jax.lax.scan(scan_body, h0,
                         (jnp.moveaxis(pre, 1, 0),
                          jnp.moveaxis(prefix_mask, 1, 0)))
    # score each candidate against its own example's track feature,
    # with relative-motion features vs the prefix's LAST box
    m = params["match"]
    cboxes = boxes[:, L:]                               # (B, K, 4)
    cte = jnp.maximum(te[:, L:], 1.0)[..., None]
    d = cboxes - last_box[:, None, :]
    rel = jnp.concatenate([d[..., :2], d[..., :2] / cte, d[..., 2:]],
                          axis=-1)
    pair = jnp.concatenate(
        [jnp.broadcast_to(hT[:, None], (B, K, H)), cand, rel], axis=-1)
    hid = jnp.tanh(pair @ m["w0"] + m["b0"])
    logits = (hid @ m["w1"] + m["b1"])[..., 0]          # (B, K)
    y = labels.astype(jnp.float32)
    bce = jnp.maximum(logits, 0) - logits * y \
        + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    return (bce * cand_mask).sum() / jnp.maximum(cand_mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Crop extraction (host)
# ---------------------------------------------------------------------------

def extract_crop(frame: np.ndarray, box: np.ndarray, crop: int
                 ) -> np.ndarray:
    """Nearest-neighbor resample of the box region to (crop, crop, 3)."""
    return extract_crops(frame, np.asarray(box)[None], crop)[0]


def extract_crops(frame: np.ndarray, boxes: np.ndarray, crop: int
                  ) -> np.ndarray:
    """Batched ``extract_crop``: (n, >=4) boxes -> (n, crop, crop, 3),
    one vectorized gather per frame instead of one per detection."""
    H, W = frame.shape[:2]
    n = len(boxes)
    if n == 0:
        return np.zeros((0, crop, crop, 3), frame.dtype)
    b = np.asarray(boxes)[:, :4]
    x0, x1 = (b[:, 0] - b[:, 2] / 2) * W, (b[:, 0] + b[:, 2] / 2) * W
    y0, y1 = (b[:, 1] - b[:, 3] / 2) * H, (b[:, 1] + b[:, 3] / 2) * H
    xs = np.clip(np.linspace(x0, x1, crop, axis=1).astype(np.int64),
                 0, W - 1)
    ys = np.clip(np.linspace(y0, y1, crop, axis=1).astype(np.int64),
                 0, H - 1)
    return frame[ys[:, :, None], xs[:, None, :]]


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------

@dataclass
class TrackExample:
    """One θ_best track on one clip, with crops pre-extracted."""
    frames: np.ndarray           # (n,)
    boxes: np.ndarray            # (n, 4)
    crops: np.ndarray            # (n, C, C, 3)
    clip_key: int = 0            # same-clip grouping for hard negatives


def build_examples(tracks: Sequence[np.ndarray],
                   frame_getter, crop: int,
                   clip_key: int = 0) -> List[TrackExample]:
    """tracks: (n, 6) [frame, cx, cy, w, h, id] arrays; frame_getter(f)
    -> rendered frame."""
    out = []
    for tr in tracks:
        if len(tr) < 3:
            continue
        crops = np.stack([
            extract_crop(frame_getter(int(f)), b, crop)
            for f, b in zip(tr[:, 0], tr[:, 1:5])])
        out.append(TrackExample(tr[:, 0].astype(np.int64), tr[:, 1:5],
                                crops, clip_key))
    return out


def train_tracker(cfg: TrackerConfig, examples: List[TrackExample],
                  steps: int = 1500, batch: int = 32, seed: int = 0,
                  lr: float = 3e-3, max_prefix: int = 6, n_cand: int = 6):
    params = init_tracker(cfg, seed)
    opt = adamw(lr=lr, weight_decay=0.0)
    state = opt.init(params)
    vg = jax.jit(jax.value_and_grad(_train_loss))
    rng = np.random.default_rng(seed)
    C = cfg.crop
    gaps = cfg.gaps
    losses = []
    if not examples:
        return params, losses

    def sample_example():
        ex = examples[rng.integers(len(examples))]
        g = int(gaps[rng.integers(len(gaps))])
        # subsample at gap g: next det >= g frames after the previous
        idx = [0]
        for i in range(1, len(ex.frames)):
            if ex.frames[i] - ex.frames[idx[-1]] >= g:
                idx.append(i)
        if len(idx) < 2:
            return None
        split = int(rng.integers(1, len(idx)))
        prefix, pos = idx[:split], idx[split]
        prefix = prefix[-max_prefix:]
        pos_frame = int(ex.frames[pos])
        # distractors: same-frame detections of other tracks; SAME-CLIP
        # tracks preferred (hard negatives — nearby objects in the same
        # scene) with random-clip fallback
        negs = []
        same = [o for o in examples
                if o is not ex and o.clip_key == ex.clip_key]
        pools = (same, examples)
        for pool in pools:
            for _ in range(3 * (n_cand - 1)):
                if len(negs) >= n_cand - 1 or not pool:
                    break
                other = pool[rng.integers(len(pool))]
                if other is ex:
                    continue
                j = np.searchsorted(other.frames, pos_frame)
                j = min(j, len(other.frames) - 1)
                # same-clip negatives must actually overlap in time
                if pool is same and abs(int(other.frames[j])
                                        - pos_frame) > 8:
                    continue
                negs.append((other, j))
            if len(negs) >= n_cand - 1:
                break
        return ex, prefix, pos, negs

    L, K = max_prefix, n_cand
    for step in range(steps):
        crops = np.zeros((batch, L + K, C, C, 3), np.float32)
        boxes = np.zeros((batch, L + K, 4), np.float32)
        te = np.zeros((batch, L + K), np.float32)
        pmask = np.zeros((batch, L), np.float32)
        cmask = np.zeros((batch, K), np.float32)
        labels = np.zeros((batch, K), np.float32)
        last_box = np.zeros((batch, 4), np.float32)
        b = 0
        while b < batch:
            s = sample_example()
            if s is None:
                continue
            ex, prefix, pos, negs = s
            off = L - len(prefix)
            prev_f = None
            for slot, i in enumerate(prefix):
                crops[b, off + slot] = ex.crops[i]
                boxes[b, off + slot] = ex.boxes[i]
                te[b, off + slot] = 0 if prev_f is None else \
                    ex.frames[i] - prev_f
                pmask[b, off + slot] = 1
                prev_f = ex.frames[i]
            last_box[b] = ex.boxes[prefix[-1]]
            t_gap = float(ex.frames[pos] - ex.frames[prefix[-1]])
            crops[b, L] = ex.crops[pos]
            boxes[b, L] = ex.boxes[pos]
            te[b, L] = t_gap
            cmask[b, 0] = 1
            labels[b, 0] = 1
            for slot, (other, j) in enumerate(negs):
                crops[b, L + 1 + slot] = other.crops[j]
                boxes[b, L + 1 + slot] = other.boxes[j]
                te[b, L + 1 + slot] = t_gap
                cmask[b, 1 + slot] = 1
            b += 1
        loss, g = vg(params, jnp.asarray(crops), jnp.asarray(boxes),
                     jnp.asarray(te), jnp.asarray(pmask),
                     jnp.asarray(cmask), jnp.asarray(labels),
                     jnp.asarray(last_box))
        params, state = opt.update(g, state, params)
        losses.append(float(loss))
    return params, losses


# ---------------------------------------------------------------------------
# Inference
# ---------------------------------------------------------------------------

@dataclass
class _ActiveTrack:
    track_id: int
    h: np.ndarray                # GRU state
    frames: List[int]
    boxes: List[np.ndarray]
    misses: int = 0

    def as_array(self) -> np.ndarray:
        out = np.zeros((len(self.frames), 6), np.float32)
        out[:, 0] = self.frames
        out[:, 1:5] = np.stack(self.boxes)
        out[:, 5] = self.track_id
        return out


def _pad(n: int, mult: int = 8) -> int:
    return max(mult, ((n + mult - 1) // mult) * mult)


def _host_params(params) -> Dict[str, np.ndarray]:
    """One-time numpy copies of the SMALL heads (det_proj, gru, match).

    Inference runs these host-side: per-frame work is a handful of tiny
    matmuls on <= max_tracks rows, where jit dispatch + device_put costs
    orders of magnitude more than the math.  The crop CNN (the only real
    compute) stays on the accelerator via ``crop_embed``."""
    out = {}
    for scope in ("det_proj", "gru", "match"):
        for k, v in params[scope].items():
            out[f"{scope}/{k}"] = np.asarray(v)
    return out


class RecurrentTracker:
    """Online inference: incremental GRU states + Hungarian matching.

    Split execution: the crop CNN (``crop_embed``) runs batched on the
    accelerator — once per chunk under the chunked engine, once per frame
    on the reference path — while the te-dependent projection, GRU steps
    and the matching MLP run host-side in numpy (same host/accelerator
    split as Hungarian itself).  Both engines call the same code, so
    their tracks are bit-identical."""

    def __init__(self, cfg: TrackerConfig, params, max_misses: int = 2,
                 min_hits: int = 2, assign: str = "host"):
        assert assign in ("host", "device")
        self.cfg = cfg
        self.params = params
        self.np_params = _host_params(params)
        self.max_misses = max_misses
        self.min_hits = min_hits
        self.assign = assign
        self.active: List[_ActiveTrack] = []
        self.finished: List[_ActiveTrack] = []
        self._next_id = 0
        self._last_frame: Optional[int] = None

    def _assign(self, cost: np.ndarray) -> List[tuple]:
        """Per-step association.  ``assign="device"`` routes through the
        batched Pallas solver (``repro.kernels.assign``) — a batch of
        one here, since the GRU recurrence makes each frame's cost
        matrix depend on the previous frame's assignment, so the
        tracker can never batch assignment ACROSS a chunk's frames (the
        genuinely batchable per-frame matrices live in ``metrics.mota``).
        Min-cost totals always agree with the host path; equal-cost
        tie-breaking may not, so "host" stays the default (the tuner /
        test bit-identity anchor)."""
        if self.assign == "device":
            from repro.core.hungarian import hungarian_batch
            return hungarian_batch([cost])[0]
        return hungarian(cost)

    # -- host-side heads (numpy twins of embed_dets / gru_step /
    #    match_logits, minus the crop CNN) --------------------------------

    def _det_feats_np(self, x: np.ndarray, boxes: np.ndarray,
                      te: np.ndarray) -> np.ndarray:
        """x: (N, e) crop embeddings -> (N, e) detection features."""
        p = self.np_params
        extra = np.stack([boxes[:, 0], boxes[:, 1], boxes[:, 2],
                          boxes[:, 3], te / 8.0, np.log1p(te)],
                         axis=1).astype(np.float32)
        d = np.concatenate([x, extra], axis=1)
        return np.tanh(d @ p["det_proj/w"] + p["det_proj/b"])

    def _gru_np(self, h: np.ndarray, feat: np.ndarray) -> np.ndarray:
        p = self.np_params
        hf = np.concatenate([feat, h], axis=-1)
        z = 1.0 / (1.0 + np.exp(-(hf @ p["gru/wz"] + p["gru/bz"])))
        r = 1.0 / (1.0 + np.exp(-(hf @ p["gru/wr"] + p["gru/br"])))
        hf2 = np.concatenate([feat, r * h], axis=-1)
        cand = np.tanh(hf2 @ p["gru/wh"] + p["gru/bh"])
        return ((1 - z) * h + z * cand).astype(np.float32)

    def _match_np(self, hs: np.ndarray, tboxes: np.ndarray,
                  feats: np.ndarray, dboxes: np.ndarray,
                  te: np.ndarray) -> np.ndarray:
        p = self.np_params
        T, N = hs.shape[0], feats.shape[0]
        d = dboxes[None, :, :] - tboxes[:, None, :]
        tesafe = np.maximum(te, 1.0)[None, :, None]
        rel = np.concatenate([d[..., :2], d[..., :2] / tesafe,
                              d[..., 2:]], axis=-1)
        pair = np.concatenate([
            np.broadcast_to(hs[:, None], (T, N, hs.shape[1])),
            np.broadcast_to(feats[None], (T, N, feats.shape[1])),
            rel,
        ], axis=-1)
        hid = np.tanh(pair @ p["match/w0"] + p["match/b0"])
        return (hid @ p["match/w1"] + p["match/b1"])[..., 0]

    def step(self, frame_idx: int, dets: np.ndarray,
             frame: np.ndarray,
             det_embeds: Optional[np.ndarray] = None) -> None:
        """dets: (n, >=4) world-unit detections; frame: rendered pixels.

        det_embeds: optional precomputed (n, embed_dim) CROP embeddings
        (``crop_embed`` outputs — one accelerator dispatch per CHUNK
        instead of per frame); te-dependent features are derived from
        them host-side, so the same embeddings serve both the matching
        candidates and the GRU updates."""
        cfg = self.cfg
        n = len(dets)
        te_scalar = 0.0 if self._last_frame is None else \
            float(frame_idx - self._last_frame)
        self._last_frame = frame_idx
        C = cfg.crop
        if det_embeds is not None:
            x = det_embeds
        elif n > 0:
            crops = extract_crops(frame, dets, C)
            npad = _pad(n)
            crops_p = np.zeros((npad, C, C, 3), np.float32)
            crops_p[:n] = crops
            x = np.asarray(crop_embed(self.params,
                                      jnp.asarray(crops_p)))[:n]
        else:
            x = np.zeros((0, cfg.embed_dim), np.float32)
        boxes = dets[:, :4].astype(np.float32) if n > 0 else \
            np.zeros((0, 4), np.float32)
        feats = self._det_feats_np(
            x, boxes, np.full((n,), te_scalar, np.float32))

        T = len(self.active)
        pairs = []
        if T > 0 and n > 0:
            hs = np.stack([t.h for t in self.active])
            tboxes = np.stack([t.boxes[-1] for t in self.active])
            te_arr = np.full((n,), max(te_scalar, 1.0), np.float32)
            logits = self._match_np(hs, tboxes, feats, boxes, te_arr)
            probs = 1.0 / (1.0 + np.exp(-logits))
            cost = np.where(probs >= cfg.match_threshold, 1.0 - probs,
                            BIG)
            pairs = self._assign(cost)

        matched_t, matched_d = set(), set()
        upd_feats, upd_tracks = [], []
        for ti, di in pairs:
            t = self.active[ti]
            # GRU update uses the WITHIN-TRACK gap
            gap = float(frame_idx - t.frames[-1])
            upd_tracks.append(t)
            upd_feats.append((di, gap))
            t.frames.append(frame_idx)
            t.boxes.append(dets[di, :4].astype(np.float32))
            t.misses = 0
            matched_t.add(ti)
            matched_d.add(di)
        # age out unmatched
        survivors = []
        for ti, t in enumerate(self.active):
            if ti in matched_t:
                survivors.append(t)
                continue
            t.misses += 1
            if t.misses > self.max_misses:
                self.finished.append(t)
            else:
                survivors.append(t)
        self.active = survivors

        # GRU advance: matched-track updates (t_elapsed = within-track
        # gap, h = track state) and new-track starts (t_elapsed = 0,
        # h = 0) reuse the crop embeddings — no second CNN pass
        new_idx = [di for di in range(n) if di not in matched_d]
        n_upd = len(upd_tracks)
        m = n_upd + len(new_idx)
        if m > 0:
            rows = [di for di, _ in upd_feats] + new_idx
            te_u = np.asarray([g for _, g in upd_feats]
                              + [0.0] * len(new_idx), np.float32)
            hs_p = np.zeros((m, self.cfg.rnn_dim), np.float32)
            for k, t in enumerate(upd_tracks):
                hs_p[k] = t.h
            f_u = self._det_feats_np(x[rows], boxes[rows], te_u)
            h_out = self._gru_np(hs_p, f_u)
            for k, t in enumerate(upd_tracks):
                t.h = h_out[k]
            for k, di in enumerate(new_idx):
                t = _ActiveTrack(self._next_id, h_out[n_upd + k],
                                 [frame_idx],
                                 [dets[di, :4].astype(np.float32)])
                self.active.append(t)
                self._next_id += 1
        # cap active set (static max_tracks capacity)
        if len(self.active) > self.cfg.max_tracks:
            self.active.sort(key=lambda t: -len(t.frames))
            self.finished.extend(self.active[self.cfg.max_tracks:])
            self.active = self.active[:self.cfg.max_tracks]

    def result(self) -> List[np.ndarray]:
        tracks = self.finished + self.active
        return [t.as_array() for t in tracks
                if len(t.frames) >= self.min_hits]


def embed_dets_chunk(params, cfg: TrackerConfig,
                     frames: Sequence[np.ndarray],
                     dets_per_frame: Sequence[np.ndarray],
                     min_bucket: int = 8) -> List[np.ndarray]:
    """Run the crop CNN over every detection in a CHUNK in one
    bucket-padded ``crop_embed`` dispatch (the executor's TRACK-stage
    batching).  Returns per-frame (n_i, embed_dim) crop embeddings,
    bit-identical to per-frame ``RecurrentTracker.step`` computation
    (conv outputs are per-sample independent of batch padding).

    ``min_bucket`` is the bucket floor; the executor scales it with the
    chunk size B so the set of distinct power-of-two buckets — and with
    it the number of ``crop_embed`` jit specializations — stays bounded
    as the tuner proposes larger chunks."""
    C = cfg.crop
    counts = [len(d) for d in dets_per_frame]
    total = sum(counts)
    if total == 0:
        return [np.zeros((0, cfg.embed_dim), np.float32)
                for _ in counts]
    from repro.core.detector import next_bucket
    npad = next_bucket(total, min_bucket=min_bucket)
    crops = np.zeros((npad, C, C, 3), np.float32)
    k = 0
    for frame, dets in zip(frames, dets_per_frame):
        if len(dets):
            crops[k:k + len(dets)] = extract_crops(frame, dets, C)
            k += len(dets)
    x = np.asarray(crop_embed(params, jnp.asarray(crops)))
    out = []
    k = 0
    for n in counts:
        out.append(x[k:k + n])
        k += n
    return out


# PR-1 name for ``embed_dets_chunk`` (same signature, kept for compat)
crop_embed_chunk = embed_dets_chunk
