"""Paper-evaluation driver: everything needed for Table 1, Figures 6-8 and
Table 2 on the 7 synthetic datasets.

For each dataset: train/val/test splits -> MultiScope setup + greedy tune
-> baselines (Chameleon / BlazeIt / Miris) parameter selection on val ->
apply every selected configuration on the UNSEEN test split -> record
(accuracy, runtime) test curves + Table-1-style "fastest within 5% of
best" runtimes.  Results are dumped as JSON artifacts consumed by
benchmarks/*.py.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.configs.multiscope import MULTISCOPE_PIPELINE, PipelineConfig
from repro.core import pipeline as pl
from repro.core import tuner as tuner_mod
from repro.core.baselines import (BlazeItBaseline, ChameleonBaseline,
                                  MirisBaseline)
from repro.core.baselines.chameleon import pareto
from repro.core.executor import run_clips
from repro.core.metrics import clip_count_accuracy, mota
from repro.core.tracker import build_examples
from repro.core.tuner import TunerPoint
from repro.data.video_synth import Clip, make_split


def _streamed_split(bank):
    """Split-level runner over the streaming executor, shared by every
    MultiScope-engine test curve (cross-clip decode prefetch + per-clip
    device round-robin)."""
    def run(pt, clips):
        return run_clips(bank, pt.params, clips)[0]
    return run


def _test_curve(run_fn, points: List[TunerPoint],
                test_clips: Sequence[Clip],
                run_split_fn=None) -> List[Dict[str, Any]]:
    """Apply each selected configuration on the test split.

    ``run_split_fn(pt, clips) -> [RunResult]`` runs a whole split at
    once — the MultiScope curves use ``executor.run_clips`` so clip
    i+1's decode prefetches while clip i computes and clips round-robin
    devices; per-clip ``run_fn`` remains for baselines with their own
    execution loops."""
    out = []
    for pt in points:
        if run_split_fn is not None:
            results = run_split_fn(pt, test_clips)
            accs = [clip_count_accuracy(r.tracks, clip)
                    for r, clip in zip(results, test_clips)]
            secs = sum(r.seconds for r in results)
        else:
            accs, secs, results = [], 0.0, []
            for clip in test_clips:
                r = run_fn(pt, clip)
                accs.append(clip_count_accuracy(r.tracks, clip))
                secs += r.seconds
                results.append(r)
        out.append({
            "params": pt.params.describe(), "module": pt.module,
            "val_accuracy": pt.val_accuracy,
            "val_seconds": pt.val_seconds,
            "test_accuracy": float(np.mean(accs)),
            "test_seconds": secs,
        })
    return out


def table1_runtime(curve: List[Dict[str, Any]], best_acc: float,
                   slack: float = 0.05) -> Optional[float]:
    """Fastest test runtime among configs within ``slack`` of best_acc."""
    ok = [c["test_seconds"] for c in curve
          if c["test_accuracy"] >= best_acc - slack]
    return min(ok) if ok else None


def run_dataset(dataset: str, *, n_train: int = 5, n_val: int = 4,
                n_test: int = 6, n_frames: int = 48,
                detector_steps: int = 400, tracker_steps: int = 1500,
                with_mota: bool = False, with_ablation: bool = False,
                with_limit_query: bool = False,
                log=print) -> Dict[str, Any]:
    t_start = time.time()
    train = make_split(dataset, "train", n_train, n_frames)
    val = make_split(dataset, "val", n_val, n_frames)
    test = make_split(dataset, "test", n_test, n_frames)
    cfg = MULTISCOPE_PIPELINE.reduced()

    # ---- MultiScope -----------------------------------------------------------
    sys = tuner_mod.setup(cfg, train, val, detector_steps=detector_steps,
                          tracker_steps=tracker_steps, log=log)
    ms_curve_val = tuner_mod.tune(sys, val, log=log)
    ms_points = pareto(ms_curve_val)
    ms_curve = _test_curve(None, ms_points, test,
                           run_split_fn=_streamed_split(sys.bank))

    # θ_best labels reused by the baselines (shared substrate, like the
    # paper giving all methods the same pretrained detector)
    det = sys.bank.detectors[sys.theta_best.det_arch]
    train_dets = []
    for clip in train:
        for f in range(0, clip.n_frames, sys.theta_best.gap):
            frame = clip.render(f, *sys.theta_best.det_res)
            dets = det.detect_batch(frame[None],
                                    sys.theta_best.det_conf)[0]
            train_dets.append((clip, f, dets))

    # ---- Chameleon --------------------------------------------------------------
    cham = ChameleonBaseline(sys.bank)
    cham_points = cham.select(val)
    cham_curve = _test_curve(None, cham_points, test,
                             run_split_fn=_streamed_split(sys.bank))

    # ---- BlazeIt ----------------------------------------------------------------
    blaze = BlazeItBaseline(sys.bank)
    blaze.train(train_dets)
    blaze_points = blaze.select(val)
    blaze_curve = _test_curve(
        lambda pt, clip: blaze.run_clip(
            pt.params, clip, float(pt.module.split("=")[1])),
        blaze_points, test)

    # ---- Miris -------------------------------------------------------------------
    miris = MirisBaseline(sys.bank)

    def getter(clip):
        # the bounded LRU render cache (pipeline.render_frame) replaces
        # the old per-run dict, which grew without bound across
        # configurations; decode cost is irrelevant here, only pixels
        def g(f):
            return pl.render_frame(clip, f, *sys.theta_best.det_res)[0]
        return g

    examples = []
    for clip in train:
        r = pl.run_clip(sys.bank, sys.theta_best, clip)
        examples.extend(build_examples(r.tracks, getter(clip),
                                       cfg.tracker.crop,
                                       clip_key=clip.clip_id))
    miris.train(examples, steps=tracker_steps)
    miris_points = miris.select(val)
    miris_curve = _test_curve(
        lambda pt, clip: miris.run_clip(
            pt.params, clip, float(pt.module.split("=")[1])),
        miris_points, test)

    curves = {"multiscope": ms_curve, "chameleon": cham_curve,
              "blazeit": blaze_curve, "miris": miris_curve}
    best_acc = max(c["test_accuracy"] for cv in curves.values()
                   for c in cv)
    table1 = {name: table1_runtime(cv, best_acc)
              for name, cv in curves.items()}

    result: Dict[str, Any] = {
        "dataset": dataset,
        "n_clips": {"train": n_train, "val": n_val, "test": n_test},
        "theta_best": sys.theta_best.describe(),
        "setup_seconds": sys.setup_seconds,
        "curves": curves,
        "best_accuracy": best_acc,
        "table1_runtime_at_5pct": table1,
        "wall_seconds": time.time() - t_start,
    }

    if with_mota:
        result["mota"] = mota_crosscheck(sys, ms_points, test[:3], log=log)
    if with_ablation:
        result["ablation"] = ablation(sys, val, test, log=log)
    if with_limit_query:
        lq_clips = make_split(dataset, "test", n_test + 6, n_frames)
        result["limit_query"] = limit_query_experiment(
            sys, blaze, lq_clips, log=log)
    return result


def mota_crosscheck(sys, points: List[TunerPoint],
                    clips: Sequence[Clip], log=print) -> List[Dict]:
    """Fig 8: count accuracy vs MOTA over candidate configurations."""
    out = []
    for pt in points:
        accs, motas = [], []
        for clip in clips:
            r = pl.run_clip(sys.bank, pt.params, clip)
            accs.append(clip_count_accuracy(r.tracks, clip))
            motas.append(mota(r.tracks, clip,
                              frames=range(0, clip.n_frames,
                                           pt.params.gap)))
        out.append({"params": pt.params.describe(),
                    "count_accuracy": float(np.mean(accs)),
                    "mota": float(np.mean(motas))})
        log(f"[fig8] {pt.params.describe()} count={np.mean(accs):.3f} "
            f"mota={np.mean(motas):.3f}")
    return out


def ablation(sys, val_clips: Sequence[Clip], test_clips: Sequence[Clip],
             log=print) -> Dict[str, List[Dict]]:
    """Fig 7: detector-only -> +SORT -> +recurrent -> +proxy (full)."""
    cfg = sys.bank.cfg
    variants: Dict[str, List[TunerPoint]] = {}

    # 1. detection module only (tuner over arch x res, SORT implicit for
    #    track formation, native rate)
    pts = []
    for arch in cfg.detector.archs:
        for res in cfg.detector.resolutions:
            p = pl.PipelineParams(arch, res, cfg.detector.confidences[1],
                                  gap=1, tracker="sort", refine=False)
            a, t = tuner_mod._evaluate(sys.bank, p, val_clips)
            pts.append(TunerPoint(p, a, t))
    variants["detector-only"] = pareto(pts)

    # 2. + SORT over gaps
    pts = []
    for arch in cfg.detector.archs:
        for res in cfg.detector.resolutions:
            for gap in cfg.tracker.gaps:
                p = pl.PipelineParams(arch, res,
                                      cfg.detector.confidences[1],
                                      gap=gap, tracker="sort",
                                      refine=False)
                a, t = tuner_mod._evaluate(sys.bank, p, val_clips)
                pts.append(TunerPoint(p, a, t))
    variants["+sort"] = pareto(pts)

    # 3. + recurrent tracker (with refinement)
    pts = []
    for res in cfg.detector.resolutions:
        for gap in cfg.tracker.gaps:
            p = pl.PipelineParams(cfg.detector.archs[-1], res,
                                  cfg.detector.confidences[1], gap=gap,
                                  tracker="recurrent", refine=True)
            a, t = tuner_mod._evaluate(sys.bank, p, val_clips)
            pts.append(TunerPoint(p, a, t))
    variants["+recurrent"] = pareto(pts)

    # 4. full (tuner output incl. proxy) — reuse sys.curve
    variants["+proxy(full)"] = pareto(sys.curve) if sys.curve else []

    out = {}
    for name, points in variants.items():
        out[name] = _test_curve(None, points, test_clips,
                                run_split_fn=_streamed_split(sys.bank))
        log(f"[fig7] {name}: {len(points)} pareto points")
    return out


def limit_query_experiment(sys, blaze: BlazeItBaseline,
                           clips: Sequence[Clip], *, want: int = 10,
                           min_count: int = 3,
                           region=(0.0, 0.5, 1.0, 1.0),
                           store_root: Optional[str] = None,
                           log=print) -> Dict[str, Any]:
    """Table 2: BlazeIt limit query vs MultiScope extract-once-serve-many.

    Find ``want`` frames with >= min_count objects in the bottom half,
    >= 2s apart.  The BlazeIt side searches per query (proxy ranking +
    detector probes).  The MultiScope side goes through the track store
    subsystem: the FIRST query materializes tracks for the whole query
    set (``TrackStore.ingest`` through the streaming executor), every
    later query scans the packed arrays in milliseconds — the reported
    ``query_seconds`` is the plan scan, ``pre_seconds`` the one-time
    ingest, and ``warm_query_seconds`` a repeat of the same query
    against the warm store (zero detector calls)."""
    import tempfile

    from repro.query import Query, QueryService, TrackStore

    fps = clips[0].profile.fps
    spacing = 2 * fps
    params = sys.theta_best

    # BlazeIt (unchanged: per-query search is the point of comparison)
    bz = blaze.limit_query(clips, params, want=want, min_count=min_count,
                           region=region, min_spacing=spacing)
    # verify against ground truth
    bz_correct = sum(
        1 for ci, f in bz["found"]
        if _gt_count_region(clips[ci], f, region) >= min_count)

    # MultiScope: materialize tracks once, serve the query from the store
    fastest = None
    for pt in (sys.curve or []):
        if fastest is None or pt.val_seconds < fastest.val_seconds:
            if pt.val_accuracy >= max(
                    p.val_accuracy for p in sys.curve) - 0.05:
                fastest = pt
    ms_params = (fastest or TunerPoint(params, 0, 0)).params
    root = store_root or tempfile.mkdtemp(prefix="trackstore_")
    try:
        store = TrackStore(root, sys.bank, ms_params)
        service = QueryService(store)
        q = Query.limit_frames(region=region, min_count=min_count,
                               want=want, min_spacing=spacing)
        cold = service.query(q, clips)      # ingest + first scan
        warm = service.query(q, clips)      # served entirely from store
        if warm.stats.ingested_clips != 0 or warm.frames != cold.frames:
            raise RuntimeError(
                "warm store disagreed with the cold scan: "
                f"re-ingested {warm.stats.ingested_clips} clips, "
                f"frames {warm.frames} vs {cold.frames}")
        found = cold.frames
    finally:
        if store_root is None:              # we made the dir; remove it
            import shutil
            shutil.rmtree(root, ignore_errors=True)
    ms_correct = sum(
        1 for ci, f in found
        if _gt_count_region(clips[ci], f, region) >= min_count)

    return {
        "want": want, "min_count": min_count,
        "blazeit": {"pre_seconds": bz["pre_seconds"],
                    "query_seconds": bz["query_seconds"],
                    "detector_frames": bz["detector_frames"],
                    "found": len(bz["found"]), "correct": bz_correct},
        "multiscope": {"pre_seconds": cold.stats.ingest_seconds,
                       "query_seconds": cold.stats.scan_seconds,
                       "warm_query_seconds": warm.stats.total_seconds,
                       "store_fingerprint": store.fingerprint,
                       "found": len(found), "correct": ms_correct},
    }


def _gt_count_region(clip: Clip, frame: int, region) -> int:
    boxes = clip.boxes_at(frame)
    if len(boxes) == 0:
        return 0
    m = ((boxes[:, 0] >= region[0]) & (boxes[:, 0] <= region[2])
         & (boxes[:, 1] >= region[1]) & (boxes[:, 1] <= region[3]))
    return int(m.sum())


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", default="all")
    ap.add_argument("--out", default="artifacts/paper")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--mota", action="store_true")
    args = ap.parse_args()
    from repro.data.video_synth import DATASETS
    names = list(DATASETS) if args.datasets == "all" \
        else args.datasets.split(",")
    os.makedirs(args.out, exist_ok=True)
    kw = dict(n_train=3, n_val=3, n_test=3, detector_steps=150,
              tracker_steps=600) if args.quick else {}
    for name in names:
        path = os.path.join(args.out, f"{name}.json")
        if os.path.exists(path):
            print(f"[paper] cached {name}")
            continue
        print(f"[paper] ==== {name} ====", flush=True)
        res = run_dataset(
            name, with_mota=args.mota or name == "caldot1",
            with_ablation=name == "caldot1",
            with_limit_query=name == "jackson", **kw)
        with open(path, "w") as f:
            json.dump(res, f, indent=1, default=float)
        print(f"[paper] {name}: table1={res['table1_runtime_at_5pct']} "
              f"wall={res['wall_seconds']:.0f}s", flush=True)


if __name__ == "__main__":
    main()
