"""Cell-grouping and fixed window-size-set selection (§3.3).

Host-side control logic (numpy), mirroring the paper's CPU-side grouping
next to the accelerator:

  * ``group_cells`` — positive cells -> rectangular windows drawn from the
    fixed size set S: connected components first (objects span cells), then
    density-based agglomerative merging that accepts a merge whenever the
    merged window is estimated FASTER than processing the parts separately;
  * ``select_window_sizes`` — the offline greedy choice of S (|S| = k,
    always containing the full frame): iteratively add the candidate size
    minimizing ``tot_time`` = sum over training frames of est(R(I_t; S)),
    assuming a perfect proxy (positive cells = θ_best detections).

Window sizes and positions are in CELL units (multiples of the proxy cell
= 32 px at full scale), which is also what makes the TPU ``window_gather``
kernel a pure block DMA.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

Size = Tuple[int, int]          # (w_cells, h_cells)
Window = Tuple[int, int, Size]  # (x_cell, y_cell, size)


@dataclass
class SizeSet:
    """The fixed set S with per-size detector execution times (seconds)."""
    sizes: List[Size]            # sizes[0] is always the full frame
    times: Dict[Size, float]

    @property
    def full(self) -> Size:
        return self.sizes[0]

    def smallest_covering(self, w: int, h: int) -> Optional[Size]:
        """Smallest-area size covering (w, h) cells; None -> full frame."""
        best = None
        for s in self.sizes:
            if s[0] >= w and s[1] >= h:
                if best is None or s[0] * s[1] < best[0] * best[1]:
                    best = s
        return best

    def est(self, windows: Sequence[Window]) -> float:
        return sum(self.times[s] for _, _, s in windows)


def connected_components(grid: np.ndarray) -> List[np.ndarray]:
    """grid: (hc, wc) {0,1} -> list of (n, 2) [y, x] cell index arrays
    (4-connectivity)."""
    hc, wc = grid.shape
    seen = np.zeros_like(grid, bool)
    comps = []
    for y0, x0 in zip(*np.nonzero(grid)):
        if seen[y0, x0]:
            continue
        stack = [(y0, x0)]
        seen[y0, x0] = True
        cells = []
        while stack:
            y, x = stack.pop()
            cells.append((y, x))
            for dy, dx in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                yy, xx = y + dy, x + dx
                if 0 <= yy < hc and 0 <= xx < wc and grid[yy, xx] \
                        and not seen[yy, xx]:
                    seen[yy, xx] = True
                    stack.append((yy, xx))
        comps.append(np.asarray(cells, np.int64))
    return comps


def _bbox(cells: np.ndarray) -> Tuple[int, int, int, int]:
    y0, x0 = cells.min(axis=0)
    y1, x1 = cells.max(axis=0)
    return int(x0), int(y0), int(x1 - x0 + 1), int(y1 - y0 + 1)


def group_cells(grid: np.ndarray, sizeset: SizeSet,
                max_windows: int = 8) -> List[Window]:
    """Positive-cell grid -> windows covering all positive cells.

    Returns [] for an empty grid (frame fully skipped).  Falls back to one
    full-frame window when a cluster exceeds every size in S or the window
    count exceeds ``max_windows`` (static per-frame capacity)."""
    hc, wc = grid.shape
    full = sizeset.full
    comps = connected_components(grid)
    if not comps:
        return []

    def size_or_full(w: int, h: int) -> Size:
        s = sizeset.smallest_covering(w, h)
        return s if s is not None else full

    clusters: List[np.ndarray] = comps
    # agglomerative merging: keep merging while some merge reduces est time
    merged_any = True
    while merged_any and len(clusters) > 1:
        merged_any = False
        i = 0
        while i < len(clusters):
            ci = clusters[i]
            # closest neighbor by centroid distance
            cen = np.array([c.mean(axis=0) for c in clusters])
            d = np.linalg.norm(cen - cen[i], axis=1)
            d[i] = np.inf
            j = int(np.argmin(d))
            if not np.isfinite(d[j]):
                break
            prop = [i, j]
            merged_cells = np.concatenate([clusters[i], clusters[j]])
            x, y, w, h = _bbox(merged_cells)
            s_merged = size_or_full(w, h)
            # absorb any other cluster that fits without a larger window
            for k in range(len(clusters)):
                if k in prop:
                    continue
                trial = np.concatenate([merged_cells, clusters[k]])
                tx, ty, tw, th = _bbox(trial)
                if size_or_full(tw, th) == s_merged \
                        and tw <= s_merged[0] and th <= s_merged[1]:
                    merged_cells = trial
                    prop.append(k)
            t_merged = sizeset.times[s_merged]
            t_split = 0.0
            for k in prop:
                x_, y_, w_, h_ = _bbox(clusters[k])
                t_split += sizeset.times[size_or_full(w_, h_)]
            if t_merged < t_split:
                clusters = [c for k, c in enumerate(clusters)
                            if k not in prop] + [merged_cells]
                merged_any = True
            else:
                i += 1

    windows: List[Window] = []
    for cells in clusters:
        x, y, w, h = _bbox(cells)
        s = sizeset.smallest_covering(w, h)
        if s is None:
            return [(0, 0, full)]
        # place the window to cover the bbox, clamped inside the grid
        wx = min(x, wc - s[0])
        wy = min(y, hc - s[1])
        windows.append((max(wx, 0), max(wy, 0), s))
    if len(windows) > max_windows:
        return [(0, 0, full)]
    # estimated-cost sanity: never worse than one full frame
    if sizeset.est(windows) >= sizeset.times[full]:
        return [(0, 0, full)]
    return windows


# ---------------------------------------------------------------------------
# Chunk planning (the staged engine's host-side stage 2->3 boundary)
# ---------------------------------------------------------------------------

@dataclass
class ChunkPlan:
    """Window plan for one chunk of frames.

    ``windows``  — per-frame planned windows, in ``group_cells`` order
                   (what the per-frame reference path would have run);
    ``by_size``  — size class -> [(frame_slot, x_cell, y_cell, win_idx)]
                   across the whole chunk, the detector's cross-frame
                   batch grouping.  ``win_idx`` is the window's index in
                   its frame's ``windows`` list, so per-frame detection
                   merge order can be reconstructed exactly.
    """
    windows: List[List[Window]]
    by_size: Dict[Size, List[Tuple[int, int, int, int]]]


def plan_chunk(grids: Sequence[np.ndarray], sizeset: SizeSet,
               max_windows: int = 8,
               chunk_size: Optional[int] = None) -> ChunkPlan:
    """Plan windows for a whole chunk of positive-cell grids on the host,
    grouping same-size windows across frames for batched execution.

    ``chunk_size`` is the executor's (tuner-visible) B: a plan never
    spans more frames than one chunk, and frame slots index into the
    chunk's (B, H, W, 3) buffer — passing it catches mismatched
    plumbing early instead of as a silent bad gather."""
    if chunk_size is not None and len(grids) > chunk_size:
        raise ValueError(f"planning {len(grids)} frames into a chunk "
                         f"of {chunk_size}")
    per_frame = [group_cells(g, sizeset, max_windows) for g in grids]
    return ChunkPlan(per_frame, _group_by_size(per_frame))


def _group_by_size(per_frame: List[List[Window]]
                   ) -> Dict[Size, List[Tuple[int, int, int, int]]]:
    by_size: Dict[Size, List[Tuple[int, int, int, int]]] = {}
    for slot, wins in enumerate(per_frame):
        for wi, (x, y, s) in enumerate(wins):
            by_size.setdefault(s, []).append((slot, x, y, wi))
    return by_size


def _single_rect_windows(grid_shape: Tuple[int, int], x: int, y: int,
                         w: int, h: int, sizeset: SizeSet) -> List[Window]:
    """``group_cells`` specialized to one filled-rectangle component:
    the merging loop is a no-op at one cluster, so only the placement +
    cost-sanity tail remains."""
    hc, wc = grid_shape
    full = sizeset.full
    s = sizeset.smallest_covering(w, h)
    if s is None:
        return [(0, 0, full)]
    wx = min(x, wc - s[0])
    wy = min(y, hc - s[1])
    windows: List[Window] = [(max(wx, 0), max(wy, 0), s)]
    if sizeset.est(windows) >= sizeset.times[full]:
        return [(0, 0, full)]
    return windows


def plan_from_mapped(grids: Sequence[np.ndarray],
                     stats: Sequence[np.ndarray], sizeset: SizeSet,
                     max_windows: int = 8,
                     chunk_size: Optional[int] = None) -> ChunkPlan:
    """Plan a chunk from the fused kernel's outputs: already-mapped
    detector grids plus per-frame stats rows [count, ymin, ymax, xmin,
    xmax, ...] (``repro.kernels.proxy_plan``).

    Bit-identical to ``plan_chunk`` over host-mapped grids.  The stats
    enable two exact shortcuts — an empty frame skips grouping outright,
    and count == bbox area forces a single filled-rectangle component
    (every bbox cell positive => one 4-connected cluster), where
    ``group_cells`` provably reduces to ``_single_rect_windows``.  Any
    other support falls back to ``group_cells`` on the mapped grid."""
    if chunk_size is not None and len(grids) > chunk_size:
        raise ValueError(f"planning {len(grids)} frames into a chunk "
                         f"of {chunk_size}")
    per_frame: List[List[Window]] = []
    for grid, st in zip(grids, stats):
        count, ymin, ymax, xmin, xmax = (int(v) for v in st[:5])
        if count == 0:
            per_frame.append([])
            continue
        w, h = xmax - xmin + 1, ymax - ymin + 1
        if count == w * h:
            per_frame.append(_single_rect_windows(
                grid.shape, xmin, ymin, w, h, sizeset))
        else:
            per_frame.append(group_cells(np.asarray(grid), sizeset,
                                         max_windows))
    return ChunkPlan(per_frame, _group_by_size(per_frame))


def full_frame_plan(n_frames: int, sizeset: SizeSet) -> ChunkPlan:
    """The no-proxy plan: one full-frame window per frame."""
    full = sizeset.full
    wins: List[List[Window]] = [[(0, 0, full)] for _ in range(n_frames)]
    return ChunkPlan(wins, {full: [(slot, 0, 0, 0)
                                   for slot in range(n_frames)]})


# ---------------------------------------------------------------------------
# Offline size-set selection
# ---------------------------------------------------------------------------

def detector_time_model(full_size: Size, t_full: float,
                        overhead_frac: float = 0.25
                        ) -> Callable[[Size], float]:
    """Analytic per-size time: fixed dispatch overhead + pixel-linear
    term, calibrated so the full frame costs ``t_full``.  Used during size
    selection (measuring every candidate would need one jit per size);
    the k CHOSEN sizes are then measured for real by the tuner cache."""
    area_full = full_size[0] * full_size[1]
    t0 = t_full * overhead_frac

    def t(size: Size) -> float:
        return t0 + (t_full - t0) * (size[0] * size[1]) / area_full
    return t


def select_window_sizes(grids: Sequence[np.ndarray], full_size: Size,
                        k: int, time_fn: Callable[[Size], float],
                        max_windows: int = 8) -> List[Size]:
    """Greedy S selection over training-frame positive grids (assumed
    perfect-proxy = cells of θ_best detections)."""
    wc_full, hc_full = full_size
    candidates = [(w, h)
                  for w in range(1, wc_full + 1)
                  for h in range(1, hc_full + 1)
                  if (w, h) != full_size]
    S: List[Size] = [full_size]

    def tot_time(sizes: List[Size]) -> float:
        ss = SizeSet(sizes, {s: time_fn(s) for s in sizes})
        return sum(ss.est(group_cells(g, ss, max_windows)) for g in grids)

    for _ in range(k - 1):
        best_s, best_t = None, tot_time(S)
        for cand in candidates:
            if cand in S:
                continue
            t = tot_time(S + [cand])
            if t < best_t - 1e-12:
                best_t, best_s = t, cand
        if best_s is None:
            break
        S.append(best_s)
    return S
