"""Accuracy metrics: pattern-count accuracy (the paper's hand-label
metric, §4) and MOTA (§4.3 cross-check).

Count accuracy: tracks are classified into the profile's spatial patterns
by nearest start/end endpoints against the pattern polylines; per-clip
accuracy = mean over patterns of  1 - |pred - gt| / max(gt, 1), floored at
0 — matching the paper's "percent accuracy averaged over patterns and
clips".

MOTA = 1 - (FN + FP + IDSW) / GT, computed per frame with IoU >= 0.3
Hungarian matching and identity bookkeeping.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.detector import iou_matrix
from repro.core.hungarian import hungarian, hungarian_batch, BIG
from repro.data.video_synth import Clip, Profile, _interp


def classify_track(track: np.ndarray, profile: Profile) -> Optional[int]:
    """track: (m, 6) world units -> pattern id (nearest path by endpoint
    + midpoint distance) or None for stubs."""
    if len(track) < 2:
        return None
    start, end = track[0, 1:3], track[-1, 1:3]
    mid = track[len(track) // 2, 1:3]
    best, best_d = None, np.inf
    for pid, path in enumerate(profile.paths):
        p0 = np.asarray(_interp(path.waypoints, 0.02))
        p1 = np.asarray(_interp(path.waypoints, 0.98))
        pm = np.asarray(_interp(path.waypoints, 0.5))
        d = (np.linalg.norm(start - p0) + np.linalg.norm(end - p1)
             + 0.5 * np.linalg.norm(mid - pm))
        if d < best_d:
            best_d, best = d, pid
    return best


def pattern_counts(tracks: Sequence[np.ndarray], profile: Profile,
                   min_len: int = 2) -> np.ndarray:
    counts = np.zeros(profile.patterns(), np.int64)
    for t in tracks:
        if len(t) < min_len:
            continue          # ignore single-detection stubs (paper §4.2)
        pid = classify_track(t, profile)
        if pid is not None:
            counts[pid] += 1
    return counts


def count_accuracy(pred_counts: np.ndarray, gt_counts: np.ndarray
                   ) -> float:
    """Mean over patterns of 1 - |pred-gt|/max(gt,1), floored at 0."""
    acc = 1.0 - np.abs(pred_counts - gt_counts) / np.maximum(gt_counts, 1)
    return float(np.clip(acc, 0.0, 1.0).mean())


def clip_count_accuracy(tracks: Sequence[np.ndarray], clip: Clip
                        ) -> float:
    return count_accuracy(pattern_counts(tracks, clip.profile),
                          clip.pattern_counts())


# ---------------------------------------------------------------------------
# MOTA
# ---------------------------------------------------------------------------

def mota(tracks: Sequence[np.ndarray], clip: Clip,
         frames: Optional[Sequence[int]] = None,
         iou_thresh: float = 0.3, assign: str = "host") -> float:
    """Multi-Object Tracking Accuracy against the clip's exact GT.

    ``assign="batch"`` solves EVERY frame's IoU association in one
    batched device dispatch (``hungarian_batch`` over the Pallas assign
    kernel) instead of one host Hungarian per frame — the per-frame
    cost matrices here are mutually independent, unlike the recurrent
    tracker's.  Min-cost totals match the host solver exactly;
    equal-cost tie-breaks may pick different pairs, which can shift
    IDSW on pathological ties, so "host" stays the default."""
    assert assign in ("host", "batch")
    if frames is None:
        frames = range(clip.n_frames)
    # index predictions: frame -> (boxes, ids)
    pred_by_frame: Dict[int, List[Tuple[np.ndarray, int]]] = {}
    for t in tracks:
        for row in t:
            pred_by_frame.setdefault(int(row[0]), []).append(
                (row[1:5], int(row[5])))
    # first pass: per-frame GT + cost matrices (independent across
    # frames — the batchable part)
    work: List[Tuple[int, np.ndarray, List[Tuple[np.ndarray, int]],
                     Optional[np.ndarray]]] = []
    for f in frames:
        gt = clip.boxes_at(f)
        preds = pred_by_frame.get(f, [])
        if len(gt) == 0 and len(preds) == 0:
            continue
        cost = None
        if len(gt) > 0 and len(preds) > 0:
            pb = np.stack([p[0] for p in preds])
            iou = iou_matrix(gt[:, :4], pb)
            cost = np.where(iou >= iou_thresh, 1.0 - iou, BIG)
        work.append((f, gt, preds, cost))
    if assign == "batch":
        costs = [c for _, _, _, c in work if c is not None]
        solved = iter(hungarian_batch(costs))
        pairs_for = [next(solved) if c is not None else []
                     for _, _, _, c in work]
    else:
        pairs_for = [hungarian(c) if c is not None else []
                     for _, _, _, c in work]
    # second pass: sequential identity bookkeeping
    fn = fp = idsw = gt_total = 0
    last_match: Dict[int, int] = {}      # gt id -> pred id
    for (f, gt, preds, cost), pairs in zip(work, pairs_for):
        gt_total += len(gt)
        if len(preds) == 0:
            fn += len(gt)
            continue
        matched_gt = set()
        matched_pred = set()
        for gi, pi in pairs:
            gid = int(gt[gi, 4])
            pid = preds[pi][1]
            if gid in last_match and last_match[gid] != pid:
                idsw += 1
            last_match[gid] = pid
            matched_gt.add(gi)
            matched_pred.add(pi)
        fn += len(gt) - len(matched_gt)
        fp += len(preds) - len(matched_pred)
    if gt_total == 0:
        return 1.0 if fp == 0 else 0.0
    return 1.0 - (fn + fp + idsw) / gt_total
