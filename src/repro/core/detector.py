"""Single-shot anchor-free object detector (the pipeline's expensive model).

The paper treats the detector as a pluggable black box with an
(architecture, input resolution) menu (YOLOv3 / Mask R-CNN at several
resolutions); this repo registers two architectures of different depths,
``ssd-lite`` and ``ssd-deep``, preserving the tuner's arch-choice
dimension.

Design: strided conv backbone to stride ``S`` (16), then a 1x1 head
predicting per cell [objectness, dx, dy, log w, log h].  A cell is
positive when an object center falls inside it; boxes are regressed
relative to the cell (center offset in [0,1]) and the frame (log-size).
The same network applies to full frames AND to the proxy-selected windows
(any HxW divisible by the stride) — one jit specialization per input
size and power-of-two batch bucket, which is exactly the paper's
"initialize the detector at each of the k fixed window sizes" with the
chunked engine's cross-frame batching layered on top.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ParamBuilder, build

STRIDE = 16

ARCHS: Dict[str, Tuple[Tuple[int, ...], Tuple[int, ...]]] = {
    # name -> (channels per block, extra 3x3 convs per block)
    "ssd-lite": ((12, 24, 48, 96), (0, 0, 0, 0)),
    "ssd-deep": ((16, 32, 64, 128), (1, 1, 1, 1)),
}


def _conv(pb: ParamBuilder, name: str, cin: int, cout: int, k: int = 3
          ) -> None:
    with pb.scope(name):
        pb.param("w", (k, k, cin, cout), (None, None, None, "mlp"),
                 scale=(1.0 / np.sqrt(k * k * cin)))
        pb.param("b", (cout,), (None,), init="zeros")


def _apply_conv(p, x, stride: int = 1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def def_detector(pb: ParamBuilder, arch: str) -> None:
    chans, extras = ARCHS[arch]
    cin = 3
    for i, (c, extra) in enumerate(zip(chans, extras)):
        _conv(pb, f"block{i}_down", cin, c)
        for j in range(extra):
            _conv(pb, f"block{i}_conv{j}", c, c)
        cin = c
    _conv(pb, "head", cin, 5, k=1)


def init_detector(arch: str, seed: int = 0):
    return build(functools.partial(def_detector, arch=arch), "init",
                 seed=seed)


@functools.partial(jax.jit, static_argnames=("arch",))
def detector_raw(params, frames, arch: str):
    """frames: (B, H, W, 3) -> (B, H/S, W/S, 5) raw head outputs."""
    chans, extras = ARCHS[arch]
    x = frames
    for i in range(len(chans)):
        x = jax.nn.relu(_apply_conv(params[f"block{i}_down"], x, stride=2))
        for j in range(extras[i]):
            x = jax.nn.relu(_apply_conv(params[f"block{i}_conv{j}"], x))
    return _apply_conv(params["head"], x)


def detector_loss(params, frames, obj_target, box_target, arch: str):
    """obj_target: (B, Hc, Wc) {0,1}; box_target: (B, Hc, Wc, 4)."""
    out = detector_raw(params, frames, arch)
    obj_logit = out[..., 0]
    box = out[..., 1:]
    obj = obj_target.astype(jnp.float32)
    bce = jnp.maximum(obj_logit, 0) - obj_logit * obj \
        + jnp.log1p(jnp.exp(-jnp.abs(obj_logit)))
    # class-balanced normalization: positives are ~5-10% of cells, so a
    # plain mean starves them of gradient and confidences stall below any
    # usable threshold
    n_pos = jnp.maximum(obj.sum(), 1.0)
    n_neg = jnp.maximum((1 - obj).sum(), 1.0)
    bce = (bce * obj).sum() / n_pos + (bce * (1 - obj)).sum() / n_neg
    l1 = jnp.sum(jnp.abs(box - box_target) * obj[..., None]) \
        / (n_pos * 4)
    return bce + l1


def make_targets(boxes_list: List[np.ndarray], hc: int, wc: int
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """boxes: per-frame (n, >=4) [cx, cy, w, h] world units -> targets."""
    B = len(boxes_list)
    obj = np.zeros((B, hc, wc), np.float32)
    box = np.zeros((B, hc, wc, 4), np.float32)
    for b, boxes in enumerate(boxes_list):
        for row in boxes:
            cx, cy, w, h = row[:4]
            j = min(int(cx * wc), wc - 1)
            i = min(int(cy * hc), hc - 1)
            obj[b, i, j] = 1.0
            # sizes in CELL units: input-resolution invariant (an object's
            # pixel size is what the conv net sees, full frame or window)
            box[b, i, j] = [cx * wc - j, cy * hc - i,
                            np.log(max(w * wc, 1e-3)),
                            np.log(max(h * hc, 1e-3))]
    return obj, box


@functools.partial(jax.jit, static_argnames=("arch",))
def _detect_scores(params, frames, arch: str):
    out = detector_raw(params, frames, arch)
    return jax.nn.sigmoid(out[..., 0]), out[..., 1:]


def decode_detections(scores: np.ndarray, boxes: np.ndarray,
                      conf: float, origin: Tuple[float, float] = (0.0, 0.0),
                      scale: Tuple[float, float] = (1.0, 1.0),
                      max_dets: int = 64) -> np.ndarray:
    """One frame's head outputs -> (n, 5) [cx, cy, w, h, score] world
    units.  origin/scale place a WINDOW's cells into the full frame:
    world = origin + cell_frac * scale."""
    hc, wc = scores.shape
    ii, jj = np.nonzero(scores > conf)
    if len(ii) == 0:
        return np.zeros((0, 5), np.float32)
    sc = scores[ii, jj]
    order = np.argsort(-sc)[:max_dets * 4]
    ii, jj, sc = ii[order], jj[order], sc[order]
    bx = boxes[ii, jj]
    cx = origin[0] + (jj + np.clip(bx[:, 0], 0, 1)) / wc * scale[0]
    cy = origin[1] + (ii + np.clip(bx[:, 1], 0, 1)) / hc * scale[1]
    w = np.exp(np.clip(bx[:, 2], -5, 5)) / wc * scale[0]
    h = np.exp(np.clip(bx[:, 3], -5, 5)) / hc * scale[1]
    dets = np.stack([cx, cy, w, h, sc], axis=1).astype(np.float32)
    return nms(dets)[:max_dets]


def nms(dets: np.ndarray, iou_thresh: float = 0.45) -> np.ndarray:
    if len(dets) <= 1:
        return dets
    order = np.argsort(-dets[:, 4])
    # one pairwise IoU matrix instead of O(n^2) scalar iou() calls;
    # greedy suppression order is unchanged
    m = iou_matrix(dets[order, :4], dets[order, :4])
    keep = []
    for i, idx in enumerate(order):
        if not keep or not (m[i, keep] > iou_thresh).any():
            keep.append(i)
    return dets[order[keep]]


def iou(a: np.ndarray, b: np.ndarray) -> float:
    ax0, ay0 = a[0] - a[2] / 2, a[1] - a[3] / 2
    ax1, ay1 = a[0] + a[2] / 2, a[1] + a[3] / 2
    bx0, by0 = b[0] - b[2] / 2, b[1] - b[3] / 2
    bx1, by1 = b[0] + b[2] / 2, b[1] + b[3] / 2
    ix = max(0.0, min(ax1, bx1) - max(ax0, bx0))
    iy = max(0.0, min(ay1, by1) - max(ay0, by0))
    inter = ix * iy
    union = a[2] * a[3] + b[2] * b[3] - inter
    return inter / union if union > 0 else 0.0


def iou_matrix(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """a: (n,4), b: (m,4) [cx,cy,w,h] -> (n,m) IoU."""
    if len(a) == 0 or len(b) == 0:
        return np.zeros((len(a), len(b)), np.float32)
    ax0 = a[:, 0] - a[:, 2] / 2
    ay0 = a[:, 1] - a[:, 3] / 2
    ax1 = a[:, 0] + a[:, 2] / 2
    ay1 = a[:, 1] + a[:, 3] / 2
    bx0 = b[:, 0] - b[:, 2] / 2
    by0 = b[:, 1] - b[:, 3] / 2
    bx1 = b[:, 0] + b[:, 2] / 2
    by1 = b[:, 1] + b[:, 3] / 2
    ix = np.maximum(0, np.minimum(ax1[:, None], bx1[None]) -
                    np.maximum(ax0[:, None], bx0[None]))
    iy = np.maximum(0, np.minimum(ay1[:, None], by1[None]) -
                    np.maximum(ay0[:, None], by0[None]))
    inter = ix * iy
    union = (a[:, 2] * a[:, 3])[:, None] + (b[:, 2] * b[:, 3])[None] - inter
    return np.where(union > 0, inter / union, 0.0).astype(np.float32)


def next_bucket(n: int, min_bucket: int = 1) -> int:
    """Smallest power-of-two >= n (>= min_bucket).  Batch dims are padded
    to these buckets so jit specializations stay one per
    (arch, input size, bucket) instead of one per exact batch count."""
    b = max(1, min_bucket)
    while b < n:
        b *= 2
    return b


def pad_to_bucket(arr: np.ndarray, min_bucket: int = 1) -> np.ndarray:
    """Zero-pad arr's leading (batch) dim to the next power-of-two
    bucket.  Returns arr unchanged when already bucket-sized."""
    n = int(arr.shape[0])
    b = next_bucket(n, min_bucket)
    if b == n:
        return arr
    padded = np.zeros((b,) + tuple(arr.shape[1:]),
                      np.asarray(arr).dtype)
    padded[:n] = arr
    return padded


class Detector:
    """Stateful wrapper: params + arch + jit cache per input size."""

    def __init__(self, arch: str, params=None, seed: int = 0):
        self.arch = arch
        self.params = params if params is not None else init_detector(
            arch, seed)
        # dispatch counter: the track store's re-ingest guarantee
        # ("zero detector calls on a warm split") is asserted against it.
        # Kept a plain per-instance int (benches reset it directly); each
        # increment also folds into the global obs registry.
        self.dispatches = 0
        from repro.obs.metrics import REGISTRY
        self._m_dispatches = REGISTRY.counter("detector.dispatches")

    def detect_batch(self, frames: np.ndarray, conf: float,
                     origins=None, scales=None, max_dets: int = 64,
                     n_valid: Optional[int] = None) -> List[np.ndarray]:
        """frames: (B, H, W, 3) -> list of (n, 5) world-unit detections.

        origins/scales: per-frame window placement (see
        decode_detections); default full frame.  n_valid: decode only the
        first n_valid rows (the rest are bucket padding)."""
        self.dispatches += 1
        self._m_dispatches.inc()
        scores, boxes = _detect_scores(self.params,
                                       jnp.asarray(frames), self.arch)
        scores = np.asarray(scores)
        n = frames.shape[0] if n_valid is None else n_valid
        hit = (scores[:n] > conf).any(axis=(1, 2))
        boxes = np.asarray(boxes) if hit.any() else None
        empty = np.zeros((0, 5), np.float32)
        out = []
        for b in range(n):
            if not hit[b]:
                out.append(empty)
                continue
            o = origins[b] if origins is not None else (0.0, 0.0)
            s = scales[b] if scales is not None else (1.0, 1.0)
            out.append(decode_detections(scores[b], boxes[b], conf,
                                         origin=o, scale=s,
                                         max_dets=max_dets))
        return out

    def detect_batch_bucketed(self, frames: np.ndarray, conf: float,
                              origins=None, scales=None,
                              max_dets: int = 64) -> List[np.ndarray]:
        """detect_batch with the batch dim zero-padded to a power-of-two
        bucket.  Padding rows are never decoded; conv outputs are
        per-sample independent, so real rows are bit-identical to an
        unpadded call."""
        n = int(frames.shape[0])
        if n == 0:
            return []
        return self.detect_batch(pad_to_bucket(frames), conf,
                                 origins=origins, scales=scales,
                                 max_dets=max_dets, n_valid=n)


def detect_jit_entries() -> int:
    """Number of live jit specializations of the detector forward pass —
    the benchmark's bound is one per (arch, input size, bucket).
    Returns -1 when jax stops exposing the (private) cache-size hook."""
    cache_size = getattr(_detect_scores, "_cache_size", None)
    return int(cache_size()) if cache_size is not None else -1
