"""Offline model training for the pipeline: detector pre-training, proxy
training (on θ_best detections), and tracker training (on θ_best tracks).

The paper assumes a PRE-TRAINED detector (YOLOv3 etc.); here the stand-in
detector is trained once per dataset on synthetic ground truth — this cost
sits outside the benchmarked runtime exactly like the paper's pretrained
weights.  Proxy and tracker training follow the paper: labels come from
the θ_best configuration's outputs, never from ground truth.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import detector as det_mod
from repro.data.video_synth import Clip
from repro.optim import adamw


def _fit(loss_fn, params, batches, lr: float = 3e-3, log=None):
    """Generic Adam fit: batches is an iterable of arg-tuples."""
    opt = adamw(lr=lr, weight_decay=0.0)
    state = opt.init(params)
    vg = jax.jit(jax.value_and_grad(loss_fn))
    losses = []
    for args in batches:
        loss, g = vg(params, *args)
        params, state = opt.update(g, state, params)
        losses.append(float(loss))
        if log and len(losses) % 50 == 0:
            log(f"  step {len(losses)} loss {np.mean(losses[-50:]):.4f}")
    return params, losses


# ---------------------------------------------------------------------------
# Detector
# ---------------------------------------------------------------------------

def train_detector(arch: str, clips: Sequence[Clip],
                   resolutions: Sequence[Tuple[int, int]],
                   steps: int = 240, batch: int = 8, seed: int = 0,
                   lr: float = 3e-3) -> det_mod.Detector:
    """Multi-resolution detector pre-training on synthetic GT boxes."""
    params = det_mod.init_detector(arch, seed)
    rng = np.random.default_rng(seed)
    S = det_mod.STRIDE

    def batches():
        for step in range(steps):
            W, H = resolutions[step % len(resolutions)]
            hc, wc = H // S, W // S
            frames, boxes = [], []
            for _ in range(batch):
                clip = clips[rng.integers(len(clips))]
                f = int(rng.integers(clip.n_frames))
                frames.append(clip.render(f, W, H))
                boxes.append(clip.boxes_at(f))
            obj, box = det_mod.make_targets(boxes, hc, wc)
            yield (jnp.asarray(np.stack(frames)), jnp.asarray(obj),
                   jnp.asarray(box))

    loss_fn = lambda p, f, o, b: det_mod.detector_loss(p, f, o, b, arch)  # noqa
    params, losses = _fit(loss_fn, params, batches(), lr=lr)
    return det_mod.Detector(arch, params), losses


def detector_f1(detector: det_mod.Detector, clips: Sequence[Clip],
                res: Tuple[int, int], conf: float = 0.4,
                n_frames: int = 40) -> float:
    """Quick detection quality check against GT (IoU>=0.3 matching)."""
    tp = fp = fn = 0
    rng = np.random.default_rng(1)
    for _ in range(n_frames):
        clip = clips[rng.integers(len(clips))]
        f = int(rng.integers(clip.n_frames))
        frame = clip.render(f, res[0], res[1])
        dets = detector.detect_batch(frame[None], conf)[0]
        gt = clip.boxes_at(f)
        iou = det_mod.iou_matrix(dets[:, :4], gt[:, :4])
        matched_gt = set()
        for i in np.argsort(-dets[:, 4] if len(dets) else []):
            j = int(np.argmax(iou[i])) if iou.shape[1] else -1
            if j >= 0 and iou[i, j] >= 0.3 and j not in matched_gt:
                matched_gt.add(j)
                tp += 1
            else:
                fp += 1
        fn += len(gt) - len(matched_gt)
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    return 2 * prec * rec / max(prec + rec, 1e-9)
