"""Hungarian algorithm (min-cost assignment), host-side numpy.

Classic O(n^3) potentials + augmenting-path formulation (Jonker-Volgenant
style).  Rectangular matrices are padded with a large cost; pairs matched
to padding are reported as unmatched.  Used by the recurrent tracker, the
SORT baseline, and the MOTA metric.

Hardware note (DESIGN.md §2): the paper runs Hungarian on the host CPU
next to a GPU; we keep the same split on TPU — association matrices are
tiny (<= max_tracks^2 = 64^2) so the assignment is host-side, bridged
with ``jax.pure_callback`` when embedded in an on-device loop
(``hungarian_on_device``).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

try:                                    # already in the image; optional
    from scipy.optimize import linear_sum_assignment as _lsa
except ImportError:                     # pragma: no cover
    _lsa = None

BIG = 1e9


def hungarian(cost: np.ndarray) -> List[Tuple[int, int]]:
    """cost: (n, m) -> list of (row, col) matched pairs (only real pairs;
    entries with cost >= BIG/2 are treated as forbidden).

    Dispatches to scipy's C implementation when available (it ships in
    the container); ``_hungarian_np`` is the dependency-free fallback.
    Both return a min-cost assignment — tie-breaking between equal-cost
    optima may differ, totals never do."""
    n, m = cost.shape
    if n == 0 or m == 0:
        return []
    if _lsa is not None:
        rows, cols = _lsa(cost)
        return [(int(r), int(c)) for r, c in zip(rows, cols)
                if cost[r, c] < BIG / 2]
    return _hungarian_np(cost)


def _hungarian_np(cost: np.ndarray) -> List[Tuple[int, int]]:
    """Pure-numpy Jonker-Volgenant: rectangular matrices are solved
    directly with rows = the SHORT side (transposing when n > m), so a
    few detections against max_tracks tracks runs min(n, m) augmenting
    paths instead of max(n, m)."""
    n, m = cost.shape
    if n == 0 or m == 0:
        return []
    if n > m:
        return sorted((r, c) for c, r in _hungarian_np(cost.T))
    a = np.full((n + 1, m + 1), BIG, np.float64)
    a[1:, 1:] = cost
    u = np.zeros(n + 1)
    v = np.zeros(m + 1)
    p = np.zeros(m + 1, np.int64)         # p[j] = row matched to col j
    way = np.zeros(m + 1, np.int64)
    for i in range(1, n + 1):
        p[0] = i
        j0 = 0
        minv = np.full(m + 1, np.inf)
        used = np.zeros(m + 1, bool)
        while True:
            used[j0] = True
            i0 = p[j0]
            cur = a[i0, 1:] - u[i0] - v[1:]
            # vectorized column scan: update minv/way over unused columns
            # and pick the argmin (first index on ties, matching the
            # scalar loop this replaces — it dominated association cost
            # at max_tracks=64)
            free = ~used[1:]
            take = free & (cur < minv[1:])
            minv[1:][take] = cur[take]
            way[1:][take] = j0
            masked = np.where(free, minv[1:], np.inf)
            j1 = int(np.argmin(masked)) + 1
            delta = masked[j1 - 1]
            u[p[used]] += delta
            v[np.flatnonzero(used)] -= delta
            minv[~used] -= delta
            j0 = j1
            if p[j0] == 0:
                break
        while j0:
            j1 = way[j0]
            p[j0] = p[j1]
            j0 = j1
    pairs = []
    for j in range(1, m + 1):
        i = int(p[j])
        if i >= 1 and cost[i - 1, j - 1] < BIG / 2:
            pairs.append((i - 1, j - 1))
    return pairs


def hungarian_on_device(cost):
    """On-device bridge: col index per row (-1 = unmatched) via
    pure_callback into the numpy solver (association matrices are tiny)."""
    import jax
    import jax.numpy as jnp

    n = cost.shape[0]

    def _cb(c):
        pairs = hungarian(np.asarray(c))
        out = np.full((n,), -1, np.int32)
        for r, cc in pairs:
            out[r] = cc
        return out

    return jax.pure_callback(_cb, jax.ShapeDtypeStruct((n,), jnp.int32),
                             cost)
