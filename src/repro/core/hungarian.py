"""Hungarian algorithm (min-cost assignment), host-side numpy.

Classic O(n^3) potentials + augmenting-path formulation (Jonker-Volgenant
style).  Rectangular matrices are padded with a large cost; pairs matched
to padding are reported as unmatched.  Used by the recurrent tracker, the
SORT baseline, and the MOTA metric.

Hardware note (DESIGN.md §2): the paper runs Hungarian on the host CPU
next to a GPU; per-step association keeps that split by default.  The
batched Pallas solver (``repro.kernels.assign``) now covers the on-device
side: ``hungarian_batch`` solves a stack of independent problems in one
dispatch (MOTA's per-frame matrices, opt-in tracker assignment), and
``hungarian_on_device`` runs entirely on device instead of bridging
through ``jax.pure_callback``.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

try:                                    # already in the image; optional
    from scipy.optimize import linear_sum_assignment as _lsa
except ImportError:                     # pragma: no cover
    _lsa = None

BIG = 1e9
# finite forbidden sentinel for the f32 device solver: large enough that
# any assignment using fewer forbidden edges wins (N * max real cost
# <= 64 * 2 << 2^13), small enough that f32 potential updates keep real
# cost differences resolvable
FORBIDDEN_DEVICE = 2.0 ** 13


def hungarian(cost: np.ndarray) -> List[Tuple[int, int]]:
    """cost: (n, m) -> list of (row, col) matched pairs (only real pairs;
    entries with cost >= BIG/2 are treated as forbidden).

    Dispatches to scipy's C implementation when available (it ships in
    the container); ``_hungarian_np`` is the dependency-free fallback.
    Both return a min-cost assignment — tie-breaking between equal-cost
    optima may differ, totals never do."""
    n, m = cost.shape
    if n == 0 or m == 0:
        return []
    if _lsa is not None:
        rows, cols = _lsa(cost)
        return [(int(r), int(c)) for r, c in zip(rows, cols)
                if cost[r, c] < BIG / 2]
    return _hungarian_np(cost)


def _hungarian_np(cost: np.ndarray) -> List[Tuple[int, int]]:
    """Pure-numpy Jonker-Volgenant: rectangular matrices are solved
    directly with rows = the SHORT side (transposing when n > m), so a
    few detections against max_tracks tracks runs min(n, m) augmenting
    paths instead of max(n, m).  Pairs come back row-sorted (the same
    ordering scipy's dispatch path emits)."""
    n, m = cost.shape
    if n == 0 or m == 0:
        return []
    if n > m:
        # invert the transposed solution with an O(n) counting pass —
        # the old path swapped axes then ran a full comparison sort on
        # output the solver had already ordered once
        col_of = np.full(n, -1, np.int64)
        for c, r in _hungarian_np(cost.T):
            col_of[r] = c
        return [(r, int(c)) for r, c in enumerate(col_of) if c >= 0]
    a = np.full((n + 1, m + 1), BIG, np.float64)
    a[1:, 1:] = cost
    u = np.zeros(n + 1)
    v = np.zeros(m + 1)
    p = np.zeros(m + 1, np.int64)         # p[j] = row matched to col j
    way = np.zeros(m + 1, np.int64)
    for i in range(1, n + 1):
        p[0] = i
        j0 = 0
        minv = np.full(m + 1, np.inf)
        used = np.zeros(m + 1, bool)
        while True:
            used[j0] = True
            i0 = p[j0]
            cur = a[i0, 1:] - u[i0] - v[1:]
            # vectorized column scan: update minv/way over unused columns
            # and pick the argmin (first index on ties, matching the
            # scalar loop this replaces — it dominated association cost
            # at max_tracks=64)
            free = ~used[1:]
            take = free & (cur < minv[1:])
            minv[1:][take] = cur[take]
            way[1:][take] = j0
            masked = np.where(free, minv[1:], np.inf)
            j1 = int(np.argmin(masked)) + 1
            delta = masked[j1 - 1]
            u[p[used]] += delta
            v[np.flatnonzero(used)] -= delta
            minv[~used] -= delta
            j0 = j1
            if p[j0] == 0:
                break
        while j0:
            j1 = way[j0]
            p[j0] = p[j1]
            j0 = j1
    # emit ROW-sorted (the contract, matching scipy) via linear inversion
    # of the col -> row matching instead of sorting afterwards
    col_of = np.full(n, -1, np.int64)
    for j in range(1, m + 1):
        i = int(p[j])
        if i >= 1 and cost[i - 1, j - 1] < BIG / 2:
            col_of[i - 1] = j - 1
    return [(r, int(c)) for r, c in enumerate(col_of) if c >= 0]


def solve_device_np(cost: np.ndarray) -> np.ndarray:
    """Numpy float32 twin of ``kernels.assign.kernel.solve_one`` — a
    line-by-line port (same update order, same first-index argmin
    tie-break, same f32 arithmetic), so its output is bit-identical to
    the device solver on the same matrix.  cost: (N, N) finite f32 ->
    (N,) int32 matched column per row (full permutation)."""
    cost = np.asarray(cost, np.float32)
    N = cost.shape[0]
    a = np.zeros((N + 1, N + 1), np.float32)
    a[1:, 1:] = cost
    rows1 = np.arange(N + 1, dtype=np.int32)
    u = np.zeros(N + 1, np.float32)
    v = np.zeros(N + 1, np.float32)
    p = np.zeros(N + 1, np.int32)
    for i in range(1, N + 1):
        p[0] = i
        j0 = 0
        way = np.zeros(N + 1, np.int32)
        minv = np.full(N + 1, np.inf, np.float32)
        used = np.zeros(N + 1, bool)
        while p[j0] != 0:
            used[j0] = True
            i0 = p[j0]
            cur = (a[i0] - u[i0]) - v                    # f32 (N+1,)
            free = ~used
            take = free & (cur < minv)
            minv = np.where(take, cur, minv)
            way = np.where(take, j0, way).astype(np.int32)
            masked = np.where(free, minv, np.float32(np.inf))
            j1 = int(np.argmin(masked))                  # first index on ties
            delta = masked[j1]
            row_hit = ((p[None, :] == rows1[:, None])
                       & used[None, :]).any(1)
            u = np.where(row_hit, u + delta, u).astype(np.float32)
            v = np.where(used, v - delta, v).astype(np.float32)
            minv = np.where(free, minv - delta, minv).astype(np.float32)
            j0 = j1
        while j0:
            j1 = way[j0]
            p[j0] = p[j1]
            j0 = j1
    col_of = np.zeros(N, np.int32)
    col_of[p[1:] - 1] = np.arange(N, dtype=np.int32)
    return col_of


def assoc_side(n: int, m: int, min_bucket: int = 8) -> int:
    """Canonical square size for tracker association: the power-of-two
    bucket of max(n, m), floored at ``min_bucket``.  Every association
    path — this host twin, the per-frame fused kernel, and the chunk
    scan (via ``solve_one``'s dynamic ``eff_n``) — solves EXACTLY this
    square, because f32 JV results are not invariant to the padded
    size: a forced forbidden match pushes sentinel-scale deltas through
    the potentials, and the rounding of real-cost differences then
    depends on which padding columns the search walked."""
    side = max(1, min_bucket)
    need = max(n, m)
    while side < need:
        side *= 2
    return side


def hungarian_device_np(cost: np.ndarray) -> List[Tuple[int, int]]:
    """Host twin of the DEVICE association path: pad to the canonical
    ``assoc_side`` square with the finite ``FORBIDDEN_DEVICE``
    sentinel, solve with the f32 JV twin, filter forbidden pairs — the
    same contract as ``hungarian_batch`` for a batch of one, minus the
    device dispatch.

    Used by ``RecurrentTracker`` so that its pair selection (ties
    included) is bit-identical to ``kernels.track_step``'s on-device
    assignment, which restricts its solve to the same square via
    ``solve_one(eff_n=...)`` no matter how many slots its buffers
    carry."""
    n, m = cost.shape
    if n == 0 or m == 0:
        return []
    side = assoc_side(n, m)
    sq = np.full((side, side), FORBIDDEN_DEVICE, np.float32)
    sq[:n, :m] = np.minimum(cost, FORBIDDEN_DEVICE)
    cols = solve_device_np(sq)
    return [(r, int(cols[r])) for r in range(n)
            if cols[r] < m and cost[r, cols[r]] < BIG / 2]


def hungarian_batch(costs: Sequence[np.ndarray]
                    ) -> List[List[Tuple[int, int]]]:
    """Solve K independent (possibly rectangular) assignment problems in
    ONE device dispatch via the batched Pallas solver
    (``repro.kernels.assign``).

    Same contract as ``hungarian`` per problem: entries >= BIG/2 are
    forbidden and never reported.  Matrices are padded to a common
    square with the finite ``FORBIDDEN_DEVICE`` sentinel (the device
    solver runs f32, so real costs must stay << 2^13 — association
    costs here are <= 1).  Tie-breaking between equal-cost optima may
    differ from the host solvers; totals never do."""
    mats = [np.asarray(c, np.float32) for c in costs]
    if not mats:
        return []
    n_max = max((c.shape[0] for c in mats), default=0)
    m_max = max((c.shape[1] for c in mats), default=0)
    side = max(n_max, m_max)
    if side == 0 or all(c.shape[0] == 0 or c.shape[1] == 0 for c in mats):
        return [[] for _ in mats]
    from repro.kernels.assign import assign_batch   # lazy: jax + cycle

    batch = np.full((len(mats), side, side), FORBIDDEN_DEVICE, np.float32)
    for k, c in enumerate(mats):
        n, m = c.shape
        batch[k, :n, :m] = np.minimum(c, FORBIDDEN_DEVICE)
    cols = np.asarray(assign_batch(batch))
    out: List[List[Tuple[int, int]]] = []
    for k, c in enumerate(mats):
        n, m = c.shape
        out.append([(r, int(cols[k, r])) for r in range(n)
                    if cols[k, r] < m and c[r, cols[k, r]] < BIG / 2])
    return out


def hungarian_on_device(cost):
    """On-device assignment: col index per row (-1 = unmatched), computed
    entirely on device by the batched Pallas solver — no host callback.
    cost: (n, m) array with BIG-style forbidden entries."""
    import jax.numpy as jnp
    from repro.kernels.assign import assign_batch   # lazy: jax + cycle

    n, m = cost.shape
    side = max(n, m)
    c = jnp.minimum(cost.astype(jnp.float32), FORBIDDEN_DEVICE)
    c = jnp.pad(c, ((0, side - n), (0, side - m)),
                constant_values=FORBIDDEN_DEVICE)
    cols = assign_batch(c[None])[0][:n]
    orig = jnp.pad(cost.astype(jnp.float32), ((0, 0), (0, side - m)),
                   constant_values=np.float32(BIG))[:n]
    got = jnp.take_along_axis(orig, cols[:, None], axis=1)[:, 0]
    return jnp.where((cols < m) & (got < BIG / 2), cols, -1)
