"""Hungarian algorithm (min-cost assignment), host-side numpy.

Classic O(n^3) potentials + augmenting-path formulation (Jonker-Volgenant
style).  Rectangular matrices are padded with a large cost; pairs matched
to padding are reported as unmatched.  Used by the recurrent tracker, the
SORT baseline, and the MOTA metric.

Hardware note (DESIGN.md §2): the paper runs Hungarian on the host CPU
next to a GPU; we keep the same split on TPU — association matrices are
tiny (<= max_tracks^2 = 64^2) so the assignment is host-side, bridged
with ``jax.pure_callback`` when embedded in an on-device loop
(``hungarian_on_device``).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

BIG = 1e9


def hungarian(cost: np.ndarray) -> List[Tuple[int, int]]:
    """cost: (n, m) -> list of (row, col) matched pairs (only real pairs;
    entries with cost >= BIG/2 are treated as forbidden)."""
    n, m = cost.shape
    if n == 0 or m == 0:
        return []
    size = max(n, m)
    a = np.full((size + 1, size + 1), BIG, np.float64)
    a[1:n + 1, 1:m + 1] = cost
    u = np.zeros(size + 1)
    v = np.zeros(size + 1)
    p = np.zeros(size + 1, np.int64)      # p[j] = row matched to col j
    way = np.zeros(size + 1, np.int64)
    for i in range(1, size + 1):
        p[0] = i
        j0 = 0
        minv = np.full(size + 1, np.inf)
        used = np.zeros(size + 1, bool)
        while True:
            used[j0] = True
            i0 = p[j0]
            delta = np.inf
            j1 = -1
            cur = a[i0, 1:] - u[i0] - v[1:]
            for j in range(1, size + 1):
                if used[j]:
                    continue
                if cur[j - 1] < minv[j]:
                    minv[j] = cur[j - 1]
                    way[j] = j0
                if minv[j] < delta:
                    delta = minv[j]
                    j1 = j
            u[p[used]] += delta
            v[np.flatnonzero(used)] -= delta
            minv[~used] -= delta
            j0 = j1
            if p[j0] == 0:
                break
        while j0:
            j1 = way[j0]
            p[j0] = p[j1]
            j0 = j1
    pairs = []
    for j in range(1, size + 1):
        i = int(p[j])
        if 1 <= i <= n and 1 <= j <= m and cost[i - 1, j - 1] < BIG / 2:
            pairs.append((i - 1, j - 1))
    return pairs


def hungarian_on_device(cost):
    """On-device bridge: col index per row (-1 = unmatched) via
    pure_callback into the numpy solver (association matrices are tiny)."""
    import jax
    import jax.numpy as jnp

    n = cost.shape[0]

    def _cb(c):
        pairs = hungarian(np.asarray(c))
        out = np.full((n,), -1, np.int32)
        for r, cc in pairs:
            out[r] = cc
        return out

    return jax.pure_callback(_cb, jax.ShapeDtypeStruct((n,), jnp.int32),
                             cost)
