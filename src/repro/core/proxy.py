"""Segmentation proxy model (§3.3): a small strided-conv encoder + 2-layer
decoder scoring every CxC pixel cell with P(cell intersects a detection).

The head (1x1 conv + sigmoid + threshold -> binary positive-cell grid) is
the fused ``proxy_score`` Pallas kernel on TPU; the encoder is standard
conv layers.  Training labels come from θ_best detections (never ground
truth), per the paper.

The cell size C is configurable (paper: 32; the reduced CPU pipeline uses
8) — the encoder applies log2(C) stride-2 convs, then two 3x3 decoder
convs at cell resolution.
"""
from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.detector import _apply_conv, _conv, pad_to_bucket
from repro.kernels.proxy_score import proxy_score
from repro.kernels.proxy_plan import proxy_plan
from repro.models.common import ParamBuilder, build


def _n_levels(cell: int) -> int:
    n = int(np.log2(cell))
    assert 2 ** n == cell, f"cell {cell} must be a power of two"
    return n


def def_proxy(pb: ParamBuilder, cell: int, base_channels: int) -> None:
    n = _n_levels(cell)
    cin = 3
    for i in range(n):
        c = base_channels * min(2 ** i, 8)
        _conv(pb, f"enc{i}", cin, c)
        cin = c
    _conv(pb, "dec0", cin, cin)
    # dec1 is the fused head: declared as a 1x1 conv, applied via the
    # proxy_score kernel (w: (cin,), b: scalar)
    with pb.scope("head"):
        pb.param("w", (cin,), (None,), scale=1.0 / np.sqrt(cin))
        pb.param("b", (1,), (None,), init="zeros")


def init_proxy(cell: int, base_channels: int, seed: int = 0):
    return build(functools.partial(def_proxy, cell=cell,
                                   base_channels=base_channels),
                 "init", seed=seed)


@functools.partial(jax.jit, static_argnames=("cell",))
def proxy_features(params, frames, cell: int):
    """frames: (B, H, W, 3) -> (B, H/C, W/C, channels)."""
    n = _n_levels(cell)
    x = frames
    for i in range(n):
        x = jax.nn.relu(_apply_conv(params[f"enc{i}"], x, stride=2))
    return jax.nn.relu(_apply_conv(params["dec0"], x))


@functools.partial(jax.jit, static_argnames=("cell",))
def proxy_scores(params, frames, cell: int, threshold: float = 0.5):
    """-> (scores (B, Hc, Wc) fp32, positive (B, Hc, Wc) int8) via the
    fused head kernel."""
    feat = proxy_features(params, frames, cell)
    return proxy_score(feat, params["head"]["w"], params["head"]["b"][0],
                       threshold)


def proxy_loss(params, frames, cell_labels, cell: int):
    """cell_labels: (B, Hc, Wc) {0,1} from θ_best detections."""
    feat = proxy_features(params, frames, cell)
    logits = jnp.einsum("bhwc,c->bhw", feat, params["head"]["w"]) \
        + params["head"]["b"][0]
    y = cell_labels.astype(jnp.float32)
    bce = jnp.maximum(logits, 0) - logits * y \
        + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    n_pos = jnp.maximum(y.sum(), 1.0)
    n_neg = jnp.maximum((1 - y).sum(), 1.0)
    return (bce * y).sum() / n_pos + (bce * (1 - y)).sum() / n_neg


def threshold_sweep(score_grids: Sequence[np.ndarray],
                    label_grids: Sequence[np.ndarray],
                    thresholds: Sequence[float]
                    ) -> List[Tuple[float, float, float]]:
    """The paper's threshold sweep over CACHED validation score grids.

    For each candidate threshold: cell-level recall of the labelled
    positive cells (labels = θ_best detections rasterized with
    ``cells_from_detections``) and the positive-cell rate (the proxy's
    selectivity — what the window planner actually pays for).  Score
    grids are computed once per resolution and reused across the whole
    sweep, so adding thresholds costs microseconds, not proxy runs.

    -> [(threshold, recall, positive_rate)] in input threshold order.
    """
    out: List[Tuple[float, float, float]] = []
    for th in thresholds:
        covered = total = pos = cells = 0
        for s, y in zip(score_grids, label_grids):
            p = s > th
            lab = y > 0
            covered += int((p & lab).sum())
            total += int(lab.sum())
            pos += int(p.sum())
            cells += p.size
        out.append((float(th), covered / max(total, 1),
                    pos / max(cells, 1)))
    return out


def sweep_candidates(score_grids: Sequence[np.ndarray],
                     base_thresholds: Sequence[float] = (),
                     quantiles: Sequence[float] = (0.5, 0.75, 0.9)
                     ) -> List[float]:
    """Candidate thresholds for the sweep: the configured menu plus
    quantiles of the cached score distribution.  Trained proxies
    concentrate scores far from 0.5, and untrained ones sit in a narrow
    band around it — quantile candidates keep the sweep meaningful for
    both instead of evaluating a fixed grid that may be all-positive or
    all-negative."""
    flat = np.concatenate([np.asarray(s).ravel() for s in score_grids])
    qs = [float(np.quantile(flat, q)) for q in quantiles]
    return sorted({round(float(t), 6) for t in
                   list(base_thresholds) + qs})


def calibrate_threshold(score_grids: Sequence[np.ndarray],
                        label_grids: Sequence[np.ndarray],
                        thresholds: Sequence[float] = (),
                        min_recall: float = 0.95) -> float:
    """Pick the LARGEST threshold (sparsest positive grids, cheapest
    window plans) whose cell recall stays >= ``min_recall``; fall back
    to the best-recall candidate when none reaches the target.  This is
    the trained-proxy calibration the ROADMAP queued — it replaces the
    old self-calibration against the untrained score distribution."""
    cand = sweep_candidates(score_grids, thresholds)
    sweep = threshold_sweep(score_grids, label_grids, cand)
    ok = [th for th, recall, _ in sweep if recall >= min_recall]
    if ok:
        return max(ok)
    return max(sweep, key=lambda e: (e[1], e[0]))[0]


def cells_from_detections(dets: np.ndarray, hc: int, wc: int
                          ) -> np.ndarray:
    """Label a cell 1 if any detection box INTERSECTS it (paper wording).

    dets: (n, >=4) [cx, cy, w, h] world units -> (hc, wc) int8."""
    grid = np.zeros((hc, wc), np.int8)
    for row in dets:
        cx, cy, w, h = row[:4]
        x0 = int(np.clip((cx - w / 2) * wc, 0, wc - 1e-6))
        x1 = int(np.clip((cx + w / 2) * wc, 0, wc - 1e-6))
        y0 = int(np.clip((cy - h / 2) * hc, 0, hc - 1e-6))
        y1 = int(np.clip((cy + h / 2) * hc, 0, hc - 1e-6))
        grid[y0:y1 + 1, x0:x1 + 1] = 1
    return grid


class ProxyModel:
    """One trained proxy at one input resolution."""

    def __init__(self, cell: int, base_channels: int,
                 resolution: Tuple[int, int], params=None, seed: int = 0):
        self.cell = cell
        self.resolution = resolution                      # (W, H)
        self.params = params if params is not None else init_proxy(
            cell, base_channels, seed)

    def grid_shape(self) -> Tuple[int, int]:
        W, H = self.resolution
        return H // self.cell, W // self.cell

    def scores(self, frame: np.ndarray, threshold: float = 0.5
               ) -> Tuple[np.ndarray, np.ndarray]:
        s, p = proxy_scores(self.params, jnp.asarray(frame[None]),
                            self.cell, threshold)
        return np.asarray(s[0]), np.asarray(p[0])

    def scores_batch(self, frames: np.ndarray, threshold: float = 0.5
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Score a CHUNK of frames in one dispatch.  frames: (B, H, W, 3)
        -> ((B, Hc, Wc) scores, (B, Hc, Wc) int8 positives).  The batch
        dim is zero-padded to a power-of-two bucket so jit
        specializations stay bounded; padding rows are dropped."""
        n = int(frames.shape[0])
        if n == 0:
            hc, wc = self.grid_shape()
            return (np.zeros((0, hc, wc), np.float32),
                    np.zeros((0, hc, wc), np.int8))
        s, p = proxy_scores(self.params, jnp.asarray(
            pad_to_bucket(frames)), self.cell, threshold)
        return np.asarray(s[:n]), np.asarray(p[:n])

    def plan_batch(self, frames: np.ndarray, threshold: float,
                   det_grid: Tuple[int, int]
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Fused score + threshold + detector-grid mapping for a CHUNK
        (``repro.kernels.proxy_plan``): only the mapped (B, hc, wc) int8
        grids and (B, 8) int32 plan stats cross back to the host, not
        the full score map.  ``det_grid`` is (wc, hc), matching
        ``pipeline.det_grid``.  Batch padding as ``scores_batch``."""
        wc, hc = det_grid
        n = int(frames.shape[0])
        if n == 0:
            return (np.zeros((0, hc, wc), np.int8),
                    np.zeros((0, 8), np.int32))
        feat = proxy_features(self.params, jnp.asarray(
            pad_to_bucket(frames)), self.cell)
        grids, stats = proxy_plan(feat, self.params["head"]["w"],
                                  self.params["head"]["b"][0], threshold,
                                  grid_hw=(hc, wc))
        return np.asarray(grids[:n]), np.asarray(stats[:n])
