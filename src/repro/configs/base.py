"""Typed configuration tree for the repro framework.

Every architecture in the zoo (the 10 assigned archs plus the paper's own
MultiScope pipeline) is described by a frozen dataclass config.  Configs are
pure data: building a model from a config never touches jax device state, so
configs can be imported anywhere (including before XLA_FLAGS tricks in the
dry-run launcher).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Model families
# ---------------------------------------------------------------------------

FAMILIES = (
    "dense",      # decoder-only transformer (GQA)
    "moe",        # decoder-only transformer with mixture-of-experts FFN
    "ssm",        # attention-free state-space model (Mamba2 / SSD)
    "hybrid",     # Mamba2 backbone with shared attention blocks (Zamba2)
    "encdec",     # encoder-decoder transformer (Whisper)
    "vlm",        # decoder transformer with a vision-patch frontend (Pixtral)
    "pipeline",   # the paper's video-analytics pipeline (MultiScope)
)


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration."""
    n_experts: int = 0            # routed experts
    top_k: int = 0                # experts per token
    n_shared: int = 0             # always-on shared experts
    expert_d_ff: int = 0          # hidden size of each routed/shared expert
    dense_first_n: int = 0        # first N layers use a dense FFN instead
    dense_d_ff: int = 0           # hidden size of that dense FFN
    router_jitter: float = 0.0
    capacity_factor: float = 1.25  # per-expert capacity = cf * tokens/ experts * top_k
    aux_loss_coef: float = 0.001

    @property
    def enabled(self) -> bool:
        return self.n_experts > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (state-space duality) block configuration."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style layout: groups of SSM layers punctuated by a SHARED
    attention+MLP block (one set of weights reused at every attention site)."""
    ssm_per_group: int = 5        # SSM layers per group before the shared block
    n_groups: int = 13            # number of (ssm_per_group + shared-attn) groups
    tail_ssm: int = 3             # trailing SSM layers after the last group
    n_shared_blocks: int = 2      # distinct shared blocks, alternated (Zamba2 uses 2)

    @property
    def total_layers(self) -> int:
        return self.n_groups * (self.ssm_per_group + 1) + self.tail_ssm


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB: input_specs() supplies precomputed embeddings.

    kind='audio'  -> (batch, n_frames, d_model) frame embeddings (Whisper conv
                     frontend output stand-in)
    kind='vision' -> (batch, n_patches, d_model) patch embeddings (Pixtral ViT
                     output stand-in), merged into the token stream at
                     placeholder positions.
    """
    kind: str = "none"            # none | audio | vision
    n_embeds: int = 0             # frames or patches per example


@dataclass(frozen=True)
class ModelConfig:
    """One architecture from the assigned pool (or a reduced smoke version)."""
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                     # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # enc-dec
    n_encoder_layers: int = 0
    # family-specific sub-configs
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    hybrid: Optional[HybridConfig] = None
    frontend: FrontendConfig = field(default_factory=FrontendConfig)
    # training-time knobs (defaults; overridable per run)
    remat: str = "full"                   # none | dots | full
    scan_layers: bool = True
    # shard attention q rows over the model axis when n_heads doesn't
    # divide it (context parallelism for small-head archs; see §Perf)
    attention_qseq_sp: bool = False
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # provenance
    source: str = ""

    # -- derived ------------------------------------------------------------
    def __post_init__(self) -> None:
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_heads > 0 and self.n_kv_heads > 0:
            if self.n_heads % self.n_kv_heads != 0:
                raise ValueError(
                    f"{self.name}: n_heads={self.n_heads} not divisible by "
                    f"n_kv_heads={self.n_kv_heads}")

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can decode at 500k context without a dense
        full-attention KV sweep (SSM state or hybrid w/ small attn share)."""
        return self.family in ("ssm", "hybrid")

    # -- parameter counting (for roofline MODEL_FLOPS = 6 N D) --------------
    def param_count(self) -> int:
        return _param_count(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k routed experts)."""
        return _param_count(self, active_only=True)

    # -- reduced config for CPU smoke tests ---------------------------------
    def reduced(self) -> "ModelConfig":
        """A tiny same-family config: small layers/width/experts/vocab."""
        kw: Dict[str, Any] = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=64,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(2, self.n_kv_heads) if self.n_kv_heads else 0,
            head_dim=16 if self.n_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            remat="none",
        )
        if self.moe.enabled:
            kw["moe"] = replace(
                self.moe, n_experts=4, top_k=2,
                n_shared=min(1, self.moe.n_shared),
                expert_d_ff=32,
                dense_first_n=min(1, self.moe.dense_first_n),
                dense_d_ff=128 if self.moe.dense_first_n else 0)
        if self.family in ("ssm", "hybrid"):
            kw["ssm"] = replace(self.ssm, d_state=16, head_dim=16,
                                chunk_size=16)
        if self.hybrid is not None:
            kw["hybrid"] = HybridConfig(ssm_per_group=1, n_groups=2,
                                        tail_ssm=1, n_shared_blocks=2)
            kw["n_layers"] = kw["hybrid"].total_layers
        if self.frontend.kind != "none":
            kw["frontend"] = replace(self.frontend, n_embeds=8)
        return replace(self, **kw)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    """Analytic parameter count matching the layer definitions in
    repro.models (kept in sync by tests/test_param_count.py)."""
    d = cfg.d_model
    if cfg.family == "pipeline":
        return 0

    def attn_params(q_dim: int, kv_dim: int, bias: bool) -> int:
        n = d * q_dim + 2 * d * kv_dim + q_dim * d
        if bias:
            n += q_dim + 2 * kv_dim
        return n

    def mlp_params(d_ff: int) -> int:
        # SwiGLU: gate + up + down
        return 3 * d * d_ff

    def ssm_params() -> int:
        s = cfg.ssm
        d_in = s.d_inner(d)
        nh = s.n_heads(d)
        conv_dim = d_in + 2 * s.n_groups * s.d_state
        in_proj = d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)
        conv = conv_dim * s.d_conv + conv_dim
        norm = d_in
        out_proj = d_in * d
        # nh * 3: A_log, dt_bias, D (one scalar per SSM head each)
        return in_proj + conv + nh * 3 + norm + out_proj

    total = 0
    emb = cfg.vocab_size * d
    total += emb
    if not cfg.tie_embeddings:
        total += emb                   # lm head

    if cfg.family in ("dense", "vlm"):
        per_layer = attn_params(cfg.q_dim, cfg.kv_dim, cfg.qkv_bias) \
            + mlp_params(cfg.d_ff) + 2 * d
        total += cfg.n_layers * per_layer + d
    elif cfg.family == "moe":
        m = cfg.moe
        attn = attn_params(cfg.q_dim, cfg.kv_dim, cfg.qkv_bias)
        n_moe_layers = cfg.n_layers - m.dense_first_n
        dense_layers = m.dense_first_n * (attn + mlp_params(m.dense_d_ff) + 2 * d)
        router = d * m.n_experts
        shared = m.n_shared * 3 * d * m.expert_d_ff
        if active_only:
            routed = m.top_k * 3 * d * m.expert_d_ff
        else:
            routed = m.n_experts * 3 * d * m.expert_d_ff
        moe_layers = n_moe_layers * (attn + router + shared + routed + 2 * d)
        total += dense_layers + moe_layers + d
    elif cfg.family == "ssm":
        total += cfg.n_layers * (ssm_params() + d) + d
    elif cfg.family == "hybrid":
        h = cfg.hybrid
        assert h is not None
        n_ssm = cfg.n_layers - h.n_groups
        total += n_ssm * (ssm_params() + d)
        # Zamba2 shared blocks read concat([x, embed]) of width 2*d: the
        # q/k/v and gate/up projections have input dim 2*d.
        shared_attn = (2 * d) * cfg.q_dim + 2 * (2 * d) * cfg.kv_dim \
            + cfg.q_dim * d
        shared_mlp = 2 * (2 * d) * cfg.d_ff + cfg.d_ff * d
        shared_block = shared_attn + shared_mlp + 2 * (2 * d)
        total += h.n_shared_blocks * shared_block + d
    elif cfg.family == "encdec":
        # Whisper uses a GELU MLP (2 matrices), not SwiGLU.
        # learned decoder-position table (models.encdec.MAX_DEC_POS rows)
        total += 32_768 * d
        gelu_mlp = 2 * d * cfg.d_ff
        enc_layer = attn_params(cfg.q_dim, cfg.kv_dim, cfg.qkv_bias) \
            + gelu_mlp + 2 * d
        dec_layer = 2 * attn_params(cfg.q_dim, cfg.kv_dim, cfg.qkv_bias) \
            + gelu_mlp + 3 * d
        total += cfg.n_encoder_layers * enc_layer + cfg.n_layers * dec_layer
        total += 2 * d
    else:
        raise ValueError(cfg.family)
    return total


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # import side-effect registration
    from repro import configs as _pkg  # noqa: F401
    _pkg.load_all()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> List[str]:
    from repro import configs as _pkg
    _pkg.load_all()
    return sorted(_REGISTRY)
