"""multiscope — the paper's video pre-processing pipeline as a first-class
arch in the same config system (``--arch multiscope``).

All knobs here mirror §3 of the paper:
  * proxy module: input resolution (5 pre-trained sizes) + threshold B_proxy
  * detection module: detector architecture + input resolution + confidence
  * tracking module: sampling gap g ∈ G (powers of two)
  * window-size set S of cardinality k=3 (greedy offline selection)
  * tuner: greedy, per-iteration target speedup S=30%
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

from repro.configs.base import ModelConfig, register


@dataclass(frozen=True)
class ProxyConfig:
    """Segmentation proxy model (§3.3): 5-layer strided conv encoder
    (stride-2 each → 1/32 resolution) + 2-layer decoder → per-cell score."""
    cell: int = 32                       # score one 32x32 cell per output px
    base_channels: int = 8
    resolutions: Tuple[Tuple[int, int], ...] = (
        (416, 256), (352, 224), (288, 192), (224, 128), (160, 96))
    thresholds: Tuple[float, ...] = (
        0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9)


@dataclass(frozen=True)
class DetectorConfig:
    """Single-shot anchor-free detector.  Two registered architectures of
    different depths preserve the paper's arch-choice tuning dimension
    (YOLOv3 vs Mask R-CNN in the paper)."""
    archs: Tuple[str, ...] = ("ssd-lite", "ssd-deep")
    resolutions: Tuple[Tuple[int, int], ...] = (
        (960, 544), (832, 480), (704, 416), (608, 352), (512, 288),
        (448, 256), (384, 224), (320, 192))
    stride: int = 32                     # one prediction cell per 32x32 px
    confidences: Tuple[float, ...] = (0.25, 0.4, 0.55, 0.7)
    max_dets: int = 64                   # static shape: detections per frame


@dataclass(frozen=True)
class TrackerConfig:
    """Recurrent reduced-rate tracker (§3.4)."""
    gaps: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)   # maximal gap sequence G
    embed_dim: int = 32                  # detection-level CNN feature size
    rnn_dim: int = 64                    # GRU hidden size (track-level)
    match_hidden: int = 64               # matching MLP hidden
    crop: int = 16                       # detection crop edge (px) fed to CNN
    match_threshold: float = 0.2         # below this a det starts a new track
    max_tracks: int = 64                 # static active-track capacity


@dataclass(frozen=True)
class WindowConfig:
    """Fixed window-size set selection (§3.3)."""
    k: int = 3                           # |S|, incl. the full-frame size
    step: int = 32                       # candidate sizes are multiples of 32
    max_windows: int = 8                 # static per-frame window capacity


@dataclass(frozen=True)
class RefineConfig:
    """Track start/end refinement (§3.4): DBSCAN + grid index + kNN."""
    dbscan_eps: float = 40.0
    dbscan_min_pts: int = 2
    n_points: int = 20                   # N evenly spaced points per track
    knn: int = 10
    grid_cell: int = 64                  # spatial index cell size (px)


@dataclass(frozen=True)
class TunerConfig:
    """Joint greedy parameter tuner (§3.5)."""
    speedup_per_iter: float = 0.30       # S = 30%
    max_iters: int = 12


@dataclass(frozen=True)
class PipelineConfig:
    proxy: ProxyConfig = field(default_factory=ProxyConfig)
    detector: DetectorConfig = field(default_factory=DetectorConfig)
    tracker: TrackerConfig = field(default_factory=TrackerConfig)
    windows: WindowConfig = field(default_factory=WindowConfig)
    refine: RefineConfig = field(default_factory=RefineConfig)
    tuner: TunerConfig = field(default_factory=TunerConfig)
    frame_size: Tuple[int, int] = (960, 544)   # native (w, h)
    fps: int = 16

    def reduced(self) -> "PipelineConfig":
        """CPU-friendly pipeline for tests/benchmarks.  Scale is chosen so
        the paper's cost structure survives: the detector at full
        resolution is ~20x the proxy cost and ~6.5x the detector at the
        lowest resolution, so all three tuner modules have real leverage."""
        return PipelineConfig(
            proxy=ProxyConfig(
                cell=8, base_channels=4,
                resolutions=((64, 40), (48, 32), (32, 24)),
                thresholds=(0.1, 0.3, 0.5, 0.7)),
            detector=DetectorConfig(
                archs=("ssd-lite", "ssd-deep"),
                resolutions=((256, 160), (208, 128), (160, 96),
                             (128, 80)),
                stride=16, max_dets=24,
                confidences=(0.4, 0.55, 0.7)),
            tracker=TrackerConfig(gaps=(1, 2, 4, 8), embed_dim=16,
                                  rnn_dim=32, match_hidden=32, crop=8,
                                  max_tracks=32),
            windows=WindowConfig(k=3, step=16, max_windows=4),
            refine=RefineConfig(dbscan_eps=20.0, grid_cell=32),
            tuner=TunerConfig(max_iters=8),
            frame_size=(256, 160),
            fps=8,
        )


MULTISCOPE_PIPELINE = PipelineConfig()

# Registered as a ModelConfig shell so `--arch multiscope` resolves through
# the same registry; pipeline details live in PipelineConfig above.
MULTISCOPE = register(ModelConfig(
    name="multiscope",
    family="pipeline",
    n_layers=0, d_model=0, n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
    source="this paper (PVLDB 2021)",
))

PIPELINES: Dict[str, PipelineConfig] = {"multiscope": MULTISCOPE_PIPELINE}
