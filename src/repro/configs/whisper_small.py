"""whisper-small — [audio] enc-dec transformer, conv frontend stubbed.

12L (12 enc + 12 dec) d_model=768 12H (GQA kv=12) d_ff=3072 vocab=51865.
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import FrontendConfig, ModelConfig, register

WHISPER_SMALL = register(ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    n_encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    head_dim=64,
    qkv_bias=True,
    tie_embeddings=True,
    frontend=FrontendConfig(kind="audio", n_embeds=1500),
    source="arXiv:2212.04356",
))
