"""mamba2-370m — [ssm] attention-free SSD (state-space duality).

48L d_model=1024 d_ff=0 vocab=50280 ssm_state=128.
[arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig, SSMConfig, register

MAMBA2_370M = register(ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk_size=128),
    source="arXiv:2405.21060",
))
