"""Config registry.  ``load_all()`` imports every per-arch module exactly
once so that ``get_config``/``list_archs`` see the full pool."""
from __future__ import annotations

import importlib

from repro.configs.base import (ModelConfig, MoEConfig, SSMConfig,
                                HybridConfig, FrontendConfig, get_config,
                                list_archs, register)
from repro.configs.shapes import (ShapeConfig, ALL_SHAPES, SHAPES, get_shape,
                                  shape_skip_reason, cells_for)

ARCH_MODULES = (
    "whisper_small",
    "mamba2_370m",
    "deepseek_67b",
    "qwen2_0_5b",
    "deepseek_coder_33b",
    "stablelm_1_6b",
    "zamba2_7b",
    "deepseek_moe_16b",
    "grok_1_314b",
    "pixtral_12b",
    "multiscope",
)

_loaded = False


def load_all() -> None:
    global _loaded
    if _loaded:
        return
    _loaded = True
    for mod in ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


ASSIGNED_ARCHS = (
    "whisper-small", "mamba2-370m", "deepseek-67b", "qwen2-0.5b",
    "deepseek-coder-33b", "stablelm-1.6b", "zamba2-7b", "deepseek-moe-16b",
    "grok-1-314b", "pixtral-12b")
