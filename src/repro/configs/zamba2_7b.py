"""zamba2-7b — [hybrid] Mamba2 backbone + SHARED attention blocks.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000 ssm_state=64.
Layout: 13 groups of (5 Mamba2 layers + 1 shared attn+MLP block) + 3 tail
Mamba2 layers = 81 layers total.  Two distinct shared blocks alternate
across the 13 attention sites (Zamba2's weight-sharing trick).
[arXiv:2411.15242; unverified]
"""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig, register

_HYBRID = HybridConfig(ssm_per_group=5, n_groups=13, tail_ssm=3,
                       n_shared_blocks=2)
assert _HYBRID.total_layers == 81

ZAMBA2_7B = register(ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14_336,
    vocab_size=32_000,
    head_dim=112,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk_size=128),
    hybrid=_HYBRID,
    source="arXiv:2411.15242",
))
