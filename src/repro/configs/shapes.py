"""The assigned input-shape cells.

Every LM-family arch is paired with the same four shapes.  ``train_*``
lowers ``train_step``; ``prefill_*`` lowers the prefill ``serve_step``;
``decode_*`` / ``long_*`` lower the single-token decode ``serve_step`` with a
KV cache (or SSM state) of ``seq_len``.

``long_500k`` requires sub-quadratic attention: it runs only for SSM/hybrid
archs and is recorded as a SKIP (with reason) for pure full-attention archs,
per the assignment.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (
    TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)

SHAPES = {s.name: s for s in ALL_SHAPES}


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def shape_skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """Return a human-readable reason if (arch, shape) must be skipped,
    else None.  Skips are part of the assignment, not failures."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("long_500k needs sub-quadratic attention; "
                f"{cfg.name} is a pure full-attention arch (see DESIGN.md)")
    return None


def cells_for(cfg: ModelConfig) -> List[Tuple[ShapeConfig, Optional[str]]]:
    """All four cells with their skip reason (None = runnable)."""
    return [(s, shape_skip_reason(cfg, s)) for s in ALL_SHAPES]
