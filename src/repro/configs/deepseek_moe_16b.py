"""deepseek-moe-16b — [moe] fine-grained MoE: 2 shared + 64 routed top-6.

28L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400.
First layer uses a dense FFN (d_ff=10944), per the HF config.
[arXiv:2401.06066; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig, register

DEEPSEEK_MOE_16B = register(ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102_400,
    head_dim=128,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, expert_d_ff=1408,
                  dense_first_n=1, dense_d_ff=10_944),
    source="arXiv:2401.06066",
))
