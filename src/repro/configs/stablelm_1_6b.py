"""stablelm-1.6b — [dense] MHA (kv == q heads).

24L d_model=2048 32H (GQA kv=32) d_ff=5632 vocab=100352.
[hf:stabilityai/stablelm-2-1_6b; unverified]
"""
from repro.configs.base import ModelConfig, register

STABLELM_1_6B = register(ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100_352,
    head_dim=64,
    qkv_bias=True,
    source="hf:stabilityai/stablelm-2-1_6b",
))
