"""pixtral-12b — [vlm] pixtral-ViT frontend (STUB) + mistral-nemo backbone.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128.
The vision frontend supplies precomputed patch embeddings via input_specs();
they are merged into the token stream at image-placeholder positions.
[hf:mistralai/Pixtral-12B-2409; unverified]
"""
from repro.configs.base import FrontendConfig, ModelConfig, register

PIXTRAL_12B = register(ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab_size=131_072,
    head_dim=128,
    rope_theta=1_000_000.0,
    frontend=FrontendConfig(kind="vision", n_embeds=1024),
    source="hf:mistralai/Pixtral-12B-2409",
))
