"""grok-1-314b — [moe] 8 experts top-2.

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072.
[hf:xai-org/grok-1; unverified]
"""
from repro.configs.base import ModelConfig, MoEConfig, register

GROK_1_314B = register(ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32_768,
    vocab_size=131_072,
    head_dim=128,
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=0, expert_d_ff=32_768),
    source="hf:xai-org/grok-1",
))
