"""Unified Model API over every architecture family.

``build_model(cfg)`` returns a ``Model`` exposing:

  init_params(seed)      -> param pytree (fp32 master weights)
  param_axes()           -> logical-axes pytree (leaf = tuple of axis names)
  param_shapes()         -> ShapeDtypeStruct pytree
  loss(params, batch)    -> (scalar fp32, metrics dict)
  prefill(params, batch) -> (logits_last (B, V), cache)
  decode_step(params, token, pos, cache) -> (logits (B, V), cache)
  make_cache(batch, max_len, mode)       -> (cache, axes)
  input_specs(shape)     -> dict of ShapeDtypeStructs for the shape cell
  input_axes(shape)      -> matching logical-axes dict

Batches are dicts; every family consumes ``tokens`` and optionally
frontend embeddings (``audio_embeds`` / ``patch_embeds``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig
from repro.models import encdec as encdec_mod
from repro.models import transformer as tf_mod
from repro.models.common import ParamBuilder, build

PyTree = Any


def _is_axes_leaf(x):
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- parameters -----------------------------------------------------------
    def _def(self, pb: ParamBuilder) -> None:
        if self.cfg.family == "encdec":
            encdec_mod.def_encdec_params(pb, self.cfg)
        else:
            tf_mod.def_lm_params(pb, self.cfg)

    def init_params(self, seed: int = 0) -> PyTree:
        return build(self._def, "init", seed=seed,
                     dtype=self.cfg.param_dtype)

    def param_axes(self) -> PyTree:
        return build(self._def, "spec")

    def param_shapes(self) -> PyTree:
        return build(self._def, "shape", dtype=self.cfg.param_dtype)

    def param_count(self) -> int:
        shapes = self.param_shapes()
        return sum(int(jnp.prod(jnp.array(l.shape)))
                   for l in jax.tree.leaves(shapes))

    # -- forward / loss --------------------------------------------------------
    def forward(self, params: PyTree, batch: Dict[str, Any],
                return_cache: bool = False):
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec_mod.encdec_forward(
                params, cfg, batch["audio_embeds"], batch["tokens"],
                return_cache=return_cache)
        return tf_mod.lm_forward(
            params, cfg, batch["tokens"],
            patch_embeds=batch.get("patch_embeds"),
            return_cache=return_cache)

    def loss(self, params: PyTree, batch: Dict[str, Any]
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        logits, aux, _ = self.forward(params, batch)
        tokens = batch["tokens"]
        targets = tokens[:, 1:]
        lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        nll = -jnp.take_along_axis(lp, targets[..., None],
                                   axis=-1)[..., 0]
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones_like(targets, jnp.float32)
        else:
            mask = mask[:, 1:].astype(jnp.float32)
        denom = jnp.maximum(mask.sum(), 1.0)
        ce = (nll * mask).sum() / denom
        total = ce + self.cfg.moe.aux_loss_coef * aux \
            if self.cfg.moe.enabled else ce
        return total, {"ce": ce, "aux": aux,
                       "tokens": mask.sum().astype(jnp.float32)}

    # -- serving ----------------------------------------------------------------
    def prefill(self, params: PyTree, batch: Dict[str, Any],
                max_len: Optional[int] = None):
        logits, _, cache = self.forward(params, batch, return_cache=True)
        if max_len is not None:
            if self.cfg.family == "encdec":
                k, v = cache["self"]
                extra = max_len - k.shape[2]
                if extra > 0:
                    padw = ((0, 0), (0, 0), (0, extra), (0, 0), (0, 0))
                    cache = dict(cache)
                    cache["self"] = (jnp.pad(k, padw), jnp.pad(v, padw))
            else:
                cache = tf_mod.pad_cache(self.cfg, cache, max_len)
        return logits[:, -1], cache

    def decode_step(self, params: PyTree, token, pos, cache):
        if self.cfg.family == "encdec":
            logits, cache = encdec_mod.encdec_decode(
                params, self.cfg, token, pos, cache)
        else:
            logits, cache = tf_mod.lm_decode(
                params, self.cfg, token, pos, cache)
        return logits[:, 0], cache

    def make_cache(self, batch: int, max_len: int, mode: str = "shape"):
        if self.cfg.family == "encdec":
            return encdec_mod.make_encdec_cache(self.cfg, batch, max_len,
                                                mode)
        return tf_mod.make_cache(self.cfg, batch, max_len, mode)

    # -- shape-cell inputs -------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        tok = jnp.int32
        d = cfg.d_model
        emb_dt = jnp.dtype(cfg.dtype)
        if shape.kind in ("train", "prefill"):
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), tok)}
            if cfg.family == "encdec":
                specs["audio_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.frontend.n_embeds, d), emb_dt)
            if cfg.family == "vlm":
                specs["patch_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.frontend.n_embeds, d), emb_dt)
            if shape.kind == "train":
                specs["loss_mask"] = jax.ShapeDtypeStruct((B, S), jnp.int8)
            return specs
        # decode: one new token against a cache of seq_len
        cache, _ = self.make_cache(B, S, mode="shape")
        return {"token": jax.ShapeDtypeStruct((B, 1), tok),
                "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
                "cache": cache}

    def input_axes(self, shape: ShapeConfig) -> Dict[str, Any]:
        cfg = self.cfg
        if shape.kind in ("train", "prefill"):
            axes: Dict[str, Any] = {"tokens": ("batch", "seq")}
            if cfg.family == "encdec":
                axes["audio_embeds"] = ("batch", None, None)
            if cfg.family == "vlm":
                axes["patch_embeds"] = ("batch", None, None)
            if shape.kind == "train":
                axes["loss_mask"] = ("batch", "seq")
            return axes
        _, cache_axes = self.make_cache(shape.global_batch, shape.seq_len,
                                        mode="shape")
        return {"token": ("batch", None), "pos": ("batch",),
                "cache": cache_axes}


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "pipeline":
        raise ValueError(
            "multiscope pipeline is built via repro.core.pipeline, "
            "not build_model")
    return Model(cfg)
