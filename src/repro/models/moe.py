"""Mixture-of-experts FFN with token-choice top-k routing and capacity-
bounded, sort-based dispatch (static shapes — XLA/SPMD friendly).

Dispatch is grouped BY SEQUENCE ROW (GShard-style groups): each batch row
independently routes its S tokens into an (E, C) slot buffer with
C = ceil(cf * S * top_k / E).  All routing ops (top_k, argsort, position
arithmetic, scatter) act along per-row local axes, so under pjit the batch
dim shards cleanly on (pod, data) and no global sort is ever built.  The
expert einsum contracts with expert weights sharded on the model axis
(EP when n_experts divides it, TP-within-expert otherwise — the
LogicalRules divisibility fallback decides per arch).

Overflowed tokens (position >= C) are dropped (contribute zero), matching
standard capacity-factor semantics; the aux load-balance loss pushes the
router away from overflow.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.common import ParamBuilder, shard
from repro.models.layers import def_mlp_swiglu, mlp_swiglu


def moe_capacity(m: MoEConfig, seq: int) -> int:
    c = int(-(-m.capacity_factor * seq * m.top_k // m.n_experts))
    return max(1, min(c, seq))


def def_moe_block(pb: ParamBuilder, name: str, cfg: ModelConfig) -> None:
    m = cfg.moe
    d = cfg.d_model
    with pb.scope(name):
        pb.param("router", (d, m.n_experts), ("embed", None),
                 dtype=jnp.float32)
        with pb.scope("experts"):
            pb.param("w_gate", (m.n_experts, d, m.expert_d_ff),
                     ("expert", "embed", "expert_mlp"))
            pb.param("w_up", (m.n_experts, d, m.expert_d_ff),
                     ("expert", "embed", "expert_mlp"))
            pb.param("w_down", (m.n_experts, m.expert_d_ff, d),
                     ("expert", "expert_mlp", "embed"))
        for i in range(m.n_shared):
            def_mlp_swiglu(pb, f"shared{i}", d, m.expert_d_ff)


def moe_block(p, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar fp32)."""
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.n_experts, m.top_k
    C = moe_capacity(m, S)

    # --- routing (fp32) ---------------------------------------------------
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                  # (B, S, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (B, S, k)

    # aux load-balance loss: E * sum_e f_e * P_e  (per row, then mean)
    pick_frac = jnp.zeros((B, E), jnp.float32).at[
        jnp.arange(B)[:, None, None], gate_idx].add(1.0) / (S * k)
    mean_prob = probs.mean(axis=1)                           # (B, E)
    aux = E * jnp.sum(pick_frac * mean_prob, axis=-1).mean()

    # --- per-row sort-based dispatch ---------------------------------------
    e_flat = gate_idx.reshape(B, S * k)                      # expert ids
    t_flat = jnp.repeat(jnp.arange(S), k)[None, :]           # token ids
    w_flat = gate_vals.reshape(B, S * k)
    order = jnp.argsort(e_flat, axis=-1, stable=True)
    e_sort = jnp.take_along_axis(e_flat, order, axis=-1)
    t_sort = jnp.take_along_axis(jnp.broadcast_to(t_flat, e_flat.shape),
                                 order, axis=-1)
    w_sort = jnp.take_along_axis(w_flat, order, axis=-1)
    # position within expert segment: i - start_of_segment(e_sort[i])
    counts = jnp.zeros((B, E), jnp.int32).at[
        jnp.arange(B)[:, None], e_sort].add(1)
    starts = jnp.cumsum(counts, axis=-1) - counts            # (B, E)
    pos = jnp.arange(S * k)[None, :] - jnp.take_along_axis(
        starts, e_sort, axis=-1)
    keep = pos < C
    slot = jnp.where(keep, e_sort * C + pos, E * C)          # E*C = dropped

    # scatter tokens into the (E*C, d) buffer (per row; 'drop' mode).
    # The zeros TARGET is sharding-constrained BEFORE the scatter: without
    # this, SPMD propagates a replicated output for the scatter and
    # all-gathers the updates across the mesh (measured at 3 TB/step for
    # grok prefill_32k — see EXPERIMENTS.md §Perf).
    xtok = jnp.take_along_axis(
        x, t_sort[..., None].astype(jnp.int32), axis=1)      # (B, S*k, d)
    buf0 = shard(jnp.zeros((B, E * C, d), x.dtype),
                 "batch", None, None)
    # vmapped 1-D scatter: the row dim stays an HLO scatter BATCH dim, so
    # SPMD partitions it along (pod, data) instead of replicating the
    # buffer and all-gathering updates (explicit arange(B) indices defeat
    # the partitioner — measured 3 TB/step on grok prefill_32k)
    buf = jax.vmap(lambda b0, s, xt: b0.at[s].set(xt, mode="drop"))(
        buf0, slot, xtok)
    buf = buf.reshape(B, E, C, d)
    buf = shard(buf, "batch", None, None, None)

    # --- expert compute (E on the model axis via weight sharding) ----------
    wg = p["experts"]["w_gate"].astype(x.dtype)
    wu = p["experts"]["w_up"].astype(x.dtype)
    wd = p["experts"]["w_down"].astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, wg)) \
        * jnp.einsum("becd,edf->becf", buf, wu)
    h = shard(h, "batch", "expert", None, "expert_mlp")
    out_buf = jnp.einsum("becf,efd->becd", h, wd)
    out_buf = out_buf.reshape(B, E * C, d)

    # --- combine: gather slots back and weight-sum over k -----------------
    gathered = jnp.take_along_axis(
        out_buf, jnp.minimum(slot, E * C - 1)[..., None], axis=1)
    gathered = jnp.where(keep[..., None], gathered, 0)
    contrib = gathered * w_sort[..., None].astype(x.dtype)
    y0 = shard(jnp.zeros((B, S, d), x.dtype), "batch", None, None)
    y = jax.vmap(lambda y_, t, c: y_.at[t].add(c))(y0, t_sort, contrib)

    # --- shared experts (always-on) ----------------------------------------
    for i in range(m.n_shared):
        y = y + mlp_swiglu(p[f"shared{i}"], x)
    return y, aux.astype(jnp.float32)
