"""GQA attention block: full-sequence (train/prefill via the flash kernel)
and single-token decode (via the decode kernel) paths, plus KV-cache plumb.

Cache layout: K, V as (B, S_max, Hkv, Dh).  Sharding preference is decided
per-arch at trace time: kv_heads on the model axis when divisible, else
sequence-sharded (SP) — see ``kv_cache_axes``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.models.common import ParamBuilder, current_rules, shard
from repro.models.layers import apply_rope, def_linear, linear, rope_tables


def def_attention(pb: ParamBuilder, name: str, cfg: ModelConfig,
                  d_in: Optional[int] = None) -> None:
    d_in = d_in or cfg.d_model
    with pb.scope(name):
        def_linear(pb, "wq", d_in, cfg.q_dim, ("embed", "qkv"),
                   bias=cfg.qkv_bias, bias_axis="qkv")
        def_linear(pb, "wk", d_in, cfg.kv_dim, ("embed", "kv"),
                   bias=cfg.qkv_bias, bias_axis="kv")
        def_linear(pb, "wv", d_in, cfg.kv_dim, ("embed", "kv"),
                   bias=cfg.qkv_bias, bias_axis="kv")
        def_linear(pb, "wo", cfg.q_dim, cfg.d_model, ("qkv", "embed"))


def kv_cache_axes(cfg: ModelConfig) -> Tuple[Optional[str], ...]:
    """Logical axes for a (B, S, Hkv, Dh) cache: prefer TP over kv heads,
    fall back to sequence parallelism for small-kv GQA archs."""
    rules = current_rules()
    model_size = 1
    if rules is not None:
        model_size = rules.axis_sizes.get("model", 1)
    if cfg.n_kv_heads and cfg.n_kv_heads % model_size == 0:
        return ("batch", None, "kv_heads", None)
    return ("batch", "kv_seq", None, None)


def _project_qkv(p, x, cfg: ModelConfig):
    B, S = x.shape[:2]
    q = linear(p["wq"], x).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = linear(p["wk"], x).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = linear(p["wv"], x).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def attention_full(p, x, cfg: ModelConfig, *, causal: bool = True,
                   positions=None, use_rope: bool = True,
                   kv_override=None):
    """Full-sequence attention.  x: (B, S, d_in) -> (B, S, d_model).

    kv_override: optional (k, v) for cross-attention (already projected).
    """
    B, S = x.shape[:2]
    if kv_override is None:
        q, k, v = _project_qkv(p, x, cfg)
        if use_rope:
            if positions is None:
                positions = jnp.arange(S)
            cos, sin = rope_tables(positions, cfg.head_dim, cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
    else:
        q = linear(p["wq"], x).reshape(B, S, cfg.n_heads, cfg.head_dim)
        k, v = kv_override
    rules = current_rules()
    msize = rules.axis_sizes.get("model", 1) if rules else 1
    if cfg.attention_qseq_sp and cfg.n_heads % msize != 0 \
            and S % max(msize, 1) == 0:
        # heads can't shard on the model axis: shard the q rows instead
        # (context parallelism) — k/v stay whole per device, attention
        # compute drops by the model-axis size instead of replicating
        q = shard(q, "batch", "kv_seq", None, None)
        k = shard(k, "batch", None, None, None)
        v = shard(v, "batch", None, None, None)
    else:
        q = shard(q, "batch", "seq", "heads", None)
        k = shard(k, "batch", "seq", "kv_heads", None)
        v = shard(v, "batch", "seq", "kv_heads", None)
    out = flash_attention(q, k, v, causal=causal)
    out = out.reshape(B, S, cfg.q_dim)
    return linear(p["wo"], out)


def cross_kv(p, enc_out, cfg: ModelConfig):
    """Project encoder output once into cross-attention K/V."""
    B, S = enc_out.shape[:2]
    k = linear(p["wk"], enc_out).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = linear(p["wv"], enc_out).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    return k, v


def attention_decode(p, x, cache_k, cache_v, pos, cfg: ModelConfig, *,
                     use_rope: bool = True, update_cache: bool = True):
    """Single-token decode.  x: (B, 1, d_in); cache_k/v: (B, S, Hkv, Dh);
    pos: (B,) int32 — number of valid cached tokens (the new token is
    written at index pos).  Returns (out (B,1,d_model), cache_k, cache_v).
    """
    B = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg)            # (B,1,H,D)
    if use_rope:
        cos, sin = rope_tables(pos[:, None], cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    if update_cache:
        # scatter the new K/V row at each batch row's position (an HLO
        # scatter: O(B*Hkv*Dh) bytes touched, not a full-cache rewrite)
        rows = jnp.arange(B)
        cache_k = cache_k.at[rows, pos].set(k[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[rows, pos].set(v[:, 0].astype(cache_v.dtype))
        kv_len = pos + 1
    else:
        kv_len = pos
    axes = kv_cache_axes(cfg)
    cache_k = shard(cache_k, *axes)
    cache_v = shard(cache_v, *axes)
    out = decode_attention(q[:, 0], cache_k, cache_v, kv_len)
    out = out.reshape(B, 1, cfg.q_dim)
    return linear(p["wo"], out), cache_k, cache_v
