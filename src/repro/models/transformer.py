"""Decoder-only LM assembly for the dense / moe / ssm / hybrid / vlm
families: parameter definition, full-sequence forward (train + prefill
with cache capture), and single-token decode over caches.

Layer parameters are STACKED (leading ``layers`` dim via ParamBuilder.stack)
and applied with ``lax.scan`` so the lowered HLO is one layer body repeated
— small HLO, fast SPMD partitioning, and XLA overlaps layer i+1 weight
all-gathers with layer i compute.  Remat wraps the scan body according to
``cfg.remat``.

Hybrid (Zamba2) layout: scan over groups; each group runs a nested scan of
``ssm_per_group`` Mamba2 layers then one SHARED attention+MLP block whose
weights (2 distinct sets, alternating) read ``concat([h, h_embed])`` of
width 2*d_model — the Zamba2 weight-sharing trick.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.attention import (attention_decode, attention_full,
                                    def_attention, kv_cache_axes)
from repro.models.common import ParamBuilder, shard
from repro.models.layers import (def_embedding, def_linear, def_mlp_swiglu,
                                 def_rmsnorm, embed, linear, mlp_swiglu,
                                 rmsnorm, unembed)
from repro.models.moe import def_moe_block, moe_block

PyTree = Any


# ---------------------------------------------------------------------------
# Parameter definition
# ---------------------------------------------------------------------------

def _def_attn_layer(pb: ParamBuilder, cfg: ModelConfig,
                    mlp_kind: str, d_ff: int) -> None:
    def_rmsnorm(pb, "ln_attn", cfg.d_model)
    def_attention(pb, "attn", cfg)
    def_rmsnorm(pb, "ln_mlp", cfg.d_model)
    if mlp_kind == "swiglu":
        def_mlp_swiglu(pb, "mlp", cfg.d_model, d_ff)
    elif mlp_kind == "moe":
        def_moe_block(pb, "moe", cfg)
    else:
        raise ValueError(mlp_kind)


def def_lm_params(pb: ParamBuilder, cfg: ModelConfig) -> None:
    def_embedding(pb, "embed", cfg.vocab_size, cfg.d_model)
    fam = cfg.family
    if fam in ("dense", "vlm"):
        with pb.scope("layers"), pb.stack(cfg.n_layers):
            _def_attn_layer(pb, cfg, "swiglu", cfg.d_ff)
    elif fam == "moe":
        m = cfg.moe
        if m.dense_first_n:
            with pb.scope("dense_layers"), pb.stack(m.dense_first_n):
                _def_attn_layer(pb, cfg, "swiglu", m.dense_d_ff)
        with pb.scope("layers"), pb.stack(cfg.n_layers - m.dense_first_n):
            _def_attn_layer(pb, cfg, "moe", 0)
    elif fam == "ssm":
        with pb.scope("layers"), pb.stack(cfg.n_layers):
            def_rmsnorm(pb, "ln", cfg.d_model)
            ssm_mod.def_ssm_block(pb, "ssm", cfg)
    elif fam == "hybrid":
        h = cfg.hybrid
        assert h is not None
        with pb.scope("groups"), pb.stack(h.n_groups), \
                pb.scope("ssm_layers"), pb.stack(h.ssm_per_group):
            def_rmsnorm(pb, "ln", cfg.d_model)
            ssm_mod.def_ssm_block(pb, "ssm", cfg)
        with pb.scope("shared"), pb.stack(h.n_shared_blocks):
            def_rmsnorm(pb, "ln_attn", 2 * cfg.d_model)
            def_attention(pb, "attn", cfg, d_in=2 * cfg.d_model)
            def_rmsnorm(pb, "ln_mlp", 2 * cfg.d_model)
            def_mlp_swiglu(pb, "mlp", cfg.d_model, cfg.d_ff,
                           d_in=2 * cfg.d_model)
        with pb.scope("tail"), pb.stack(h.tail_ssm):
            def_rmsnorm(pb, "ln", cfg.d_model)
            ssm_mod.def_ssm_block(pb, "ssm", cfg)
    else:
        raise ValueError(fam)
    def_rmsnorm(pb, "ln_final", cfg.d_model)
    if not cfg.tie_embeddings:
        def_linear(pb, "lm_head", cfg.d_model, cfg.vocab_size,
                   ("embed", "vocab"))


# ---------------------------------------------------------------------------
# Remat policy
# ---------------------------------------------------------------------------

def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)        # "full": save nothing


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _attn_layer_fwd(lp, h, cfg: ModelConfig, mlp_kind: str,
                    capture_cache: bool):
    """One attention layer.  Returns (h, aux, cache_slice_or_None)."""
    hin = rmsnorm(lp["ln_attn"], h, cfg.norm_eps)
    B, S = hin.shape[:2]
    cache = None
    if capture_cache:
        from repro.models.layers import apply_rope, rope_tables
        q = linear(lp["attn"]["wq"], hin).reshape(
            B, S, cfg.n_heads, cfg.head_dim)
        k = linear(lp["attn"]["wk"], hin).reshape(
            B, S, cfg.n_kv_heads, cfg.head_dim)
        v = linear(lp["attn"]["wv"], hin).reshape(
            B, S, cfg.n_kv_heads, cfg.head_dim)
        cos, sin = rope_tables(jnp.arange(S), cfg.head_dim, cfg.rope_theta)
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
        from repro.kernels.flash_attention import flash_attention
        q = shard(q, "batch", "seq", "heads", None)
        k = shard(k, "batch", "seq", "kv_heads", None)
        v = shard(v, "batch", "seq", "kv_heads", None)
        attn_out = flash_attention(q, k, v, causal=True)
        attn_out = linear(lp["attn"]["wo"], attn_out.reshape(B, S, cfg.q_dim))
        cache = (k, v)
    else:
        attn_out = attention_full(lp["attn"], hin, cfg)
    h = h + attn_out
    hin = rmsnorm(lp["ln_mlp"], h, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if mlp_kind == "swiglu":
        h = h + mlp_swiglu(lp["mlp"], hin)
    else:
        out, aux = moe_block(lp["moe"], hin, cfg)
        h = h + out
    return h, aux, cache


def _ssm_layer_fwd(lp, h, cfg: ModelConfig, capture_cache: bool):
    hin = rmsnorm(lp["ln"], h, cfg.norm_eps)
    if capture_cache:
        out, state = ssm_mod.ssm_block_full(lp["ssm"], hin, cfg,
                                            return_state=True)
    else:
        out = ssm_mod.ssm_block_full(lp["ssm"], hin, cfg)
        state = None
    return h + out, state


def _shared_block_fwd(sp, h, h_embed, cfg: ModelConfig,
                      capture_cache: bool, pos_offset: int = 0):
    """Zamba2 shared attn+MLP block on concat([h, h_embed])."""
    x2 = jnp.concatenate([h, h_embed], axis=-1)
    hin = rmsnorm(sp["ln_attn"], x2, cfg.norm_eps)
    B, S = hin.shape[:2]
    cache = None
    if capture_cache:
        from repro.models.layers import apply_rope, rope_tables
        q = linear(sp["attn"]["wq"], hin).reshape(
            B, S, cfg.n_heads, cfg.head_dim)
        k = linear(sp["attn"]["wk"], hin).reshape(
            B, S, cfg.n_kv_heads, cfg.head_dim)
        v = linear(sp["attn"]["wv"], hin).reshape(
            B, S, cfg.n_kv_heads, cfg.head_dim)
        cos, sin = rope_tables(jnp.arange(S), cfg.head_dim, cfg.rope_theta)
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
        from repro.kernels.flash_attention import flash_attention
        attn_out = flash_attention(q, k, v, causal=True)
        attn_out = linear(sp["attn"]["wo"],
                          attn_out.reshape(B, S, cfg.q_dim))
        cache = (k, v)
    else:
        attn_out = attention_full(sp["attn"], hin, cfg)
    h = h + attn_out
    hin = rmsnorm(sp["ln_mlp"], jnp.concatenate([h, h_embed], axis=-1),
                  cfg.norm_eps)
    h = h + mlp_swiglu(sp["mlp"], hin)
    return h, cache


def lm_forward(params: PyTree, cfg: ModelConfig, tokens, *,
               patch_embeds=None, return_cache: bool = False):
    """tokens: (B, S) int32 -> (logits fp32, aux_loss, cache|None)."""
    dtype = jnp.dtype(cfg.dtype)
    h = embed(params["embed"], tokens, dtype)
    if patch_embeds is not None:
        P = patch_embeds.shape[1]
        h = jnp.concatenate([patch_embeds.astype(dtype), h[:, P:]], axis=1)
    h = shard(h, "batch", "seq", None)
    fam = cfg.family
    aux_total = jnp.zeros((), jnp.float32)
    cache: Dict[str, Any] = {}

    if fam in ("dense", "vlm", "moe"):
        def make_body(mlp_kind):
            def body(carry, lp):
                h, aux = carry
                h, a, c = _attn_layer_fwd(lp, h, cfg, mlp_kind,
                                          return_cache)
                return (h, aux + a), c
            return _remat(body, cfg)

        if fam == "moe" and cfg.moe.dense_first_n:
            (h, aux_total), c = jax.lax.scan(
                make_body("swiglu"), (h, aux_total),
                params["dense_layers"])
            if return_cache:
                cache["dense_layers"] = c
        mlp_kind = "moe" if fam == "moe" else "swiglu"
        (h, aux_total), c = jax.lax.scan(
            make_body(mlp_kind), (h, aux_total), params["layers"])
        if return_cache:
            cache["layers"] = c

    elif fam == "ssm":
        def body(h, lp):
            h, st = _ssm_layer_fwd(lp, h, cfg, return_cache)
            return h, st
        h, states = jax.lax.scan(_remat(body, cfg), h, params["layers"])
        if return_cache:
            cache["layers"] = states

    elif fam == "hybrid":
        hcfg = cfg.hybrid
        h_embed = h

        def group_body(h, xs):
            gi, gp = xs

            def ssm_body(hh, lp):
                hh, st = _ssm_layer_fwd(lp, hh, cfg, return_cache)
                return hh, st
            h, states = jax.lax.scan(_remat(ssm_body, cfg), h,
                                     gp["ssm_layers"])
            sp = jax.tree.map(
                lambda a: a[gi % hcfg.n_shared_blocks], params["shared"])
            h, kv = _shared_block_fwd(sp, h, h_embed, cfg, return_cache)
            return h, (states, kv)

        h, (g_states, g_kv) = jax.lax.scan(
            group_body, h,
            (jnp.arange(hcfg.n_groups), params["groups"]))

        def tail_body(hh, lp):
            hh, st = _ssm_layer_fwd(lp, hh, cfg, return_cache)
            return hh, st
        h, t_states = jax.lax.scan(_remat(tail_body, cfg), h,
                                   params["tail"])
        if return_cache:
            cache["groups"] = g_states
            cache["shared_kv"] = g_kv
            cache["tail"] = t_states
    else:
        raise ValueError(fam)

    h = rmsnorm(params["ln_final"], h, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], h)
    else:
        logits = jnp.einsum("...d,dv->...v", h.astype(jnp.float32),
                            params["lm_head"]["w"].astype(jnp.float32))
    logits = shard(logits, "batch", "seq", "vocab")
    return logits, aux_total, (cache if return_cache else None)


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def make_cache(cfg: ModelConfig, batch: int, max_len: int,
               mode: str = "shape") -> Tuple[PyTree, PyTree]:
    """Returns (cache, logical_axes) — zeros (mode='init') or
    ShapeDtypeStructs (mode='shape')."""
    dtype = jnp.dtype(cfg.dtype)
    kv_axes = kv_cache_axes(cfg)

    def mk(shape, dt):
        if mode == "init":
            return jnp.zeros(shape, dt)
        return jax.ShapeDtypeStruct(shape, dt)

    def kv_pair(n_layers):
        shape = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        ax = ("layers",) + kv_axes
        return (mk(shape, dtype), mk(shape, dtype)), (ax, ax)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        c, a = kv_pair(cfg.n_layers)
        return {"layers": c}, {"layers": a}
    if fam == "moe":
        cache, axes = {}, {}
        if cfg.moe.dense_first_n:
            c, a = kv_pair(cfg.moe.dense_first_n)
            cache["dense_layers"], axes["dense_layers"] = c, a
        c, a = kv_pair(cfg.n_layers - cfg.moe.dense_first_n)
        cache["layers"], axes["layers"] = c, a
        return cache, axes
    if fam == "ssm":
        st = ssm_mod.init_ssm_state(cfg, batch, dtype)
        sax = ssm_mod.ssm_state_axes(cfg)
        L = cfg.n_layers
        cache = jax.tree.map(
            lambda x: mk((L,) + x.shape, x.dtype), st)
        axes = jax.tree.map(lambda a: ("layers",) + a, sax,
                            is_leaf=lambda x: isinstance(x, tuple))
        return {"layers": cache}, {"layers": axes}
    if fam == "hybrid":
        h = cfg.hybrid
        st = ssm_mod.init_ssm_state(cfg, batch, dtype)
        sax = ssm_mod.ssm_state_axes(cfg)
        lead_g = (h.n_groups, h.ssm_per_group)
        cache = {
            "groups": jax.tree.map(
                lambda x: mk(lead_g + x.shape, x.dtype), st),
            "tail": jax.tree.map(
                lambda x: mk((h.tail_ssm,) + x.shape, x.dtype), st),
        }
        axes = {
            "groups": jax.tree.map(
                lambda a: ("layers", "layers2") + a, sax,
                is_leaf=lambda x: isinstance(x, tuple)),
            "tail": jax.tree.map(
                lambda a: ("layers",) + a, sax,
                is_leaf=lambda x: isinstance(x, tuple)),
        }
        kvs = (h.n_groups, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        kax = ("layers",) + kv_axes
        cache["shared_kv"] = (mk(kvs, dtype), mk(kvs, dtype))
        axes["shared_kv"] = (kax, kax)
        return cache, axes
    raise ValueError(fam)


def pad_cache(cfg: ModelConfig, cache: PyTree, max_len: int) -> PyTree:
    """Grow the seq axis of every KV cache leaf (captured at prefill length)
    to ``max_len`` so decode can append.  SSM states are length-free."""
    def pad_kv(pair):
        k, v = pair
        extra = max_len - k.shape[2]
        if extra <= 0:
            return (k, v)
        padw = ((0, 0), (0, 0), (0, extra), (0, 0), (0, 0))
        return (jnp.pad(k, padw), jnp.pad(v, padw))

    fam = cfg.family
    out = dict(cache)
    if fam in ("dense", "vlm", "moe"):
        for key in ("dense_layers", "layers"):
            if key in out and out[key] is not None:
                out[key] = pad_kv(out[key])
    elif fam == "hybrid":
        out["shared_kv"] = pad_kv(out["shared_kv"])
    return out


# ---------------------------------------------------------------------------
# Single-token decode
# ---------------------------------------------------------------------------

def lm_decode(params: PyTree, cfg: ModelConfig, token, pos, cache):
    """token: (B, 1) int32; pos: (B,) int32 — valid cache length per row.

    Returns (logits (B, 1, V) fp32, new_cache).
    """
    dtype = jnp.dtype(cfg.dtype)
    h = embed(params["embed"], token, dtype)
    h = shard(h, "batch", None, None)
    fam = cfg.family
    new_cache: Dict[str, Any] = {}

    if fam in ("dense", "vlm", "moe"):
        def make_body(mlp_kind):
            def body(h, xs):
                lp, (ck, cv) = xs
                hin = rmsnorm(lp["ln_attn"], h, cfg.norm_eps)
                attn_out, ck, cv = attention_decode(
                    lp["attn"], hin, ck, cv, pos, cfg)
                h = h + attn_out
                hin = rmsnorm(lp["ln_mlp"], h, cfg.norm_eps)
                if mlp_kind == "swiglu":
                    h = h + mlp_swiglu(lp["mlp"], hin)
                else:
                    out, _ = moe_block(lp["moe"], hin, cfg)
                    h = h + out
                return h, (ck, cv)
            return body

        if fam == "moe" and cfg.moe.dense_first_n:
            h, c = jax.lax.scan(make_body("swiglu"), h,
                                (params["dense_layers"],
                                 cache["dense_layers"]))
            new_cache["dense_layers"] = c
        mlp_kind = "moe" if fam == "moe" else "swiglu"
        h, c = jax.lax.scan(make_body(mlp_kind), h,
                            (params["layers"], cache["layers"]))
        new_cache["layers"] = c

    elif fam == "ssm":
        def body(h, xs):
            lp, st = xs
            hin = rmsnorm(lp["ln"], h, cfg.norm_eps)
            out, st = ssm_mod.ssm_block_decode(lp["ssm"], hin, st, cfg)
            return h + out, st
        h, states = jax.lax.scan(body, h,
                                 (params["layers"], cache["layers"]))
        new_cache["layers"] = states

    elif fam == "hybrid":
        hcfg = cfg.hybrid
        h_embed = h

        def group_body(h, xs):
            gi, gp, gst, (ck, cv) = xs

            def ssm_body(hh, xs2):
                lp, st = xs2
                hin = rmsnorm(lp["ln"], hh, cfg.norm_eps)
                out, st = ssm_mod.ssm_block_decode(lp["ssm"], hin, st, cfg)
                return hh + out, st
            h, states = jax.lax.scan(ssm_body, h,
                                     (gp["ssm_layers"], gst))
            sp = jax.tree.map(
                lambda a: a[gi % hcfg.n_shared_blocks], params["shared"])
            x2 = jnp.concatenate([h, h_embed], axis=-1)
            hin = rmsnorm(sp["ln_attn"], x2, cfg.norm_eps)
            attn_out, ck, cv = attention_decode(
                sp["attn"], hin, ck, cv, pos, cfg)
            h = h + attn_out
            hin = rmsnorm(sp["ln_mlp"],
                          jnp.concatenate([h, h_embed], axis=-1),
                          cfg.norm_eps)
            h = h + mlp_swiglu(sp["mlp"], hin)
            return h, (states, (ck, cv))

        ck_all, cv_all = cache["shared_kv"]
        h, (g_states, g_kv) = jax.lax.scan(
            group_body, h,
            (jnp.arange(hcfg.n_groups), params["groups"],
             cache["groups"], (ck_all, cv_all)))

        def tail_body(hh, xs):
            lp, st = xs
            hin = rmsnorm(lp["ln"], hh, cfg.norm_eps)
            out, st = ssm_mod.ssm_block_decode(lp["ssm"], hin, st, cfg)
            return hh + out, st
        h, t_states = jax.lax.scan(tail_body, h,
                                   (params["tail"], cache["tail"]))
        new_cache["groups"] = g_states
        new_cache["shared_kv"] = g_kv
        new_cache["tail"] = t_states
    else:
        raise ValueError(fam)

    h = rmsnorm(params["ln_final"], h, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], h)
    else:
        logits = jnp.einsum("...d,dv->...v", h.astype(jnp.float32),
                            params["lm_head"]["w"].astype(jnp.float32))
    return logits, new_cache
