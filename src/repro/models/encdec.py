"""Encoder-decoder transformer (Whisper-style backbone).

The audio conv frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, n_frames, d_model).  The encoder
adds sinusoidal positions and runs bidirectional attention; the decoder
uses learned positions, causal self-attention, and cross-attention to the
encoder output.  MLPs are GELU (Whisper), with pre-LayerNorm.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (attention_decode, attention_full,
                                    cross_kv, def_attention, kv_cache_axes)
from repro.models.common import ParamBuilder, shard
from repro.models.layers import (def_embedding, def_layernorm, def_mlp_gelu,
                                 embed, layernorm, linear, mlp_gelu,
                                 sinusoidal_positions, unembed)

PyTree = Any

MAX_DEC_POS = 32_768   # learned decoder position table rows (long_500k is
                       # skipped for enc-dec archs, so 32k covers all cells)


def def_encdec_params(pb: ParamBuilder, cfg: ModelConfig) -> None:
    def_embedding(pb, "embed", cfg.vocab_size, cfg.d_model)
    pb.param("dec_pos", (MAX_DEC_POS, cfg.d_model), (None, "embed"),
             scale=0.01)
    with pb.scope("encoder"), pb.stack(cfg.n_encoder_layers):
        def_layernorm(pb, "ln_attn", cfg.d_model)
        def_attention(pb, "attn", cfg)
        def_layernorm(pb, "ln_mlp", cfg.d_model)
        def_mlp_gelu(pb, "mlp", cfg.d_model, cfg.d_ff)
    with pb.scope("decoder"), pb.stack(cfg.n_layers):
        def_layernorm(pb, "ln_self", cfg.d_model)
        def_attention(pb, "self_attn", cfg)
        def_layernorm(pb, "ln_cross", cfg.d_model)
        def_attention(pb, "cross_attn", cfg)
        def_layernorm(pb, "ln_mlp", cfg.d_model)
        def_mlp_gelu(pb, "mlp", cfg.d_model, cfg.d_ff)
    def_layernorm(pb, "ln_enc_final", cfg.d_model)
    def_layernorm(pb, "ln_final", cfg.d_model)


def encode(params: PyTree, cfg: ModelConfig, audio_embeds):
    """audio_embeds: (B, F, d) -> encoder output (B, F, d)."""
    dtype = jnp.dtype(cfg.dtype)
    B, F, _ = audio_embeds.shape
    h = audio_embeds.astype(dtype)
    h = h + sinusoidal_positions(F, cfg.d_model).astype(dtype)[None]
    h = shard(h, "batch", "seq", None)

    def body(h, lp):
        hin = layernorm(lp["ln_attn"], h, cfg.norm_eps)
        h = h + attention_full(lp["attn"], hin, cfg, causal=False,
                               use_rope=False)
        hin = layernorm(lp["ln_mlp"], h, cfg.norm_eps)
        h = h + mlp_gelu(lp["mlp"], hin)
        return h, None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["encoder"])
    return layernorm(params["ln_enc_final"], h, cfg.norm_eps)


def _dec_layer_full(lp, h, enc_out, cfg: ModelConfig, capture: bool):
    hin = layernorm(lp["ln_self"], h, cfg.norm_eps)
    B, S = hin.shape[:2]
    cache = None
    if capture:
        k = linear(lp["self_attn"]["wk"], hin).reshape(
            B, S, cfg.n_kv_heads, cfg.head_dim)
        v = linear(lp["self_attn"]["wv"], hin).reshape(
            B, S, cfg.n_kv_heads, cfg.head_dim)
        from repro.kernels.flash_attention import flash_attention
        q = linear(lp["self_attn"]["wq"], hin).reshape(
            B, S, cfg.n_heads, cfg.head_dim)
        attn = flash_attention(q, k, v, causal=True)
        attn = linear(lp["self_attn"]["wo"], attn.reshape(B, S, cfg.q_dim))
        ck, cv = cross_kv(lp["cross_attn"], enc_out, cfg)
        cache = ((k, v), (ck, cv))
    else:
        attn = attention_full(lp["self_attn"], hin, cfg, use_rope=False)
    h = h + attn
    hin = layernorm(lp["ln_cross"], h, cfg.norm_eps)
    if capture:
        (ck, cv) = cache[1]
        kv = (ck, cv)
    else:
        kv = cross_kv(lp["cross_attn"], enc_out, cfg)
    h = h + attention_full(lp["cross_attn"], hin, cfg, causal=False,
                           kv_override=kv)
    hin = layernorm(lp["ln_mlp"], h, cfg.norm_eps)
    h = h + mlp_gelu(lp["mlp"], hin)
    return h, cache


def encdec_forward(params: PyTree, cfg: ModelConfig, audio_embeds, tokens,
                   *, return_cache: bool = False):
    """-> (logits fp32, aux=0, cache|None).  Whisper has no positional
    RoPE: decoder uses a learned table; self-attn is causal."""
    dtype = jnp.dtype(cfg.dtype)
    enc_out = encode(params, cfg, audio_embeds)
    B, S = tokens.shape
    h = embed(params["embed"], tokens, dtype)
    h = h + params["dec_pos"][:S].astype(dtype)[None]
    h = shard(h, "batch", "seq", None)

    def body(h, lp):
        h, c = _dec_layer_full(lp, h, enc_out, cfg, return_cache)
        return h, c

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    h, raw_cache = jax.lax.scan(body, h, params["decoder"])
    h = layernorm(params["ln_final"], h, cfg.norm_eps)
    logits = unembed(params["embed"], h)
    logits = shard(logits, "batch", "seq", "vocab")
    aux = jnp.zeros((), jnp.float32)
    cache = None
    if return_cache:
        (sk, sv), (ck, cv) = raw_cache
        cache = {"self": (sk, sv), "cross": (ck, cv)}
    return logits, aux, cache


def make_encdec_cache(cfg: ModelConfig, batch: int, max_len: int,
                      mode: str = "shape"):
    dtype = jnp.dtype(cfg.dtype)
    F = cfg.frontend.n_embeds
    kv_axes = kv_cache_axes(cfg)

    def mk(shape):
        if mode == "init":
            return jnp.zeros(shape, dtype)
        return jax.ShapeDtypeStruct(shape, dtype)

    self_shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                  cfg.head_dim)
    cross_shape = (cfg.n_layers, batch, F, cfg.n_kv_heads, cfg.head_dim)
    sax = ("layers",) + kv_axes
    cax = ("layers", "batch", None, "kv_heads", None)
    cache = {"self": (mk(self_shape), mk(self_shape)),
             "cross": (mk(cross_shape), mk(cross_shape))}
    axes = {"self": (sax, sax), "cross": (cax, cax)}
    return cache, axes


def encdec_decode(params: PyTree, cfg: ModelConfig, token, pos, cache):
    """Single-token decoder step.  cache: {'self': (k,v), 'cross': (k,v)}
    with leading layer dim.  Returns (logits (B,1,V) fp32, new_cache)."""
    dtype = jnp.dtype(cfg.dtype)
    B = token.shape[0]
    h = embed(params["embed"], token, dtype)
    h = h + params["dec_pos"][pos][:, None].astype(dtype)
    F = cache["cross"][0].shape[2]
    flen = jnp.full((B,), F, jnp.int32)

    def body(h, xs):
        lp, (sk, sv), (ck, cv) = xs
        hin = layernorm(lp["ln_self"], h, cfg.norm_eps)
        attn, sk, sv = attention_decode(lp["self_attn"], hin, sk, sv, pos,
                                        cfg, use_rope=False)
        h = h + attn
        hin = layernorm(lp["ln_cross"], h, cfg.norm_eps)
        attn, _, _ = attention_decode(lp["cross_attn"], hin, ck, cv, flen,
                                      cfg, use_rope=False,
                                      update_cache=False)
        h = h + attn
        hin = layernorm(lp["ln_mlp"], h, cfg.norm_eps)
        h = h + mlp_gelu(lp["mlp"], hin)
        return h, (sk, sv)

    h, new_self = jax.lax.scan(
        body, h, (params["decoder"], cache["self"], cache["cross"]))
    h = layernorm(params["ln_final"], h, cfg.norm_eps)
    logits = unembed(params["embed"], h)
    return logits, {"self": new_self, "cross": cache["cross"]}
