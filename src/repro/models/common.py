"""Parameter construction with logical sharding axes.

One code path defines parameter structure, shapes, init distributions AND
logical sharding axes; the ``ParamBuilder`` runs it in one of three modes:

  * ``init``  — materialize arrays (PRNG derived from the scoped name, so
                init is order-independent and restart-stable)
  * ``spec``  — return the logical-axes tuple per param (for sharding rules)
  * ``shape`` — return ShapeDtypeStruct per param (for dry-run eval_shape)

Logical axes are mapped to mesh axes by ``repro.distributed.sharding`` with
divisibility-checked fallback, so a single model definition serves every
mesh (1-device CPU smoke tests, 16x16 pods, 2x16x16 multi-pod).
"""
from __future__ import annotations

import hashlib
from contextlib import contextmanager
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Axes = Tuple[Optional[str], ...]
PyTree = Any


def _name_seed(name: str, base_seed: int) -> int:
    h = hashlib.sha256(f"{base_seed}:{name}".encode()).digest()
    return int.from_bytes(h[:8], "little") % (2**63 - 1)


class ParamBuilder:
    """Scoped parameter factory.  See module docstring for modes."""

    def __init__(self, mode: str, seed: int = 0, dtype: str = "float32"):
        assert mode in ("init", "spec", "shape")
        self.mode = mode
        self.seed = seed
        self.dtype = jnp.dtype(dtype)
        self._scope: List[str] = []
        self._stack: List[Tuple[int, str]] = []   # (n, axis_name)
        self.tree: Dict[str, Any] = {}

    # -- scoping -------------------------------------------------------------
    @contextmanager
    def scope(self, name: str):
        self._scope.append(name)
        try:
            yield self
        finally:
            self._scope.pop()

    @contextmanager
    def stack(self, n: int, axis: str = "layers"):
        """Every param declared inside gets a leading (n,) dim with logical
        axis ``axis`` — the scan-over-layers parameter layout.  Nested
        stacks compose (e.g. (groups, layers_per_group, ...))."""
        self._stack.append((int(n), axis))
        try:
            yield self
        finally:
            self._stack.pop()

    def _path(self, name: str) -> str:
        return "/".join(self._scope + [name])

    def _insert(self, name: str, value: Any) -> Any:
        node = self.tree
        parts = self._scope + [name]
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        if parts[-1] in node:
            raise ValueError(f"duplicate param {'/'.join(parts)}")
        node[parts[-1]] = value
        return value

    # -- param declaration ----------------------------------------------------
    def param(self, name: str, shape: Sequence[int], axes: Axes,
              init: str = "normal", scale: Optional[float] = None,
              dtype: Optional[Any] = None) -> Any:
        shape = tuple(int(s) for s in shape)
        if len(axes) != len(shape):
            raise ValueError(
                f"{self._path(name)}: axes {axes} rank != shape {shape}")
        if self._stack:
            shape = tuple(n for n, _ in self._stack) + shape
            axes = tuple(a for _, a in self._stack) + tuple(axes)
        dt = jnp.dtype(dtype) if dtype is not None else self.dtype
        if self.mode == "spec":
            return self._insert(name, axes)
        if self.mode == "shape":
            return self._insert(name, jax.ShapeDtypeStruct(shape, dt))
        key = jax.random.PRNGKey(_name_seed(self._path(name), self.seed))
        if init == "normal":
            fan_in = shape[-2] if len(shape) >= 2 else max(shape[-1], 1)
            s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
            arr = jax.random.normal(key, shape, dtype=jnp.float32) * s
        elif init == "zeros":
            arr = jnp.zeros(shape, dtype=jnp.float32)
        elif init == "ones":
            arr = jnp.ones(shape, dtype=jnp.float32)
        elif init == "ssm_a":          # Mamba A_log init: log(uniform[1,16])
            u = jax.random.uniform(key, shape, minval=1.0, maxval=16.0)
            arr = jnp.log(u)
        elif init == "ssm_dt":         # dt_bias ~ softplus-inv(U[1e-3, 1e-1])
            u = jax.random.uniform(key, shape, minval=1e-3, maxval=1e-1)
            arr = u + jnp.log(-jnp.expm1(-u))
        else:
            raise ValueError(f"unknown init {init!r}")
        return self._insert(name, arr.astype(dt))


def build(fn: Callable[[ParamBuilder], None], mode: str, seed: int = 0,
          dtype: str = "float32") -> PyTree:
    pb = ParamBuilder(mode, seed=seed, dtype=dtype)
    fn(pb)
    return pb.tree


# ---------------------------------------------------------------------------
# Activation sharding context
# ---------------------------------------------------------------------------
# Model code calls shard(x, "batch", "seq", "heads", ...) with logical axes;
# outside a mesh context this is a no-op so smoke tests need no mesh.

_CTX: Dict[str, Any] = {"mesh": None, "rules": None}


@contextmanager
def sharding_ctx(mesh, rules):
    """Install (mesh, LogicalRules) so shard()/logical_pspec() resolve."""
    prev = dict(_CTX)
    _CTX["mesh"] = mesh
    _CTX["rules"] = rules
    try:
        yield
    finally:
        _CTX.update(prev)


def current_mesh():
    return _CTX["mesh"]


def current_rules():
    return _CTX["rules"]


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain activation sharding by logical axes (no-op without mesh)."""
    mesh, rules = _CTX["mesh"], _CTX["rules"]
    if mesh is None or rules is None:
        return x
    spec = rules.pspec_for_shape(x.shape, axes)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))
