"""Shared neural-net building blocks (pure functions over ParamBuilder
trees).

Conventions:
  * ``def_*(pb, ...)`` declares parameters (works in init/spec/shape modes).
  * ``*_apply(p, x, ...)`` consumes the matching subtree.
  * Compute dtype follows the activations (bf16 in production); params are
    cast at the point of use; norms and softmax run in fp32.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamBuilder


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def def_rmsnorm(pb: ParamBuilder, name: str, dim: int) -> None:
    with pb.scope(name):
        pb.param("scale", (dim,), (None,), init="ones")


def rmsnorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def def_layernorm(pb: ParamBuilder, name: str, dim: int) -> None:
    with pb.scope(name):
        pb.param("scale", (dim,), (None,), init="ones")
        pb.param("bias", (dim,), (None,), init="zeros")


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Linear / embedding
# ---------------------------------------------------------------------------

def def_linear(pb: ParamBuilder, name: str, d_in: int, d_out: int,
               axes: Tuple[Optional[str], Optional[str]],
               bias: bool = False, bias_axis: Optional[str] = None) -> None:
    with pb.scope(name):
        pb.param("w", (d_in, d_out), axes)
        if bias:
            pb.param("b", (d_out,), (bias_axis,), init="zeros")


def linear(p, x):
    y = jnp.einsum("...i,io->...o", x, p["w"].astype(x.dtype))
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def def_embedding(pb: ParamBuilder, name: str, vocab: int, dim: int) -> None:
    with pb.scope(name):
        pb.param("table", (vocab, dim), ("vocab", "embed"), scale=1.0)


def embed(p, tokens, dtype):
    return p["table"].astype(dtype)[tokens]


def unembed(p, x):
    """Logits in fp32 (loss numerics)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      p["table"].astype(jnp.float32))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_tables(positions, head_dim: int, theta: float):
    """positions: (...,) int32 -> cos, sin of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D); cos/sin: (B, S, D//2) or (S, D//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None]
        sin = sin[None]
    c = cos[..., None, :].astype(x.dtype)    # (B, S, 1, D/2)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def sinusoidal_positions(n: int, dim: int):
    """Whisper-style fixed sinusoidal position embeddings (n, dim)."""
    half = dim // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10_000.0) / max(half - 1, 1)))
    ang = jnp.arange(n, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def def_mlp_swiglu(pb: ParamBuilder, name: str, d_model: int, d_ff: int,
                   d_in: Optional[int] = None) -> None:
    d_in = d_in or d_model
    with pb.scope(name):
        pb.param("w_gate", (d_in, d_ff), ("embed", "mlp"))
        pb.param("w_up", (d_in, d_ff), ("embed", "mlp"))
        pb.param("w_down", (d_ff, d_model), ("mlp", "embed"))


def mlp_swiglu(p, x):
    from repro.models.common import shard
    g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    h = shard(h, *((None,) * (h.ndim - 1)), "mlp")
    return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(x.dtype))


def def_mlp_gelu(pb: ParamBuilder, name: str, d_model: int, d_ff: int,
                 d_in: Optional[int] = None) -> None:
    d_in = d_in or d_model
    with pb.scope(name):
        pb.param("w_in", (d_in, d_ff), ("embed", "mlp"))
        pb.param("b_in", (d_ff,), ("mlp",), init="zeros")
        pb.param("w_out", (d_ff, d_model), ("mlp", "embed"))
        pb.param("b_out", (d_model,), (None,), init="zeros")


def mlp_gelu(p, x):
    from repro.models.common import shard
    h = jnp.einsum("...d,df->...f", x, p["w_in"].astype(x.dtype)) \
        + p["b_in"].astype(x.dtype)
    h = jax.nn.gelu(h)
    h = shard(h, *((None,) * (h.ndim - 1)), "mlp")
    return jnp.einsum("...f,fd->...d", h, p["w_out"].astype(x.dtype)) \
        + p["b_out"].astype(x.dtype)
