"""Mamba2 (SSD) block: in_proj -> (z, x, B, C, dt), causal depthwise conv,
SSD scan (Pallas kernel on TPU), gated RMSNorm, out_proj.

Decode carries two states per layer: the SSM state (B, H, P, N) fp32 and a
conv tail (B, d_conv-1, conv_dim) holding the last inputs of the depthwise
convolution.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.ssd_scan import ssd_scan, ssd_step
from repro.models.common import ParamBuilder, shard
from repro.models.layers import def_linear, linear, rmsnorm


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.d_inner(cfg.d_model)
    n_heads = s.n_heads(cfg.d_model)
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return s, d_inner, n_heads, conv_dim


def def_ssm_block(pb: ParamBuilder, name: str, cfg: ModelConfig) -> None:
    s, d_inner, n_heads, conv_dim = _dims(cfg)
    d = cfg.d_model
    with pb.scope(name):
        # fused input projection: [z (d_inner), x (d_inner), B, C, dt]
        d_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + n_heads
        def_linear(pb, "in_proj", d, d_proj, ("embed", "mlp"))
        pb.param("conv_w", (s.d_conv, conv_dim), (None, "mlp"))
        pb.param("conv_b", (conv_dim,), ("mlp",), init="zeros")
        pb.param("A_log", (n_heads,), (None,), init="ssm_a")
        pb.param("dt_bias", (n_heads,), (None,), init="ssm_dt")
        pb.param("D", (n_heads,), (None,), init="ones")
        pb.param("norm_scale", (d_inner,), ("mlp",), init="ones")
        def_linear(pb, "out_proj", d_inner, d, ("mlp", "embed"))


def _split_proj(proj, cfg: ModelConfig):
    s, d_inner, n_heads, _ = _dims(cfg)
    gN = s.n_groups * s.d_state
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:d_inner + d_inner + 2 * gN]
    dt = proj[..., -n_heads:]
    return z, xbc, dt


def _gated_norm(p, y, z, eps: float):
    """Mamba2's normalization: RMSNorm(y * silu(z))."""
    g = y * jax.nn.silu(z)
    return rmsnorm({"scale": p["norm_scale"]}, g, eps)


def ssm_block_full(p, x, cfg: ModelConfig, return_state: bool = False):
    """Full-sequence SSD.  x: (B, S, d_model) -> (B, S, d_model)
    [, decode state dict when return_state]."""
    s, d_inner, n_heads, conv_dim = _dims(cfg)
    gN = s.n_groups * s.d_state
    B_, S_ = x.shape[:2]
    proj = linear(p["in_proj"], x)
    z, xbc, dt = _split_proj(proj, cfg)
    # causal depthwise conv over time
    pad = jnp.pad(xbc, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + S_] * p["conv_w"][i].astype(x.dtype)
               for i in range(s.d_conv))
    conv = jax.nn.silu(conv + p["conv_b"].astype(x.dtype))
    xs = conv[..., :d_inner].reshape(B_, S_, n_heads, s.head_dim)
    Bmat = conv[..., d_inner:d_inner + gN]
    Cmat = conv[..., d_inner + gN:]
    dt_act = jax.nn.softplus(dt.astype(jnp.float32)
                             + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xs = shard(xs, "batch", "seq", "heads", None)
    y, final_state = ssd_scan(xs, dt_act, A, Bmat, Cmat,
                              p["D"].astype(jnp.float32),
                              chunk=s.chunk_size)
    y = y.reshape(B_, S_, d_inner)
    y = _gated_norm(p, y, z, cfg.norm_eps)
    out = linear(p["out_proj"], y)
    if return_state:
        state = {"ssm": final_state,
                 "conv": xbc[:, S_ - (s.d_conv - 1):, :]}
        return out, state
    return out


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    s, d_inner, n_heads, conv_dim = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, n_heads, s.head_dim, s.d_state),
                         jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
    }


def ssm_state_axes(cfg: ModelConfig):
    return {"ssm": ("batch", "heads", None, None),
            "conv": ("batch", None, "mlp")}


def ssm_block_decode(p, x, state, cfg: ModelConfig):
    """Single-token decode.  x: (B, 1, d_model); state: init_ssm_state().

    Returns (out (B, 1, d_model), new_state).
    """
    s, d_inner, n_heads, conv_dim = _dims(cfg)
    gN = s.n_groups * s.d_state
    B_ = x.shape[0]
    proj = linear(p["in_proj"], x[:, 0])               # (B, d_proj)
    z, xbc, dt = _split_proj(proj, cfg)
    # depthwise conv over the stored tail + the new input
    hist = jnp.concatenate(
        [state["conv"], xbc[:, None, :].astype(state["conv"].dtype)], axis=1)
    conv = jnp.einsum("btc,tc->bc", hist.astype(x.dtype),
                      p["conv_w"].astype(x.dtype))
    conv = jax.nn.silu(conv + p["conv_b"].astype(x.dtype))
    xt = conv[..., :d_inner].reshape(B_, n_heads, s.head_dim)
    Bt = conv[..., d_inner:d_inner + gN]
    Ct = conv[..., d_inner + gN:]
    dt_act = jax.nn.softplus(dt.astype(jnp.float32)
                             + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, new_ssm = ssd_step(state["ssm"], xt, dt_act, A, Bt, Ct,
                          p["D"].astype(jnp.float32))
    y = y.reshape(B_, d_inner)
    y = _gated_norm(p, y, z, cfg.norm_eps)
    out = linear(p["out_proj"], y)[:, None, :]
    new_state = {"ssm": new_ssm, "conv": hist[:, 1:]}
    return out, new_state
