"""Exploratory query subsystem: pre-process once, query many.

MultiScope's serving story (§1, §4.2): the pipeline extracts tracks from
a dataset ONCE, and an open-ended stream of analyst queries is answered
from the materialized tracks in milliseconds — the detector is never
touched again for a warm clip.

  * ``store``   — ``TrackStore``: persistent, versioned materialization
    of ``executor.run_clips`` outputs, keyed by
    (dataset, clip, θ-fingerprint), with incremental ingest;
  * ``ops``     — composable query operators (spatial regions, temporal
    ranges, per-frame count predicates, track filters, limit-N,
    aggregations);
  * ``plan``    — compiles a ``Query`` into a vectorized numpy plan
    over the store's packed track arrays;
  * ``service`` — ``QueryService``: thread-safe concurrent queries with
    transparent ingest of cold clips and per-query latency accounting
    (ingest vs scan).
"""
from repro.query.ops import (CountAtLeast, Limit, Query, Region,  # noqa: F401
                             TimeRange, TrackFilter)
from repro.query.plan import CompiledPlan, QueryResult, compile_query  # noqa: F401
from repro.query.service import QueryService, QueryStats  # noqa: F401
from repro.query.store import (IngestReport, PackedTracks,  # noqa: F401
                               TrackStore, theta_fingerprint)
