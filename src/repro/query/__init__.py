"""Exploratory query subsystem: pre-process once, query many.

MultiScope's serving story (§1, §4.2): the pipeline extracts tracks from
a dataset ONCE, and an open-ended stream of analyst queries is answered
from the materialized tracks in milliseconds — the detector is never
touched again for a warm clip.

  * ``store``   — ``TrackStore``: persistent, versioned materialization
    of ``executor.run_clips`` outputs, keyed by
    (dataset, clip, θ-fingerprint), with incremental ingest, an
    optional ``StoreBudget`` (LRU/TTL eviction of clip NPZs; evicted
    clips keep their index summaries and re-ingest on next touch), and
    an OPEN-clip layout for live ingestion (monotone ``watermark``
    marking how much of a still-arriving clip is queryable);
  * ``index``   — secondary indexes built at materialize time:
    per-frame count histograms (min_len buckets), per-track bounding
    boxes, coarse 4x4 occupancy grids, and per-clip ``ClipSummary``
    digests persisted in the version's ``index.json`` (they survive
    eviction);
  * ``ops``     — composable query operators (spatial regions, temporal
    ranges, per-frame count predicates, track filters, limit-N,
    aggregations, an optional dataset scope);
  * ``plan``    — compiles a ``Query`` into a two-phase plan: consult
    the index to skip whole clips (bbox/grid/span/count bounds) or
    answer count/limit queries from histograms, fall back to the
    vectorized row scan otherwise — bit-identical either way
    (tests/test_query_index.py);
  * ``service`` — ``QueryService``: thread-safe concurrent queries over
    one store or a ``{dataset: store}`` mapping, with transparent
    ingest of cold clips, summary-aware ``prefetch`` ordering
    (unskippable clips first, biggest predicted scan first), per-query
    latency accounting (ingest vs scan, median + p95), and standing-
    query subscriptions for live streams.

Live ingestion (``repro.stream``) makes this subsystem continuous —
cameras append frame segments to open clips and queries stay
answerable at every watermark:

    ingestor = SegmentIngestor(store, service=service)
    sq = service.register_standing(
        StandingQuery(Query.count_frames(min_count=2), clips))
    ingestor.open(clip)
    ingestor.append(clip, 12)     # 12 new frames: tracker state
                                  # resumes, index merges, sq gets an
                                  # exact delta for the new watermark

See ``examples/quickstart.py`` for the end-to-end live-append loop.
"""
from repro.query.index import (MIN_LEN_BUCKETS, ClipSummary,  # noqa: F401
                               build_index, summarize)
from repro.query.ops import (CountAtLeast, Limit, Query, Region,  # noqa: F401
                             TimeRange, TrackFilter)
from repro.query.plan import CompiledPlan, QueryResult, compile_query  # noqa: F401,E501
from repro.query.service import QueryService, QueryStats  # noqa: F401
from repro.query.store import (IngestReport, PackedTracks,  # noqa: F401
                               StoreBudget, TrackStore,
                               theta_fingerprint)
