"""Exploratory query subsystem: pre-process once, query many.

MultiScope's serving story (§1, §4.2): the pipeline extracts tracks from
a dataset ONCE, and an open-ended stream of analyst queries is answered
from the materialized tracks in milliseconds — the detector is never
touched again for a warm clip.

  * ``store``   — ``TrackStore``: persistent, versioned materialization
    of ``executor.run_clips`` outputs, keyed by
    (dataset, clip, θ-fingerprint), with incremental ingest and an
    optional ``StoreBudget`` (LRU/TTL eviction of clip NPZs; evicted
    clips keep their index summaries and re-ingest on next touch);
  * ``index``   — secondary indexes built at materialize time:
    per-frame count histograms (min_len buckets), per-track bounding
    boxes, and per-clip ``ClipSummary`` digests persisted in the
    version's ``index.json`` (they survive eviction);
  * ``ops``     — composable query operators (spatial regions, temporal
    ranges, per-frame count predicates, track filters, limit-N,
    aggregations, an optional dataset scope);
  * ``plan``    — compiles a ``Query`` into a two-phase plan: consult
    the index to skip whole clips or answer count/limit queries from
    histograms, fall back to the vectorized row scan otherwise —
    bit-identical either way (tests/test_query_index.py);
  * ``service`` — ``QueryService``: thread-safe concurrent queries over
    one store or a ``{dataset: store}`` mapping, with transparent
    ingest of cold clips and per-query latency accounting
    (ingest vs scan, median + p95).
"""
from repro.query.index import (MIN_LEN_BUCKETS, ClipSummary,  # noqa: F401
                               build_index, summarize)
from repro.query.ops import (CountAtLeast, Limit, Query, Region,  # noqa: F401
                             TimeRange, TrackFilter)
from repro.query.plan import CompiledPlan, QueryResult, compile_query  # noqa: F401,E501
from repro.query.service import QueryService, QueryStats  # noqa: F401
from repro.query.store import (IngestReport, PackedTracks,  # noqa: F401
                               StoreBudget, TrackStore,
                               theta_fingerprint)
