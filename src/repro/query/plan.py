"""Query compilation + two-phase vectorized execution over packed
track arrays.

``compile_query`` folds a ``Query``'s operator conjunction into one
``CompiledPlan`` (regions intersect, time ranges intersect, track
filters merge, count thresholds take the max).  Execution is
two-phase, per clip, in scan order:

  **Phase 1 — consult the index** (``repro.query.index``):

  1. skip test    — the clip's ``ClipSummary`` proves it cannot
     contribute (query region disjoint from the union track bbox, time
     window outside the frame span, ``min_count`` above the per-frame
     maximum, ``min_len`` above the longest track).  Skipped clips cost
     O(1), are never loaded (summaries survive eviction), and don't
     count toward ``scanned_clips``;
  2. histogram answer — when the predicate is indexed (min_len is a
     histogram bucket, no class filter, region absent or provably a
     no-op because it contains the bucket's union bbox), per-frame
     counts come straight from the precomputed histogram row — zero
     rows touched, bit-identical to the scan by construction.

  **Phase 2 — fall back to the row scan** (the PR-3 path):

  1. track mask   — ``lengths >= min_len`` (&& class membership);
  2. row mask     — track mask gathered onto rows, AND region bounds on
     the (cx, cy) columns, AND the frame-index window;
  3. frame counts — ``np.bincount`` of the surviving rows' frame
     column: per-frame object counts in one pass;

  then (either phase) matching frames are ``counts >= k`` via
  ``np.flatnonzero`` (ascending order for free), and limit queries run
  the greedy spacing filter per clip, early-exiting the clip loop the
  moment the n-th frame is found.

``run(..., use_index=False)`` disables phase 1 entirely — the
differential tests (tests/test_query_index.py) assert both modes give
bit-identical results on every query shape, and the benchmark's
indexed-vs-scan mode measures the gap.

Every step is O(rows) vectorized (O(1) when the index answers);
nothing at query time touches pixels, models, or per-track Python
loops, which is what makes warm queries run in milliseconds against
multi-clip stores (BENCH_query.json).

The limit-scan semantics replicate the original inline
``experiment.limit_query_experiment`` loop exactly (clips in order,
frames ascending, spacing enforced only within a clip), asserted by
tests/test_query.py.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.query.index import (MIN_LEN_BUCKETS, ClipSummary,
                               bbox_is_empty, region_mask)
from repro.query.ops import (CountAtLeast, Limit, Query, Region,
                             TimeRange, TrackFilter)
from repro.query.store import PackedTracks


@dataclass
class QueryResult:
    """What a plan returns.  ``frames`` is the matching
    (clip_index, frame) list (limit queries); ``aggregates`` carries the
    scalar results; ``scanned_clips``/``skipped_clips``/``indexed_clips``
    show the early-exit and the index at work."""
    frames: List[Tuple[int, int]] = field(default_factory=list)
    aggregates: Dict[str, float] = field(default_factory=dict)
    scanned_clips: int = 0      # clips that touched packed arrays
    skipped_clips: int = 0      # clips proven irrelevant by summary
    indexed_clips: int = 0      # clips answered from the histogram
    n_clips: int = 0
    stats: Optional[object] = None      # QueryStats, filled by the service


def _normalize(entry) -> Tuple[object, Optional[PackedTracks],
                               Optional[ClipSummary]]:
    """Entries are (clip, packed) or (clip, packed, summary)."""
    if len(entry) == 2:
        clip, packed = entry
        return clip, packed, None
    clip, packed, summary = entry
    return clip, packed, summary


@dataclass(frozen=True)
class CompiledPlan:
    """The folded conjunction, ready to scan packed arrays."""
    region: Optional[Region]
    time_range: Optional[TimeRange]
    min_len: int
    classes: Optional[Tuple[int, ...]]
    min_count: int
    limit: Optional[Limit]
    aggregate: str
    datasets: Optional[Tuple[str, ...]] = None      # service-level scope

    def describe(self) -> str:
        parts = [f"agg={self.aggregate}", f"count>={self.min_count}",
                 f"len>={self.min_len}"]
        if self.region is not None:
            r = self.region
            parts.append(f"region=[{r.x0},{r.y0},{r.x1},{r.y1}]")
        if self.time_range is not None:
            parts.append(f"t=[{self.time_range.start},"
                         f"{self.time_range.end})")
        if self.classes is not None:
            parts.append(f"classes={sorted(self.classes)}")
        if self.limit is not None:
            parts.append(f"limit={self.limit.n}"
                         f"@{self.limit.min_spacing}")
        if self.datasets is not None:
            parts.append(f"datasets={sorted(self.datasets)}")
        return " ".join(parts)

    # -- phase 1: index consultation ------------------------------------------

    def _floor_bucket(self) -> int:
        """Index of the largest bucket <= min_len.  Sound for pruning:
        the bucket's surviving set is a SUPERSET of the plan's, so its
        max_count/bbox bound the plan's from above."""
        bi = 0
        for i, b in enumerate(MIN_LEN_BUCKETS):
            if b <= self.min_len:
                bi = i
        return bi

    def can_skip(self, summary: Optional[ClipSummary]) -> bool:
        """True when the summary PROVES the clip contributes nothing to
        this plan (sound for every aggregate: no surviving row means no
        frame, no second, no track)."""
        if summary is None:
            return False
        if summary.n_rows == 0:
            return True
        if self.min_len > summary.max_len:
            return True                 # no track long enough
        bi = self._floor_bucket()
        if self.aggregate != "tracks" \
                and self.min_count > summary.max_count[bi]:
            # no frame can reach the count — but the "tracks" aggregate
            # ignores count predicates, so the test is unsound there
            return True
        if self.time_range is not None:
            t = self.time_range
            if t.start > summary.max_frame:
                return True
            if t.end is not None and t.end <= summary.min_frame:
                return True
        if self.region is not None \
                and self._region_disjoint(summary, bi):
            return True
        return False

    def _region_disjoint(self, summary: ClipSummary, bi: int) -> bool:
        """The region provably touches no surviving detection of bucket
        ``bi``: disjoint from the union bbox, or — finer — from the
        occupancy grid (a region can overlap the bbox yet intersect no
        occupied cell, e.g. the empty middle between two lanes)."""
        r = self.region
        if math.isnan(r.x0):
            return True                 # folded-disjoint sentinel region
        bb = summary.bbox[bi]
        if bbox_is_empty(bb):
            return True                 # no surviving track anywhere
        if r.x1 < bb[0] or bb[2] < r.x0 \
                or r.y1 < bb[1] or bb[3] < r.y0:
            return True                 # region disjoint from every track
        if summary.grid is not None and not (
                summary.grid[bi] & region_mask(r.x0, r.y0, r.x1, r.y1)):
            return True                 # bbox overlaps, occupied cells don't
        return False

    def row_disjoint(self, summary: Optional[ClipSummary]) -> bool:
        """True when the summary proves every row CURRENTLY visible
        fails a static row-level predicate (region / time) — a
        PERMANENT disqualification, unlike ``can_skip``'s count and
        track-length tests, which later appends can overturn.  Standing
        queries (``repro.stream.standing``) use this to drop a
        watermark's delta outright: rows visible now and provably
        region- or time-disjoint can never match later, because row
        predicates never change."""
        if summary is None:
            return False
        if summary.n_rows == 0:
            return True
        if self.time_range is not None:
            t = self.time_range
            if t.start > summary.max_frame:
                return True
            if t.end is not None and t.end <= summary.min_frame:
                return True
        # bucket 0 (min_len floor 1) covers EVERY visible row; higher
        # buckets would be unsound here — a track below the plan's
        # min_len today can cross it tomorrow, its old rows with it
        if self.region is not None and self._region_disjoint(summary, 0):
            return True
        return False

    def _indexed_counts(self, packed: PackedTracks) -> Optional[np.ndarray]:
        """Per-frame counts straight from the histogram, or None when
        the predicate is not indexed (class filter, off-bucket min_len,
        region that actually filters rows)."""
        if self.classes is not None or packed.hist is None:
            return None
        if self.min_len not in MIN_LEN_BUCKETS:
            return None
        bi = MIN_LEN_BUCKETS.index(self.min_len)
        if self.region is not None:
            bb = packed.summary.bbox[bi]
            if not bbox_is_empty(bb):
                r = self.region
                if not (r.x0 <= bb[0] and r.y0 <= bb[1]
                        and bb[2] <= r.x1 and bb[3] <= r.y1):
                    return None         # region filters: needs the scan
            # empty bbox: every histogram row is zero, region moot
        counts = packed.hist[bi].astype(np.int64)   # astype = fresh copy
        if self.time_range is not None:
            t = self.time_range
            if t.start > 0:
                counts[:min(t.start, len(counts))] = 0
            if t.end is not None and t.end < len(counts):
                counts[t.end:] = 0
        return counts

    # -- phase 2: per-clip scan kernels ---------------------------------------

    def _row_mask(self, packed: PackedTracks, profile) -> np.ndarray:
        """(N,) rows surviving the track + region + time predicates."""
        tmask = packed.lengths >= self.min_len
        if self.classes is not None:
            tmask &= np.isin(packed.classes(profile),
                             np.asarray(self.classes, np.int64))
        mask = tmask[packed.row_track] if packed.n_tracks \
            else np.zeros(0, bool)
        rows = packed.rows
        if self.region is not None:
            r = self.region
            cx, cy = rows[:, 1], rows[:, 2]
            mask &= (cx >= r.x0) & (cx <= r.x1) \
                & (cy >= r.y0) & (cy <= r.y1)
        if self.time_range is not None:
            f = rows[:, 0]
            mask &= f >= self.time_range.start
            if self.time_range.end is not None:
                mask &= f < self.time_range.end
        return mask

    def _frame_counts(self, packed: PackedTracks, profile) -> np.ndarray:
        """(n_frames,) surviving track points per frame."""
        mask = self._row_mask(packed, profile)
        frames = packed.rows[mask, 0].astype(np.int64)
        return np.bincount(frames, minlength=packed.n_frames)

    # -- execution ------------------------------------------------------------

    def run(self, entries: Sequence, use_index: bool = True
            ) -> QueryResult:
        """entries: (clip, PackedTracks[, ClipSummary]) in scan order;
        clip provides ``profile`` (fps, pattern classification) only.
        ``packed`` may be None only for clips the summary can skip
        (evicted clips the planner proved irrelevant)."""
        res = QueryResult(n_clips=len(entries))
        n_match = 0
        seconds = 0.0
        total_tracks = 0
        for ci, entry in enumerate(entries):
            clip, packed, summary = _normalize(entry)
            if self.limit is not None \
                    and len(res.frames) >= self.limit.n:
                break                   # early-exit: clip never scanned
            if self.datasets is not None \
                    and clip.profile.name not in self.datasets:
                continue                # out of scope: contributes nothing
            if summary is None and packed is not None:
                summary = packed.summary
            if use_index and self.can_skip(summary):
                res.skipped_clips += 1
                continue
            if packed is None:
                raise RuntimeError(
                    f"clip {ci} is cold and the index cannot skip it")
            res.scanned_clips += 1
            if self.aggregate == "tracks":
                mask = self._row_mask(packed, clip.profile)
                if packed.n_tracks:
                    total_tracks += len(
                        np.unique(packed.row_track[mask]))
                continue
            counts = self._indexed_counts(packed) if use_index else None
            if counts is not None:
                res.indexed_clips += 1
            else:
                counts = self._frame_counts(packed, clip.profile)
            hits = np.flatnonzero(counts >= self.min_count)
            n_match += len(hits)
            seconds += len(hits) / max(packed.fps, 1)
            if self.limit is None:
                if self.aggregate == "frames":
                    res.frames.extend((ci, int(f)) for f in hits)
                continue
            picked: List[int] = []
            spacing = self.limit.min_spacing
            for f in hits:
                if len(res.frames) >= self.limit.n:
                    break
                f = int(f)
                if all(abs(f - g) >= spacing for g in picked):
                    res.frames.append((ci, f))
                    picked.append(f)
        if self.aggregate == "tracks":
            res.aggregates["tracks"] = total_tracks
        elif self.limit is None:
            # under a limit the early-exit makes these partial sums;
            # Query rejects limit+scalar-aggregate, and we don't expose
            # truncated totals as side-channel aggregates either
            res.aggregates["count"] = n_match
            res.aggregates["duration_seconds"] = seconds
        if self.aggregate in ("count", "duration"):
            res.frames = []
        return res


def compile_query(q: Query) -> CompiledPlan:
    """Fold the operator conjunction into one CompiledPlan."""
    region: Optional[Region] = None
    time_range: Optional[TimeRange] = None
    min_len = 1
    classes: Optional[Tuple[int, ...]] = None
    min_count = 1
    for op in q.where:
        if isinstance(op, Region):
            region = op if region is None else region.intersect(op)
        elif isinstance(op, TimeRange):
            if time_range is None:
                time_range = op
            else:
                start = max(time_range.start, op.start)
                end = op.end if time_range.end is None else (
                    time_range.end if op.end is None
                    else min(time_range.end, op.end))
                if end is not None and end < start:
                    end = start     # disjoint ranges: match nothing
                time_range = TimeRange(start, end)
        elif isinstance(op, TrackFilter):
            min_len = max(min_len, op.min_len)
            if op.classes is not None:
                classes = tuple(op.classes) if classes is None \
                    else tuple(set(classes) & set(op.classes))
        elif isinstance(op, CountAtLeast):
            min_count = max(min_count, op.k)
        else:                               # Query.__post_init__ rejects
            raise TypeError(f"unknown operator {op!r}")
    return CompiledPlan(region, time_range, min_len, classes, min_count,
                        q.limit, q.aggregate, q.datasets)
