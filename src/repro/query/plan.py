"""Query compilation + vectorized execution over packed track arrays.

``compile_query`` folds a ``Query``'s operator conjunction into one
``CompiledPlan`` (regions intersect, time ranges intersect, track
filters merge, count thresholds take the max), and the plan scans each
clip's ``PackedTracks`` with pure numpy:

  1. track mask   — ``lengths >= min_len`` (&& class membership);
  2. row mask     — track mask gathered onto rows, AND region bounds on
     the (cx, cy) columns, AND the frame-index window;
  3. frame counts — ``np.bincount`` of the surviving rows' frame
     column: per-frame object counts in one pass;
  4. matching frames — ``counts >= k`` via ``np.flatnonzero``
     (ascending order for free);
  5. limit        — greedy spacing filter per clip, early-exiting the
     clip loop the moment the n-th frame is found.

Every step is O(rows) vectorized; nothing at query time touches pixels,
models, or per-track Python loops, which is what makes warm queries
run in milliseconds against multi-clip stores (BENCH_query.json).

The limit-scan semantics replicate the original inline
``experiment.limit_query_experiment`` loop exactly (clips in order,
frames ascending, spacing enforced only within a clip), asserted by
tests/test_query.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.query.ops import (CountAtLeast, Limit, Query, Region,
                             TimeRange, TrackFilter)
from repro.query.store import PackedTracks


@dataclass
class QueryResult:
    """What a plan returns.  ``frames`` is the matching
    (clip_index, frame) list (limit queries); ``aggregates`` carries the
    scalar results; ``scanned_clips`` shows the early-exit at work."""
    frames: List[Tuple[int, int]] = field(default_factory=list)
    aggregates: Dict[str, float] = field(default_factory=dict)
    scanned_clips: int = 0
    n_clips: int = 0
    stats: Optional[object] = None      # QueryStats, filled by the service


@dataclass(frozen=True)
class CompiledPlan:
    """The folded conjunction, ready to scan packed arrays."""
    region: Optional[Region]
    time_range: Optional[TimeRange]
    min_len: int
    classes: Optional[Tuple[int, ...]]
    min_count: int
    limit: Optional[Limit]
    aggregate: str

    def describe(self) -> str:
        parts = [f"agg={self.aggregate}", f"count>={self.min_count}",
                 f"len>={self.min_len}"]
        if self.region is not None:
            r = self.region
            parts.append(f"region=[{r.x0},{r.y0},{r.x1},{r.y1}]")
        if self.time_range is not None:
            parts.append(f"t=[{self.time_range.start},"
                         f"{self.time_range.end})")
        if self.classes is not None:
            parts.append(f"classes={sorted(self.classes)}")
        if self.limit is not None:
            parts.append(f"limit={self.limit.n}"
                         f"@{self.limit.min_spacing}")
        return " ".join(parts)

    # -- per-clip kernels -----------------------------------------------------

    def _row_mask(self, packed: PackedTracks, profile) -> np.ndarray:
        """(N,) rows surviving the track + region + time predicates."""
        tmask = packed.lengths >= self.min_len
        if self.classes is not None:
            tmask &= np.isin(packed.classes(profile),
                             np.asarray(self.classes, np.int64))
        mask = tmask[packed.row_track] if packed.n_tracks \
            else np.zeros(0, bool)
        rows = packed.rows
        if self.region is not None:
            r = self.region
            cx, cy = rows[:, 1], rows[:, 2]
            mask &= (cx >= r.x0) & (cx <= r.x1) \
                & (cy >= r.y0) & (cy <= r.y1)
        if self.time_range is not None:
            f = rows[:, 0]
            mask &= f >= self.time_range.start
            if self.time_range.end is not None:
                mask &= f < self.time_range.end
        return mask

    def _frame_counts(self, packed: PackedTracks, profile) -> np.ndarray:
        """(n_frames,) surviving track points per frame."""
        mask = self._row_mask(packed, profile)
        frames = packed.rows[mask, 0].astype(np.int64)
        return np.bincount(frames, minlength=packed.n_frames)

    # -- execution ------------------------------------------------------------

    def run(self, entries: Sequence[Tuple[object, PackedTracks]]
            ) -> QueryResult:
        """entries: (clip, PackedTracks) in scan order; clip provides
        ``profile`` (fps, pattern classification) only."""
        res = QueryResult(n_clips=len(entries))
        if self.aggregate == "tracks":
            total = 0
            for clip, packed in entries:
                res.scanned_clips += 1
                mask = self._row_mask(packed, clip.profile)
                if packed.n_tracks:
                    total += len(np.unique(packed.row_track[mask]))
            res.aggregates["tracks"] = total
            return res

        n_match = 0
        seconds = 0.0
        for ci, (clip, packed) in enumerate(entries):
            if self.limit is not None \
                    and len(res.frames) >= self.limit.n:
                break                       # early-exit: clip never scanned
            res.scanned_clips += 1
            counts = self._frame_counts(packed, clip.profile)
            hits = np.flatnonzero(counts >= self.min_count)
            n_match += len(hits)
            seconds += len(hits) / max(packed.fps, 1)
            if self.limit is None:
                if self.aggregate == "frames":
                    res.frames.extend((ci, int(f)) for f in hits)
                continue
            picked: List[int] = []
            spacing = self.limit.min_spacing
            for f in hits:
                if len(res.frames) >= self.limit.n:
                    break
                f = int(f)
                if all(abs(f - g) >= spacing for g in picked):
                    res.frames.append((ci, f))
                    picked.append(f)
        if self.limit is None:
            # under a limit the early-exit makes these partial sums;
            # Query rejects limit+scalar-aggregate, and we don't expose
            # truncated totals as side-channel aggregates either
            res.aggregates["count"] = n_match
            res.aggregates["duration_seconds"] = seconds
        if self.aggregate in ("count", "duration"):
            res.frames = []
        return res


def compile_query(q: Query) -> CompiledPlan:
    """Fold the operator conjunction into one CompiledPlan."""
    region: Optional[Region] = None
    time_range: Optional[TimeRange] = None
    min_len = 1
    classes: Optional[Tuple[int, ...]] = None
    min_count = 1
    for op in q.where:
        if isinstance(op, Region):
            region = op if region is None else region.intersect(op)
        elif isinstance(op, TimeRange):
            if time_range is None:
                time_range = op
            else:
                start = max(time_range.start, op.start)
                end = op.end if time_range.end is None else (
                    time_range.end if op.end is None
                    else min(time_range.end, op.end))
                if end is not None and end < start:
                    end = start     # disjoint ranges: match nothing
                time_range = TimeRange(start, end)
        elif isinstance(op, TrackFilter):
            min_len = max(min_len, op.min_len)
            if op.classes is not None:
                classes = tuple(op.classes) if classes is None \
                    else tuple(set(classes) & set(op.classes))
        elif isinstance(op, CountAtLeast):
            min_count = max(min_count, op.k)
        else:                               # Query.__post_init__ rejects
            raise TypeError(f"unknown operator {op!r}")
    return CompiledPlan(region, time_range, min_len, classes, min_count,
                        q.limit, q.aggregate)
