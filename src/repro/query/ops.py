"""Composable query operators over materialized tracks.

A ``Query`` is a conjunction of operators plus an optional limit and an
aggregation mode; ``repro.query.plan.compile_query`` folds the operator
list into one vectorized scan over the store's packed track arrays.

Row-level operators (restrict which track points count):
  * ``Region(x0, y0, x1, y1)``  — detection center inside the box,
    world units, bounds inclusive (matching the paper's Table-2 query);
  * ``TimeRange(start, end)``   — frame index in ``[start, end)``
    (``end=None`` → clip end).

Track-level operators (restrict which tracks contribute at all):
  * ``TrackFilter(min_len, classes)`` — minimum number of track rows
    (``min_len=2`` drops single-detection stubs, §4.2) and an optional
    set of spatial-pattern classes (``metrics.classify_track`` ids).

Frame-level operators:
  * ``CountAtLeast(k)`` — a frame matches when at least ``k`` surviving
    track points land on it.

Scoping:
  * ``Query.datasets`` — an optional tuple of dataset (profile) names;
    a scoped query only considers clips of those datasets.  This is how
    one ``QueryService`` fronting several stores routes a query: clips
    outside the scope are dropped BEFORE the scan, preserving the
    remaining clips' scan order and their indices into the caller's
    clip list.  ``q.scoped("caldot1")`` derives a scoped copy.

Result shaping:
  * ``Limit(n, min_spacing)`` — stop after ``n`` matching frames,
    scanning clips in order and frames in ascending order, skipping
    frames closer than ``min_spacing`` to an already-returned frame of
    the SAME clip.  The plan early-exits: clips past the n-th hit are
    never scanned.
  * ``Query.aggregate`` — "frames" (the matching (clip, frame) list),
    "count" (matching-frame count), "duration" (matching seconds at the
    clip's fps), or "tracks" (distinct contributing tracks).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

AGGREGATES = ("frames", "count", "duration", "tracks")


@dataclass(frozen=True)
class Region:
    """Spatial predicate: detection center in [x0,x1] x [y0,y1]."""
    x0: float
    y0: float
    x1: float
    y1: float

    def __post_init__(self):
        if self.x1 < self.x0 or self.y1 < self.y0:
            raise ValueError(f"empty region {self}")

    @classmethod
    def full(cls) -> "Region":
        return cls(0.0, 0.0, 1.0, 1.0)

    def intersect(self, other: "Region") -> "Region":
        x0, y0 = max(self.x0, other.x0), max(self.y0, other.y0)
        x1, y1 = min(self.x1, other.x1), min(self.y1, other.y1)
        if x1 < x0 or y1 < y0:      # disjoint: a region matching nothing
            nan = float("nan")
            return Region(nan, nan, nan, nan)
        return Region(x0, y0, x1, y1)


@dataclass(frozen=True)
class TimeRange:
    """Temporal predicate: frame index in [start, end)."""
    start: int = 0
    end: Optional[int] = None

    def __post_init__(self):
        if self.end is not None and self.end < self.start:
            raise ValueError(f"empty time range {self}")


@dataclass(frozen=True)
class TrackFilter:
    """Track-level predicate: length floor + optional pattern classes."""
    min_len: int = 2
    classes: Optional[Tuple[int, ...]] = None


@dataclass(frozen=True)
class CountAtLeast:
    """Frame-level predicate: >= k surviving track points on the frame."""
    k: int = 1

    def __post_init__(self):
        if self.k < 1:
            raise ValueError("CountAtLeast needs k >= 1")


@dataclass(frozen=True)
class Limit:
    """Return at most n frames, >= min_spacing apart within a clip."""
    n: int
    min_spacing: int = 0

    def __post_init__(self):
        if self.n < 1:
            raise ValueError("Limit needs n >= 1")


Op = object     # Region | TimeRange | TrackFilter | CountAtLeast


@dataclass(frozen=True)
class Query:
    """A conjunction of operators + limit + aggregation mode + an
    optional dataset scope."""
    where: Tuple[Op, ...] = field(default_factory=tuple)
    limit: Optional[Limit] = None
    aggregate: str = "frames"
    datasets: Optional[Tuple[str, ...]] = None

    def __post_init__(self):
        if self.aggregate not in AGGREGATES:
            raise ValueError(f"unknown aggregate {self.aggregate!r} "
                             f"(expected one of {AGGREGATES})")
        if self.datasets is not None:
            if isinstance(self.datasets, str):
                raise TypeError("datasets must be a tuple of names, "
                                "not a bare string")
            object.__setattr__(self, "datasets", tuple(self.datasets))
            if not all(isinstance(d, str) for d in self.datasets):
                raise TypeError(f"dataset names must be strings: "
                                f"{self.datasets!r}")
        if self.limit is not None and self.aggregate != "frames":
            # the limit scan early-exits, so a scalar aggregate computed
            # under it would be a silently truncated count
            raise ValueError("limit only composes with "
                             "aggregate='frames'")
        for op in self.where:
            if not isinstance(op, (Region, TimeRange, TrackFilter,
                                   CountAtLeast)):
                raise TypeError(f"unknown operator {op!r}")

    def scoped(self, *datasets: str) -> "Query":
        """A copy of this query restricted to the named datasets."""
        return dataclasses.replace(self, datasets=tuple(datasets))

    # -- convenience constructors ---------------------------------------------

    @classmethod
    def limit_frames(cls, *, region=None, min_count: int = 1,
                     want: int = 10, min_spacing: int = 0,
                     min_track_len: int = 2,
                     time_range: Optional[TimeRange] = None) -> "Query":
        """The paper's Table-2 limit query: ``want`` frames with at
        least ``min_count`` objects inside ``region``."""
        where = [TrackFilter(min_len=min_track_len),
                 CountAtLeast(min_count)]
        if region is not None:
            where.append(Region(*region))
        if time_range is not None:
            where.append(time_range)
        return cls(tuple(where), Limit(want, min_spacing), "frames")

    @classmethod
    def count_frames(cls, *, region=None, min_count: int = 1,
                     min_track_len: int = 2,
                     time_range: Optional[TimeRange] = None) -> "Query":
        """How many frames match the predicate?"""
        q = cls.limit_frames(region=region, min_count=min_count,
                             min_track_len=min_track_len,
                             time_range=time_range)
        return cls(q.where, None, "count")

    @classmethod
    def duration(cls, *, region=None, min_count: int = 1,
                 min_track_len: int = 2) -> "Query":
        """For how many seconds does the predicate hold?"""
        q = cls.limit_frames(region=region, min_count=min_count,
                             min_track_len=min_track_len)
        return cls(q.where, None, "duration")

    @classmethod
    def count_tracks(cls, *, region=None, classes=None,
                     min_track_len: int = 2,
                     time_range: Optional[TimeRange] = None) -> "Query":
        """How many distinct tracks touch the region/time window?"""
        where = [TrackFilter(min_len=min_track_len,
                             classes=None if classes is None
                             else tuple(classes))]
        if region is not None:
            where.append(Region(*region))
        if time_range is not None:
            where.append(time_range)
        return cls(tuple(where), None, "tracks")
