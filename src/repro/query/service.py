"""QueryService: concurrent exploratory queries over a TrackStore.

The service is the subsystem's front door.  Any number of threads may
call ``query`` concurrently; each call

  1. **warms** the clips it needs — cold clips are ingested through the
     store (one ingest at a time; concurrent queries needing the same
     cold clips wait on the ingest lock and then find them warm instead
     of extracting twice);
  2. **scans** the packed track arrays through the compiled plan.

Every result carries a ``QueryStats`` with the latency split into
ingest vs scan time — the exploratory-analytics contract in numbers:
the FIRST query over a cold dataset pays extraction, every later query
pays only the millisecond-scale scan (BENCH_query.json records both).

``prefetch`` starts the ingest on a background daemon thread instead,
so an analyst's warm-up can overlap query formulation.  Queries over
already-materialized clips bypass the ingest lock entirely (their
latency stays millisecond-scale even while a large prefetch is in
flight); a query that still needs a cold clip waits for the in-flight
ingest to finish, then ingests whatever remains missing (the store's
``has`` makes ingest incremental at clip granularity).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.data.video_synth import Clip
from repro.query.ops import Query
from repro.query.plan import QueryResult, compile_query
from repro.query.store import IngestReport, TrackStore


@dataclass
class QueryStats:
    """Per-query latency accounting (seconds, wall clock)."""
    ingest_seconds: float = 0.0     # time spent materializing cold clips
    scan_seconds: float = 0.0       # time spent in the vectorized scan
    ingested_clips: int = 0
    plan: str = ""

    @property
    def total_seconds(self) -> float:
        return self.ingest_seconds + self.scan_seconds


class QueryService:
    """Thread-safe query answering with transparent cold-clip ingest."""

    def __init__(self, store: TrackStore, history: int = 256):
        self.store = store
        self._ingest_lock = threading.Lock()
        self._hist_lock = threading.Lock()
        self._history: Deque[QueryStats] = deque(maxlen=history)

    # -- ingest ---------------------------------------------------------------

    def warm(self, clips: Sequence[Clip],
             log=lambda *_: None) -> IngestReport:
        """Ingest whatever is cold, blocking until the clips are warm.
        Serialized: two queries racing for the same cold clips extract
        them once, not twice.  Fully-warm requests never touch the
        ingest lock, so queries over materialized clips keep their
        millisecond latency while a large background ingest (e.g. a
        ``prefetch`` of another split) is in flight."""
        if all(self.store.has(c) for c in clips):
            return IngestReport(requested=len(clips), cached=len(clips))
        with self._ingest_lock:
            return self.store.ingest(clips, log=log)

    def prefetch(self, clips: Sequence[Clip],
                 log=lambda *_: None) -> threading.Thread:
        """Kick off ``warm`` on a background daemon thread (returned so
        callers can join; queries never need to — they warm whatever
        the prefetch has not covered yet)."""
        th = threading.Thread(target=self.warm, args=(list(clips),),
                              kwargs={"log": log}, daemon=True,
                              name="trackstore-ingest")
        th.start()
        return th

    # -- queries --------------------------------------------------------------

    def query(self, q: Query, clips: Sequence[Clip],
              log=lambda *_: None) -> QueryResult:
        """Answer ``q`` over ``clips`` (scan order = list order)."""
        stats = QueryStats()
        plan = compile_query(q)
        stats.plan = plan.describe()
        t0 = time.perf_counter()
        report = self.warm(clips, log=log)
        stats.ingest_seconds = time.perf_counter() - t0
        stats.ingested_clips = report.ingested
        t0 = time.perf_counter()
        entries = [(clip, self.store.get(clip)) for clip in clips]
        missing = [i for i, (_, p) in enumerate(entries) if p is None]
        if missing:                  # ingest raced a set_params; be loud
            raise RuntimeError(f"clips {missing} cold after ingest "
                               f"(θ changed mid-query?)")
        result = plan.run(entries)
        stats.scan_seconds = time.perf_counter() - t0
        result.stats = stats
        with self._hist_lock:
            self._history.append(stats)
        log(f"[query] {stats.plan}: ingest={stats.ingest_seconds:.3f}s "
            f"({stats.ingested_clips} clips) "
            f"scan={stats.scan_seconds * 1e3:.2f}ms")
        return result

    # -- reporting ------------------------------------------------------------

    def latency_report(self) -> Dict[str, float]:
        """Aggregate ingest/scan split over the recorded history."""
        with self._hist_lock:
            hist: List[QueryStats] = list(self._history)
        if not hist:
            return {"queries": 0}
        scans = sorted(s.scan_seconds for s in hist)
        mid = len(scans) // 2
        return {
            "queries": len(hist),
            "ingest_seconds_total": sum(s.ingest_seconds for s in hist),
            "scan_seconds_total": sum(s.scan_seconds for s in hist),
            "scan_seconds_median": scans[mid],
            "warm_queries": sum(1 for s in hist
                                if s.ingested_clips == 0),
        }
