"""QueryService: concurrent exploratory queries over one or MANY
TrackStores.

The service is the subsystem's front door.  It fronts either a single
``TrackStore`` or a mapping ``{dataset_name: TrackStore}`` — a query's
clips are routed to the store owning their dataset (``profile.name``),
results are merged back in the caller's scan order, and
``Query.datasets`` optionally scopes a query to a subset of datasets
(clips outside the scope are dropped before the scan; surviving frames
keep their indices into the caller's clip list).

Any number of threads may call ``query`` concurrently; each call

  1. **consults the index** — each clip's persisted ``ClipSummary``
     (which survives eviction) is tested against the compiled plan;
     clips the summary proves irrelevant are neither warmed nor
     scanned, so a selective query over a partially-evicted store
     re-ingests nothing it does not actually need;
  2. **warms** the clips it still needs — cold clips are ingested
     through their store (one ingest at a time; concurrent queries
     needing the same cold clips wait on the ingest lock and then find
     them warm instead of extracting twice);
  3. **scans** the packed track arrays through the compiled plan
     (two-phase: histogram answers when the predicate is indexed, row
     scan otherwise — see ``repro.query.plan``).

Every result carries a ``QueryStats`` with the latency split into
ingest vs scan time — the exploratory-analytics contract in numbers:
the FIRST query over a cold dataset pays extraction, every later query
pays only the millisecond-scale scan (BENCH_query.json records both).

``prefetch`` starts the ingest on a background daemon thread instead,
so an analyst's warm-up can overlap query formulation.  Queries over
already-materialized clips bypass the ingest lock entirely (their
latency stays millisecond-scale even while a large prefetch is in
flight); a query that still needs a cold clip waits for the in-flight
ingest to finish, then ingests whatever remains missing (the store's
``has`` makes ingest incremental at clip granularity).  With a query
(``prefetch(clips, q=...)``), the warm-up order is summary-aware:
never-materialized clips first, then clips the plan cannot skip by
descending predicted scan cost, summary-skippable clips last.

The service is also the subscription hub for LIVE streams
(``repro.stream``): ``register_standing`` attaches a ``StandingQuery``
(bootstrapped against whatever is already materialized), and the
segment ingestor's ``notify_append`` fans each watermark advance out
to every subscriber, which folds the delta incrementally instead of
re-running the query.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.data.video_synth import Clip
from repro.obs.metrics import REGISTRY
from repro.obs.recorder import crash_dump
from repro.obs.trace import TRACER
from repro.query.ops import Query
from repro.query.plan import CompiledPlan, QueryResult, compile_query
from repro.query.store import IngestReport, TrackStore

# A query whose working set was evicted mid-flight (θ swap or a budget
# smaller than the set) retries warm→get this many times before
# failing loudly instead of livelocking.
_WARM_ATTEMPTS = 3


@dataclass
class QueryStats:
    """Per-query latency accounting (seconds, wall clock) plus the
    plan-phase clip counters ``plan.run`` computes."""
    ingest_seconds: float = 0.0     # time spent materializing cold clips
    scan_seconds: float = 0.0       # time spent in the vectorized scan
    ingested_clips: int = 0
    plan: str = ""
    # plan-phase disposition of this query's clips (QueryResult
    # pass-throughs): summary-skipped, answered from the histogram
    # index, row-scanned, and the selected total
    skipped_clips: int = 0
    indexed_clips: int = 0
    scanned_clips: int = 0
    n_clips: int = 0
    # datasets this query actually touched, "+"-joined sorted names
    # (the latency_report per-dataset breakdown groups on it)
    datasets: str = ""

    @property
    def total_seconds(self) -> float:
        return self.ingest_seconds + self.scan_seconds


def summarize_latency(hist: Sequence["QueryStats"]) -> Dict[str, object]:
    """Aggregate a list of ``QueryStats`` into the ``latency_report``
    dict: the flat keys are unchanged from before the per-dataset
    breakdown (bit-compatible), the new clip-counter totals expose what
    ``plan.run`` always computed, and ``datasets`` groups queries by
    the datasets they touched.  Pure — tested directly."""
    if not hist:
        return {"queries": 0}

    def block(group: Sequence[QueryStats]) -> Dict[str, float]:
        scans = np.asarray(sorted(s.scan_seconds for s in group))
        return {
            "queries": len(group),
            "ingest_seconds_total": sum(s.ingest_seconds
                                        for s in group),
            "scan_seconds_total": sum(s.scan_seconds for s in group),
            "scan_seconds_median": float(np.median(scans)),
            "scan_seconds_p95": float(np.percentile(scans, 95)),
            "warm_queries": sum(1 for s in group
                                if s.ingested_clips == 0),
        }

    out: Dict[str, object] = block(hist)
    out["clips_skipped_total"] = sum(s.skipped_clips for s in hist)
    out["clips_indexed_total"] = sum(s.indexed_clips for s in hist)
    out["clips_scanned_total"] = sum(s.scanned_clips for s in hist)
    out["clips_total"] = sum(s.n_clips for s in hist)
    by: Dict[str, List[QueryStats]] = {}
    for s in hist:
        by.setdefault(s.datasets or "(none)", []).append(s)
    out["datasets"] = {name: block(group)
                       for name, group in sorted(by.items())}
    return out


class QueryService:
    """Thread-safe query answering with transparent cold-clip ingest,
    over one store or a ``{dataset: store}`` mapping."""

    def __init__(self, stores, history: int = 256):
        if isinstance(stores, TrackStore):
            self.stores: Dict[str, TrackStore] = {}
            self.default_store: Optional[TrackStore] = stores
        elif isinstance(stores, Mapping):
            self.stores = dict(stores)
            self.default_store = None
        else:
            raise TypeError(f"stores must be a TrackStore or a mapping "
                            f"of dataset name to TrackStore, got "
                            f"{type(stores).__name__}")
        self._ingest_lock = threading.Lock()
        self._hist_lock = threading.Lock()
        self._history: Deque[QueryStats] = deque(maxlen=history)  # guarded-by: _hist_lock
        self._standing_lock = threading.Lock()
        self._standing: List["StandingQuery"] = []  # guarded-by: _standing_lock

    @property
    def store(self) -> TrackStore:
        """Back-compat single-store accessor."""
        if self.default_store is not None:
            return self.default_store
        if len(self.stores) == 1:
            return next(iter(self.stores.values()))
        raise AttributeError("service fronts multiple stores; use "
                             "store_for(clip) or .stores")

    def store_for(self, clip: Clip) -> TrackStore:
        """The store owning the clip's dataset."""
        st = self.stores.get(clip.profile.name, self.default_store)
        if st is None:
            raise KeyError(f"no store for dataset "
                           f"{clip.profile.name!r} (have "
                           f"{sorted(self.stores)})")
        return st

    # -- ingest ---------------------------------------------------------------

    def warm(self, clips: Sequence[Clip],
             log=lambda *_: None) -> IngestReport:
        """Ingest whatever is cold, blocking until the clips are warm.
        Serialized: two queries racing for the same cold clips extract
        them once, not twice.  Fully-warm requests never touch the
        ingest lock, so queries over materialized clips keep their
        millisecond latency while a large background ingest (e.g. a
        ``prefetch`` of another split) is in flight."""
        total = IngestReport(requested=len(clips))
        # ONE group per store (keyed by identity, per-store clip order
        # preserved): each store ingests its whole share as a single
        # batch, keeping cross-clip decode prefetch and the
        # batch-protected eviction semantics even for interleaved
        # multi-dataset clip lists
        groups: Dict[int, Tuple[TrackStore, List[Clip]]] = {}
        for clip in clips:
            st = self.store_for(clip)
            groups.setdefault(id(st), (st, []))[1].append(clip)
        cold_groups = []
        for st, cs in groups.values():
            if all(st.has(c) for c in cs):
                total.cached += len(cs)
            else:
                cold_groups.append((st, cs))
        if not cold_groups:
            return total
        with self._ingest_lock:
            for st, cs in cold_groups:
                r = st.ingest(cs, log=log)
                total.ingested += r.ingested
                total.cached += r.cached
                total.frames += r.frames
                total.seconds += r.seconds
                total.wall_seconds += r.wall_seconds
                total.evicted += r.evicted
                total.evicted_bytes += r.evicted_bytes
                # store_bytes is a per-store snapshot, not a delta:
                # one batch per store makes summing them correct
                total.store_bytes += r.store_bytes
        return total

    def _prefetch_order(self, clips: Sequence[Clip],
                        plan) -> List[Clip]:
        """Summary-aware warm-up order for ``prefetch``:

          1. clips with NO summary first (never materialized — they
             must be extracted, and nothing can predict their cost);
          2. then clips the plan cannot skip, largest predicted scan
             cost first (``summary.n_rows`` — the row scan is O(rows),
             so big clips warming early shortens the worst query);
          3. summary-skippable clips last (the plan will never touch
             them; they only matter to ``use_index=False`` baselines).

        Within a tier the caller's order is kept (stable sort)."""
        def tier(clip: Clip) -> tuple:
            try:
                summary = self.store_for(clip).summary(clip)
            except KeyError:
                summary = None
            if summary is None:
                return (0, 0)
            if plan is not None and plan.can_skip(summary):
                return (2, -summary.n_rows)
            return (1, -summary.n_rows)
        return sorted(clips, key=tier)

    def prefetch(self, clips: Sequence[Clip],
                 q: Optional[Query] = None,
                 log=lambda *_: None) -> threading.Thread:
        """Kick off ``warm`` on a background daemon thread (returned so
        callers can join; queries never need to — they warm whatever
        the prefetch has not covered yet).  With ``q``, clips warm in
        summary-aware order: unskippable clips first, largest predicted
        scan cost first, so the query that prompted the prefetch gets
        its working set earliest."""
        plan = compile_query(q) if q is not None else None
        ordered = self._prefetch_order(clips, plan)
        th = threading.Thread(target=self.warm, args=(ordered,),
                              kwargs={"log": log}, daemon=True,
                              name="trackstore-ingest")
        th.start()
        return th

    # -- standing queries (live ingestion, repro.stream) ----------------------

    def register_standing(self, sq) -> object:
        """Subscribe a ``repro.stream.StandingQuery``: it first catches
        up on already-materialized data (``bootstrap``), then receives
        every watermark advance via ``notify_append``.  Returns the
        query for chaining.

        Bootstrap and subscription happen under the SAME lock that
        serializes delta delivery — an append landing while a query
        registers is therefore seen exactly once, either by the
        bootstrap's store read or as a delivered delta, never neither
        (a delta that fell in the gap would be unrecoverable: later
        deltas only carry later rows)."""
        with self._standing_lock:
            sq.bootstrap(self)
            self._standing.append(sq)
        return sq

    def unregister_standing(self, sq) -> None:
        with self._standing_lock:
            if sq in self._standing:
                self._standing.remove(sq)

    def notify_append(self, clip: Clip, packed, delta) -> List[object]:
        """Fan one watermark advance out to every standing query
        (called by ``SegmentIngestor.append``).  Delivery holds the
        subscription lock — see ``register_standing``.  Returns the
        non-None standing deltas."""
        out = []
        with self._standing_lock:
            for sq in self._standing:
                d = sq.on_append(clip, packed, delta)
                if d is not None:
                    out.append(d)
        return out

    # -- queries --------------------------------------------------------------

    def _gather(self, plan: CompiledPlan,
                selected: Sequence[Tuple[int, Clip]], use_index: bool,
                stats: "QueryStats", log) -> List[tuple]:
        """Warm (index-aware) and collect (clip, packed, summary)
        entries for the scan.  Summaries (and the skip decisions made
        from them) are re-read on every attempt, and an attempt only
        counts as successful if no store's θ-fingerprint moved while it
        ran — a set_params racing the query can therefore trigger a
        retry but never a silently mixed-θ answer.  Retries when
        eviction or a θ swap races the warm-up; raises after
        ``_WARM_ATTEMPTS``."""
        def skippable(s):
            return use_index and plan.can_skip(s)

        for _ in range(_WARM_ATTEMPTS):
            stores = {id(self.store_for(c)): self.store_for(c)
                      for _, c in selected}
            fps = {sid: st.fingerprint for sid, st in stores.items()}
            summaries = [self.store_for(c).summary(c)
                         for _, c in selected]
            need = [c for (_, c), s in zip(selected, summaries)
                    if not skippable(s)]
            report = self.warm(need, log=log)
            stats.ingested_clips += report.ingested
            entries, missing = [], []
            for (_, c), s in zip(selected, summaries):
                packed = None
                if not skippable(s):
                    packed = self.store_for(c).get(c)
                    if packed is None:
                        missing.append(c)
                entries.append((c, packed, s))
            stable = all(st.fingerprint == fps[sid]
                         for sid, st in stores.items())
            if not missing and stable:
                return entries
        raise RuntimeError(
            f"clips still cold after {_WARM_ATTEMPTS} warm attempts "
            f"(θ kept changing mid-query, or the store budget is too "
            f"small for this query's working set)")

    def query(self, q: Query, clips: Sequence[Clip],
              log=lambda *_: None, use_index: bool = True) -> QueryResult:
        """Answer ``q`` over ``clips`` (scan order = list order;
        ``q.datasets`` drops out-of-scope clips first).  Frame indices
        in the result refer to positions in ``clips``.
        ``use_index=False`` forces the full row scan — the differential
        baseline the indexed path is tested against."""
        if TRACER.enabled:
            with TRACER.span("query.run", "query") as sp:
                result = self._query(q, clips, log, use_index)
                st = result.stats
                sp.args = {"plan": st.plan, "datasets": st.datasets,
                           "ingested": st.ingested_clips,
                           "skipped": st.skipped_clips,
                           "indexed": st.indexed_clips,
                           "scanned": st.scanned_clips}
                return result
        return self._query(q, clips, log, use_index)

    def _query(self, q: Query, clips: Sequence[Clip], log,
               use_index: bool) -> QueryResult:
        try:
            return self._query_inner(q, clips, log, use_index)
        except BaseException as exc:
            REGISTRY.counter("query.errors").inc()
            # black box: no-op unless a FlightRecorder is installed
            crash_dump("query.run", exc)
            raise

    def _query_inner(self, q: Query, clips: Sequence[Clip], log,
                     use_index: bool) -> QueryResult:
        stats = QueryStats()
        plan = compile_query(q)
        stats.plan = plan.describe()
        selected = [(i, c) for i, c in enumerate(clips)
                    if q.datasets is None
                    or c.profile.name in q.datasets]
        stats.datasets = "+".join(
            sorted({c.profile.name for _, c in selected}))
        t0 = time.perf_counter()
        entries = self._gather(plan, selected, use_index, stats, log)
        stats.ingest_seconds = time.perf_counter() - t0
        t0 = time.perf_counter()
        result = plan.run(entries, use_index=use_index)
        # plan indices are positions in `selected`; map back to `clips`
        result.frames = [(selected[j][0], f) for j, f in result.frames]
        stats.scan_seconds = time.perf_counter() - t0
        stats.skipped_clips = result.skipped_clips
        stats.indexed_clips = result.indexed_clips
        stats.scanned_clips = result.scanned_clips
        stats.n_clips = result.n_clips
        result.stats = stats
        with self._hist_lock:
            self._history.append(stats)
        REGISTRY.counter("query.count").inc()
        REGISTRY.histogram("query.scan_seconds").observe(
            stats.scan_seconds)
        REGISTRY.histogram("query.ingest_seconds").observe(
            stats.ingest_seconds)
        REGISTRY.counter("query.clips.skipped").inc(stats.skipped_clips)
        REGISTRY.counter("query.clips.indexed").inc(stats.indexed_clips)
        REGISTRY.counter("query.clips.scanned").inc(stats.scanned_clips)
        log(f"[query] {stats.plan}: ingest={stats.ingest_seconds:.3f}s "
            f"({stats.ingested_clips} clips) "
            f"scan={stats.scan_seconds * 1e3:.2f}ms "
            f"(skipped {result.skipped_clips}, indexed "
            f"{result.indexed_clips} of {result.n_clips})")
        return result

    # -- reporting ------------------------------------------------------------

    def latency_report(self) -> Dict[str, object]:
        """Aggregate ingest/scan split over the recorded history
        (``summarize_latency``): the flat keys of the original report,
        plus the plan-phase clip-counter totals and a per-dataset
        breakdown keyed by the datasets each query touched.  Median and
        p95 use linear interpolation (an even-length history averages
        the two middle scans rather than reporting the upper one)."""
        with self._hist_lock:
            hist: List[QueryStats] = list(self._history)
        return summarize_latency(hist)
