"""Reference implementations for differential testing of the query
plan (the kernels' ``ref.py`` idiom, applied to the query subsystem).

``reference_limit_scan`` is the original inline limit-query loop from
the pre-store ``experiment.limit_query_experiment`` — per-track Python,
dict-of-counts per frame — kept verbatim as the single source of truth
for what the compiled vectorized plan must reproduce.  Both
tests/test_query.py and benchmarks/query_bench.py assert against it.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np


def reference_limit_scan(all_tracks: Sequence[Sequence[np.ndarray]],
                         want: int, min_count: int, region,
                         spacing: int) -> List[Tuple[int, int]]:
    """Find ``want`` (clip, frame) pairs with >= ``min_count`` track
    points inside ``region`` (x0, y0, x1, y1; bounds inclusive),
    >= ``spacing`` frames apart within a clip; single-detection stub
    tracks are ignored (§4.2)."""
    found: List[Tuple[int, int]] = []
    for ci, tracks in enumerate(all_tracks):
        per_frame: Dict[int, int] = {}
        for tr in tracks:
            if len(tr) < 2:
                continue
            for row in tr:
                cx, cy = row[1], row[2]
                if region[0] <= cx <= region[2] \
                        and region[1] <= cy <= region[3]:
                    per_frame[int(row[0])] = per_frame.get(
                        int(row[0]), 0) + 1
        for f, n in sorted(per_frame.items()):
            if n >= min_count and len(found) < want and not any(
                    c == ci and abs(f - g) < spacing for c, g in found):
                found.append((ci, f))
    return found
