"""Reference implementations for differential testing of the query
plan (the kernels' ``ref.py`` idiom, applied to the query subsystem).

``reference_limit_scan`` is the original inline limit-query loop from
the pre-store ``experiment.limit_query_experiment`` — per-track Python,
dict-of-counts per frame — kept verbatim as the single source of truth
for what the compiled vectorized plan must reproduce.  Both
tests/test_query.py and benchmarks/query_bench.py assert against it.

``reference_query`` generalizes the same naive per-track/dict-of-counts
style to the full operator algebra (region × time × min_len × count ×
limit × every aggregate) so the two-phase indexed plan can be
differentially tested against an implementation that shares NO code
with it (tests/test_query_index.py): indexed answer == full-scan
answer == this inline loop, bit for bit.  Class filters are the one
operator not covered here (classification needs the clip profile);
they are tested indexed-vs-scan instead.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def reference_limit_scan(all_tracks: Sequence[Sequence[np.ndarray]],
                         want: int, min_count: int, region,
                         spacing: int) -> List[Tuple[int, int]]:
    """Find ``want`` (clip, frame) pairs with >= ``min_count`` track
    points inside ``region`` (x0, y0, x1, y1; bounds inclusive),
    >= ``spacing`` frames apart within a clip; single-detection stub
    tracks are ignored (§4.2)."""
    found: List[Tuple[int, int]] = []
    for ci, tracks in enumerate(all_tracks):
        per_frame: Dict[int, int] = {}
        for tr in tracks:
            if len(tr) < 2:
                continue
            for row in tr:
                cx, cy = row[1], row[2]
                if region[0] <= cx <= region[2] \
                        and region[1] <= cy <= region[3]:
                    per_frame[int(row[0])] = per_frame.get(
                        int(row[0]), 0) + 1
        for f, n in sorted(per_frame.items()):
            if n >= min_count and len(found) < want and not any(
                    c == ci and abs(f - g) < spacing for c, g in found):
                found.append((ci, f))
    return found


def reference_query(all_tracks: Sequence[Sequence[np.ndarray]],
                    fps: Sequence[int], *,
                    region=None,
                    time_range: Optional[Tuple[int, Optional[int]]] = None,
                    min_len: int = 1, min_count: int = 1,
                    limit: Optional[Tuple[int, int]] = None,
                    aggregate: str = "frames") -> dict:
    """The full query algebra as naive per-track Python: the oracle the
    compiled plan (indexed or not) must match exactly.

    ``region`` is (x0, y0, x1, y1) inclusive; ``time_range`` is
    (start, end) with end exclusive or None; ``limit`` is
    (want, min_spacing).  Returns ``{"frames": [(clip, frame), ...],
    "aggregates": {...}}`` shaped like ``plan.QueryResult``.
    """
    frames: List[Tuple[int, int]] = []
    n_match = 0
    seconds = 0.0
    total_tracks = 0
    for ci, tracks in enumerate(all_tracks):
        if limit is not None and len(frames) >= limit[0]:
            break
        per_frame: Dict[int, int] = {}
        clip_tracks = 0
        for tr in tracks:
            if len(tr) < min_len:
                continue
            touched = False
            for row in tr:
                f, cx, cy = int(row[0]), row[1], row[2]
                if region is not None and not (
                        region[0] <= cx <= region[2]
                        and region[1] <= cy <= region[3]):
                    continue
                if time_range is not None:
                    start, end = time_range
                    if f < start or (end is not None and f >= end):
                        continue
                touched = True
                per_frame[f] = per_frame.get(f, 0) + 1
            if touched:
                clip_tracks += 1
        total_tracks += clip_tracks
        hits = [f for f, n in sorted(per_frame.items())
                if n >= min_count]
        n_match += len(hits)
        seconds += len(hits) / max(fps[ci], 1)
        if limit is None:
            if aggregate == "frames":
                frames.extend((ci, f) for f in hits)
            continue
        picked: List[int] = []
        for f in hits:
            if len(frames) >= limit[0]:
                break
            if all(abs(f - g) >= limit[1] for g in picked):
                frames.append((ci, f))
                picked.append(f)
    aggregates: Dict[str, float] = {}
    if aggregate == "tracks":
        aggregates["tracks"] = total_tracks
    elif limit is None:
        aggregates["count"] = n_match
        aggregates["duration_seconds"] = seconds
    if aggregate in ("count", "duration"):
        frames = []
    return {"frames": frames, "aggregates": aggregates}
