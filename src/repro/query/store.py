"""TrackStore: materialize pre-processed tracks once, serve them forever.

The store persists ``executor.run_clips`` outputs keyed by
``(dataset, clip, θ-fingerprint)``:

  * **θ-fingerprint** — a hash of the TRACK-RELEVANT fields of
    ``PipelineParams``.  Scheduling-only knobs (``chunk_size``) are
    excluded: tracks are bit-identical across chunk sizes by
    construction (tests/test_executor.py), so re-tuning B must not
    invalidate materialized tracks.  Any change to a field that can
    change tracks (arch, resolution, confidence, gap, proxy, tracker,
    refine) yields a new fingerprint, i.e. a new store version; stale
    versions stay on disk until ``prune()``.
  * **Layout** — one NPZ per clip under
    ``root/<dataset>/<fingerprint>/<split>_<clip>_<frames>.npz`` holding
    the packed track arrays, the run's cost counters, and the clip's
    secondary index (count histograms + per-track bboxes,
    ``repro.query.index``); one ``meta.json`` per fingerprint directory
    describing θ; and one ``index.json`` per fingerprint directory with
    every clip's ``ClipSummary`` + byte size + last-used time.  The
    summaries survive eviction of their NPZ, so the planner can still
    prove an evicted clip irrelevant without re-ingesting it.
  * **Packed representation** — all of a clip's tracks concatenated
    into one ``(N, 6)`` row array ``[frame, cx, cy, w, h, track_id]``
    with an offsets array delimiting tracks.  Query plans
    (``repro.query.plan``) scan these packed arrays with vectorized
    numpy ops; nothing at query time is per-track Python.
  * **Incremental ingest** — ``ingest(clips)`` materializes only the
    clips missing from the current version, streaming them through the
    executor with cross-clip decode prefetch (``executor.run_clips``).
    A fully-materialized split re-ingests with ZERO detector calls and
    zero decodes (asserted by tests/test_query.py).
  * **Bounded size** — an optional ``StoreBudget(max_bytes,
    ttl_seconds)`` caps the version's disk footprint: after each ingest
    (and on ``set_budget``) the least-recently-used clip NPZs are
    evicted from memory AND disk until the budget holds.  Evicted clips
    stay summarized in ``index.json`` and re-ingest transparently on
    the next touch (tracks are deterministic per fingerprint, so the
    re-extracted data — and its index — are identical).

The store itself is thread-safe (one lock around the in-memory index
and disk writes); ``QueryService`` layers concurrent query execution
and transparent cold-clip ingest on top.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.executor import ExecutorOptions, run_clips
from repro.core.pipeline import ModelBank, PipelineParams, RunResult
from repro.data.video_synth import Clip
from repro.query.index import (MIN_LEN_BUCKETS, ClipSummary, build_index,
                               summarize)

SCHEMA_VERSION = 1

# PipelineParams fields that CANNOT change extracted tracks (pure
# scheduling knobs; see module docstring).  A denylist, so any field
# added to θ later is track-relevant — and store-invalidating — by
# default; a new scheduling-only knob must opt in here explicitly.
_SCHEDULING_ONLY = ("chunk_size",)

ClipKey = Tuple[str, str, int, int]     # (dataset, split, clip_id, n_frames)


def _track_fields(params: PipelineParams) -> Dict[str, object]:
    return {f.name: getattr(params, f.name)
            for f in dataclasses.fields(params)
            if f.name not in _SCHEDULING_ONLY}


def theta_fingerprint(params: PipelineParams) -> str:
    """Stable hex fingerprint of θ's track-relevant fields."""
    payload = _track_fields(params)
    payload["schema"] = SCHEMA_VERSION
    blob = json.dumps(payload, sort_keys=True, default=list)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def clip_key(clip: Clip) -> ClipKey:
    return (clip.profile.name, clip.split, clip.clip_id, clip.n_frames)


def _clip_name(key: ClipKey) -> str:
    _, split, clip_id, n_frames = key
    return f"{split}_{clip_id}_{n_frames}"


@dataclass
class PackedTracks:
    """One clip's tracks as packed numpy arrays (the query-scan format).

    ``rows``    — (N, 6) ``[frame, cx, cy, w, h, track_id]``, all tracks
                  concatenated in track order;
    ``offsets`` — (T+1,) int64; track i is ``rows[offsets[i]:offsets[i+1]]``.

    ``hist`` / ``track_bbox`` are the clip's secondary index
    (``repro.query.index.build_index``), built at pack time, persisted
    in the NPZ, and rebuilt lazily for arrays packed elsewhere.

    Derived arrays used by every plan (row→track map, per-track lengths)
    are computed once and cached; per-track pattern classification is
    computed lazily on first class-filtered query (it needs the clip's
    profile, so it cannot be precomputed dataset-independently).
    """
    rows: np.ndarray
    offsets: np.ndarray
    n_frames: int
    fps: int
    seconds: float = 0.0                    # extraction cost (RunResult)
    counters: Tuple[int, ...] = ()          # RunResult counter snapshot
    hist: Optional[np.ndarray] = field(default=None, repr=False)
    track_bbox: Optional[np.ndarray] = field(default=None, repr=False)
    # OPEN-clip marker (live ingestion, ``repro.stream``): frames
    # [0, watermark) have been appended and extracted; None for sealed
    # clips.  ``n_frames`` equals the watermark while open, so every
    # frame-indexed structure (hist width, bincount minlength) covers
    # exactly the ingested prefix and grows monotonically per append.
    watermark: Optional[int] = None
    _summary: Optional[ClipSummary] = field(default=None, repr=False)
    _row_track: Optional[np.ndarray] = field(default=None, repr=False)
    _classes: Optional[np.ndarray] = field(default=None, repr=False)

    @property
    def n_tracks(self) -> int:
        return len(self.offsets) - 1

    @property
    def lengths(self) -> np.ndarray:
        return np.diff(self.offsets)

    @property
    def row_track(self) -> np.ndarray:
        """(N,) track index of every row."""
        if self._row_track is None:
            self._row_track = np.repeat(
                np.arange(self.n_tracks, dtype=np.int64), self.lengths)
        return self._row_track

    def build_index_arrays(self) -> None:
        """Ensure ``hist``/``track_bbox`` exist (idempotent)."""
        if self.hist is None or self.track_bbox is None:
            self.hist, self.track_bbox = build_index(
                self.rows, self.offsets, self.n_frames)

    @property
    def summary(self) -> ClipSummary:
        """The clip's scalar index digest (built on first use)."""
        if self._summary is None:
            self.build_index_arrays()
            self._summary = summarize(self.rows, self.offsets,
                                      self.hist, self.track_bbox)
        return self._summary

    def track(self, i: int) -> np.ndarray:
        return self.rows[self.offsets[i]:self.offsets[i + 1]]

    def tracks(self) -> List[np.ndarray]:
        return [self.track(i) for i in range(self.n_tracks)]

    def classes(self, profile) -> np.ndarray:
        """(T,) per-track pattern id (``metrics.classify_track``), -1
        for unclassifiable stubs.  Cached after the first call."""
        if self._classes is None:
            from repro.core.metrics import classify_track
            out = np.full(self.n_tracks, -1, np.int64)
            for i in range(self.n_tracks):
                c = classify_track(self.track(i), profile)
                if c is not None:
                    out[i] = c
            self._classes = out
        return self._classes

    @classmethod
    def pack(cls, tracks: Sequence[np.ndarray], clip: Clip,
             result: Optional[RunResult] = None,
             n_frames: Optional[int] = None,
             build: bool = True) -> "PackedTracks":
        """``n_frames`` overrides the frame span (the stream path packs
        an open clip at its watermark); ``build=False`` skips the index
        rebuild so an incrementally merged index can be attached
        instead (``repro.stream.state``)."""
        offsets = np.zeros(len(tracks) + 1, np.int64)
        parts = []
        for i, t in enumerate(tracks):
            offsets[i + 1] = offsets[i] + len(t)
            parts.append(np.asarray(t, np.float32).reshape(len(t), 6))
        rows = np.concatenate(parts) if parts \
            else np.zeros((0, 6), np.float32)
        counters = () if result is None else (
            result.frames_processed, result.detector_windows,
            result.full_frames, result.skipped_frames)
        seconds = 0.0 if result is None else float(result.seconds)
        span = clip.n_frames if n_frames is None else int(n_frames)
        packed = cls(rows, offsets, span, clip.profile.fps,
                     seconds, counters)
        if build:
            packed.build_index_arrays()
        return packed


@dataclass
class StoreBudget:
    """Size/age bound on one store version's materialized clips.

    ``max_bytes``   — evict least-recently-used clip NPZs until the
                      version's disk footprint is at or under the cap;
    ``ttl_seconds`` — evict clips not touched for this long.

    Enforcement runs at the end of every ``ingest`` and on
    ``set_budget``; the clips of the in-flight ingest batch are never
    evicted by their own ingest (so a query's working set becomes fully
    warm before LRU pressure applies), which means a single batch
    larger than ``max_bytes`` leaves the store above budget until a
    later enforcement — size your budget to hold one query's working
    set.  Eviction is metadata-preserving: the clip's summary stays in
    ``index.json`` for index-based skipping, and the next touch
    re-ingests bit-identical data.
    """
    max_bytes: Optional[int] = None
    ttl_seconds: Optional[float] = None


@dataclass
class IngestReport:
    """What one ``ingest`` call actually did."""
    requested: int = 0          # clips asked for
    ingested: int = 0           # clips that ran through the executor
    cached: int = 0             # clips already materialized
    frames: int = 0             # frames processed during this ingest
    seconds: float = 0.0        # summed RunResult.seconds (cost model)
    wall_seconds: float = 0.0   # wall clock of the executor sweep
    evicted: int = 0            # clips evicted by budget enforcement
    evicted_bytes: int = 0      # bytes freed by those evictions
    store_bytes: int = 0        # version disk footprint after ingest

    @property
    def fps(self) -> float:
        return self.frames / self.wall_seconds if self.wall_seconds > 0 \
            else 0.0


class TrackStore:
    """Persistent, versioned store of extracted tracks for one θ.

    ``set_params`` re-points the store at a different θ version: the
    in-memory index is invalidated and subsequent lookups hit the new
    fingerprint's directory (cold until re-ingested).  All public
    methods are thread-safe.
    """

    def __init__(self, root: str, bank: Optional[ModelBank],
                 params: PipelineParams,
                 options: Optional[ExecutorOptions] = None,
                 budget: Optional[StoreBudget] = None):
        self.root = root
        self.bank = bank
        self.options = options
        # guarded-by: _lock
        self.budget = budget
        self._lock = threading.RLock()
        self._index: Dict[ClipKey, PackedTracks] = {}   # guarded-by: _lock
        # per-clip index.json entries for the CURRENT fingerprint:
        # {"summary": ClipSummary, "bytes": int, "last_used": float,
        #  "present": bool}; populated lazily per dataset directory
        self._entries: Dict[ClipKey, dict] = {}     # guarded-by: _lock
        self._loaded_datasets: Set[str] = set()     # guarded-by: _lock
        self.evictions = 0              # guarded-by: _lock (lifetime counters)
        self.evicted_bytes = 0          # guarded-by: _lock
        from repro.obs.metrics import REGISTRY
        self._m_evictions = REGISTRY.counter("store.evictions")
        self._m_evicted_bytes = REGISTRY.counter("store.evicted_bytes")
        # /healthz store_budget inputs: present-bytes over budget-bytes
        # (budget gauge stays 0 for unbudgeted stores -> "no data")
        self._m_bytes = REGISTRY.gauge("store.bytes")
        self._m_budget_bytes = REGISTRY.gauge("store.budget_bytes")
        if budget is not None and budget.max_bytes is not None:
            self._m_budget_bytes.set(budget.max_bytes)
        self.params: Optional[PipelineParams] = None    # guarded-by: _lock
        self.fingerprint: Optional[str] = None      # guarded-by: _lock
        self.set_params(params)

    # -- versioning -----------------------------------------------------------

    def set_params(self, params: PipelineParams) -> None:
        """Point the store at θ; a changed fingerprint invalidates the
        in-memory index (disk versions are kept until ``prune``)."""
        fp = theta_fingerprint(params)
        with self._lock:
            if fp != self.fingerprint:
                self._index.clear()
                self._entries.clear()
                self._loaded_datasets.clear()
            self.params = params
            self.fingerprint = fp

    def prune(self) -> List[str]:
        """Delete on-disk versions whose fingerprint is not current.
        Returns the removed fingerprints.  Tolerates nested content
        inside version dirs and concurrent deletion."""
        removed = []
        with self._lock:
            try:
                datasets = os.listdir(self.root)
            except FileNotFoundError:
                return removed
            for dataset in datasets:
                dpath = os.path.join(self.root, dataset)
                if not os.path.isdir(dpath):
                    continue
                try:
                    versions = os.listdir(dpath)
                except FileNotFoundError:
                    continue            # dataset dir vanished under us
                for fp in versions:
                    if fp == self.fingerprint:
                        continue
                    vdir = os.path.join(dpath, fp)
                    if not os.path.isdir(vdir):
                        continue
                    shutil.rmtree(vdir, ignore_errors=True)
                    if not os.path.isdir(vdir):     # actually gone
                        removed.append(fp)
        return removed

    # -- budget / eviction ----------------------------------------------------

    def set_budget(self, budget: Optional[StoreBudget]) -> int:
        """Install (or clear) the budget and enforce it immediately.
        Returns the number of clips evicted by this call."""
        with self._lock:
            self.budget = budget
            self._m_budget_bytes.set(
                budget.max_bytes
                if budget is not None and budget.max_bytes is not None
                else 0)
            return self._enforce_budget()

    def disk_bytes(self) -> int:
        """Disk footprint of the current version's PRESENT clips, over
        every dataset directory under the root."""
        with self._lock:
            self._load_all_datasets()
            return sum(e["bytes"] for e in self._entries.values()
                       if e["present"])

    # holds-lock: _lock
    def _load_all_datasets(self) -> None:
        try:
            names = os.listdir(self.root)
        except FileNotFoundError:
            return
        for dataset in names:
            if os.path.isdir(os.path.join(self.root, dataset)):
                self._ensure_loaded(dataset)

    # holds-lock: _lock
    def _enforce_budget(self, protect: frozenset = frozenset()) -> int:
        """Evict TTL-expired then LRU clips (never ``protect``-ed ones)
        until the budget holds.  Caller must hold the lock."""
        if self.budget is None:
            return 0
        self._load_all_datasets()
        n0 = self.evictions
        now = time.time()
        dirty: Set[str] = set()
        def evictable(key, e):
            # an OPEN clip (live ingestion mid-stream) is never evicted:
            # its NPZ is the only copy of the stream's visible prefix,
            # and a transparent batch re-ingest would clobber the
            # append pipeline's tracker/index state
            wm = e.get("watermark")
            return key not in protect \
                and not (wm is not None and wm < key[3])

        if self.budget.ttl_seconds is not None:
            for key, e in list(self._entries.items()):
                if e["present"] and evictable(key, e) \
                        and now - e["last_used"] > self.budget.ttl_seconds:
                    self._evict(key)
                    dirty.add(key[0])
        if self.budget.max_bytes is not None:
            present = [(e["last_used"], key) for key, e
                       in self._entries.items() if e["present"]]
            total = sum(self._entries[k]["bytes"] for _, k in present)
            for _, key in sorted(present):      # oldest first
                if total <= self.budget.max_bytes:
                    break
                if not evictable(key, self._entries[key]):
                    continue
                total -= self._entries[key]["bytes"]
                self._evict(key)
                dirty.add(key[0])
        for dataset in dirty:
            self._flush_index(dataset)
        return self.evictions - n0

    # holds-lock: _lock
    def _evict(self, key: ClipKey) -> None:
        """Drop one clip's NPZ from memory and disk; its summary stays
        in the entry map (and index.json) for index-based skipping.
        Caller must hold the lock."""
        e = self._entries[key]
        try:
            os.remove(self._clip_path(key))
        except FileNotFoundError:
            pass                        # already gone (concurrent prune)
        e["present"] = False
        self._index.pop(key, None)
        self.evictions += 1
        self.evicted_bytes += e["bytes"]
        self._m_evictions.inc()
        self._m_evicted_bytes.inc(e["bytes"])

    # -- paths ----------------------------------------------------------------

    def _version_dir(self, dataset: str,
                     fingerprint: Optional[str] = None) -> str:
        # repro-lint: disable=lock-discipline -- unlocked callers (has/get) always pass an explicit fingerprint snapshot; the default-arg read is only reached under the lock
        fp = fingerprint or self.fingerprint
        return os.path.join(self.root, dataset, fp)

    def _clip_path(self, key: ClipKey,
                   fingerprint: Optional[str] = None) -> str:
        return os.path.join(self._version_dir(key[0], fingerprint),
                            _clip_name(key) + ".npz")

    # holds-lock: _lock
    def _write_meta(self, dataset: str) -> None:
        vdir = self._version_dir(dataset)
        os.makedirs(vdir, exist_ok=True)
        path = os.path.join(vdir, "meta.json")
        if not os.path.exists(path):
            with open(path, "w") as f:
                json.dump({
                    "fingerprint": self.fingerprint,
                    "schema": SCHEMA_VERSION,
                    "params": self.params.describe(),
                    "theta": _track_fields(self.params),
                    "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
                }, f, indent=1, default=list)

    # -- index.json (per-version clip summaries) ------------------------------

    def _index_path(self, dataset: str) -> str:
        return os.path.join(self._version_dir(dataset), "index.json")

    # holds-lock: _lock
    def _ensure_loaded(self, dataset: str) -> None:
        """Populate ``_entries`` from the dataset's index.json (once per
        dataset per fingerprint).  Caller must hold the lock."""
        if dataset in self._loaded_datasets:
            return
        self._loaded_datasets.add(dataset)
        try:
            with open(self._index_path(dataset)) as f:
                doc = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return
        for name, e in doc.get("clips", {}).items():
            try:
                split, clip_id, n_frames = name.rsplit("_", 2)
                key = (dataset, split, int(clip_id), int(n_frames))
            except ValueError:
                continue
            if key in self._entries:
                # an in-memory entry (registered by get/materialize
                # before this dataset's first bulk load) is fresher
                # than the persisted one — clobbering it would reset
                # last_used and invert the LRU order
                continue
            wm = e.get("watermark")
            self._entries[key] = {
                "summary": ClipSummary.from_json(e["summary"]),
                "bytes": int(e["bytes"]),
                "last_used": float(e["last_used"]),
                "present": bool(e["present"]),
                "watermark": None if wm is None else int(wm),
            }

    # holds-lock: _lock
    def _flush_index(self, dataset: str) -> None:
        """Atomically rewrite the dataset's index.json from the entry
        map.  Caller must hold the lock."""
        vdir = self._version_dir(dataset)
        os.makedirs(vdir, exist_ok=True)
        doc = {
            "schema": SCHEMA_VERSION,
            "fingerprint": self.fingerprint,
            "buckets": list(MIN_LEN_BUCKETS),
            "clips": {
                _clip_name(k): {
                    "summary": e["summary"].to_json(),
                    "bytes": e["bytes"],
                    "last_used": e["last_used"],
                    "present": e["present"],
                    "watermark": e.get("watermark"),
                } for k, e in self._entries.items() if k[0] == dataset
            },
        }
        path = self._index_path(dataset)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1)
        os.replace(tmp, path)

    # holds-lock: _lock
    def _register(self, key: ClipKey, packed: PackedTracks,
                  path: str) -> None:
        """Record/refresh a clip's entry after load or materialize.
        Caller must hold the lock."""
        try:
            nbytes = os.path.getsize(path)
        except OSError:
            nbytes = int(packed.rows.nbytes + packed.offsets.nbytes)
        self._entries[key] = {
            "summary": packed.summary, "bytes": nbytes,
            "last_used": time.time(), "present": True,
            "watermark": packed.watermark,
        }

    # -- lookup ---------------------------------------------------------------

    def has(self, clip: Clip) -> bool:
        key = clip_key(clip)
        with self._lock:
            if key in self._index:
                return True
            fp = self.fingerprint        # snapshot: θ may swap under us
        return os.path.exists(self._clip_path(key, fp))

    def summary(self, clip: Clip) -> Optional[ClipSummary]:
        """The clip's index digest, available even when its NPZ has
        been evicted; None when the clip was never materialized for
        this θ."""
        key = clip_key(clip)
        with self._lock:
            self._ensure_loaded(key[0])
            e = self._entries.get(key)
            if e is not None:
                return e["summary"]
            hit = self._index.get(key)
            return hit.summary if hit is not None else None

    def _read_clip(self, path: str) -> PackedTracks:
        with np.load(path) as z:
            return PackedTracks(
                rows=z["rows"], offsets=z["offsets"],
                n_frames=int(z["info"][0]), fps=int(z["info"][1]),
                seconds=float(z["seconds"][0]),
                counters=tuple(int(v) for v in z["info"][2:]),
                hist=z["hist"] if "hist" in z.files else None,
                track_bbox=(z["track_bbox"]
                            if "track_bbox" in z.files else None),
                watermark=(int(z["watermark"][0])
                           if "watermark" in z.files else None))

    def get(self, clip: Clip) -> Optional[PackedTracks]:
        """The clip's packed tracks, loading from disk on first touch;
        None when the clip is cold (not materialized for this θ)."""
        key = clip_key(clip)
        with self._lock:
            hit = self._index.get(key)
            if hit is not None:
                e = self._entries.get(key)
                if e is not None:
                    e["last_used"] = time.time()
                return hit
            fp = self.fingerprint        # snapshot: θ may swap under us
        path = self._clip_path(key, fp)
        if not os.path.exists(path):
            return None
        try:
            packed = self._read_clip(path)
        except FileNotFoundError:
            return None                  # evicted between exists and load
        with self._lock:
            if self.fingerprint != fp:
                # θ swapped while we were reading: the data belongs to
                # the OLD version — caching it would serve stale-θ
                # tracks under the new fingerprint.  The clip is cold
                # for the current θ.
                return None
            self._register(key, packed, path)
            # racing loaders produce identical values; first write wins
            return self._index.setdefault(key, packed)

    def tracks(self, clip: Clip) -> List[np.ndarray]:
        """Convenience: the clip's tracks as the executor returned them
        (exact roundtrip through the packed arrays)."""
        packed = self.get(clip)
        if packed is None:
            raise KeyError(f"clip {clip_key(clip)} not materialized "
                           # repro-lint: disable=lock-discipline -- error-message snapshot; a torn θ read only mislabels the exception
                           f"for θ {self.fingerprint}")
        return packed.tracks()

    # -- ingest ---------------------------------------------------------------

    def materialize(self, clip: Clip, result: RunResult,
                    flush: bool = True) -> PackedTracks:
        """Pack one executor result and persist it (with its index).
        ``flush=False`` defers the index.json rewrite — batch callers
        (``ingest``) flush once per dataset at the end instead of
        re-serializing every summary after every clip."""
        return self.materialize_packed(
            clip, PackedTracks.pack(result.tracks, clip, result),
            flush=flush)

    def materialize_packed(self, clip: Clip, packed: PackedTracks,
                           flush: bool = True) -> PackedTracks:
        """Persist an already-packed clip (the stream path packs per
        watermark and attaches its incrementally merged index before
        landing here).  An open clip (``packed.watermark`` set below
        ``clip.n_frames``) gets the watermark persisted in the NPZ and
        the index entry; re-materializing the same key replaces the
        previous watermark's NPZ atomically, so a concurrent reader
        sees either the old prefix or the new one, never a tear."""
        key = clip_key(clip)
        packed.build_index_arrays()
        with self._lock:
            self._ensure_loaded(key[0])
            self._write_meta(key[0])
            path = self._clip_path(key)
            tmp = path + ".tmp.npz"
            info = np.asarray(
                [packed.n_frames, packed.fps, *packed.counters], np.int64)
            arrays = dict(rows=packed.rows, offsets=packed.offsets,
                          info=info,
                          seconds=np.asarray([packed.seconds],
                                             np.float64),
                          hist=packed.hist,
                          track_bbox=packed.track_bbox)
            if packed.watermark is not None:
                arrays["watermark"] = np.asarray([packed.watermark],
                                                 np.int64)
            np.savez(tmp, **arrays)
            os.replace(tmp, path)       # atomic: readers never see partials
            self._index[key] = packed
            self._register(key, packed, path)
            if flush:
                self._flush_index(key[0])
        return packed

    def watermark(self, clip: Clip) -> Optional[int]:
        """Frames ingested so far for an OPEN clip; ``clip.n_frames``
        once sealed (or batch-ingested); None when never materialized
        for this θ."""
        key = clip_key(clip)
        with self._lock:
            self._ensure_loaded(key[0])
            e = self._entries.get(key)
            if e is not None:
                wm = e.get("watermark")
                return key[3] if wm is None else wm
            hit = self._index.get(key)
            if hit is None:
                return None
            return key[3] if hit.watermark is None else hit.watermark

    def sidecar_path(self, clip: Clip, suffix: str) -> str:
        """Path for a per-clip sidecar file inside the current version
        directory (the stream subsystem persists tracker checkpoints as
        ``<clip>.<suffix>`` next to the clip NPZ)."""
        key = clip_key(clip)
        with self._lock:
            vdir = self._version_dir(key[0])
            os.makedirs(vdir, exist_ok=True)
        return os.path.join(vdir, _clip_name(key) + "." + suffix)

    def ingest(self, clips: Sequence[Clip],
               log=lambda *_: None) -> IngestReport:
        """Materialize every clip not yet in the current θ version.

        Cold clips stream through ``executor.run_clips`` — clip i+1's
        decode prefetches while clip i computes, chunks round-robin
        devices — warm clips cost one index lookup and zero model
        calls.  OPEN clips (live ingestion, ``repro.stream``) count as
        cached: they are served at their current watermark and only
        their ``SegmentIngestor`` may extend them.  Budget enforcement
        runs after the batch lands (the batch itself is protected from
        its own ingest)."""
        report = IngestReport(requested=len(clips))
        cold = [c for c in clips if not self.has(c)]
        report.cached = len(clips) - len(cold)
        if cold:
            if self.bank is None:
                raise RuntimeError(
                    f"{len(cold)} cold clips but the store has no model "
                    f"bank to extract with")
            t0 = time.perf_counter()
            # repro-lint: disable=lock-discipline -- batch ingest runs against a stable θ snapshot; set_params mid-ingest is unsupported (the fingerprint check in get() rejects stale results)
            results, seconds = run_clips(self.bank, self.params, cold,
                                         self.options)
            for clip, res in zip(cold, results):
                self.materialize(clip, res, flush=False)
                report.frames += res.frames_processed
            report.ingested = len(cold)
            report.seconds = seconds
            report.wall_seconds = time.perf_counter() - t0
        with self._lock:
            for dataset in {clip_key(c)[0] for c in cold}:
                self._flush_index(dataset)      # once per dataset, not per clip
            self._load_all_datasets()
            bytes0 = self.evicted_bytes
            report.evicted = self._enforce_budget(
                protect=frozenset(clip_key(c) for c in clips))
            report.evicted_bytes = self.evicted_bytes - bytes0
            report.store_bytes = sum(
                e["bytes"] for e in self._entries.values() if e["present"])
            self._m_bytes.set(report.store_bytes)
        if report.ingested:
            log(f"[store] ingested {report.ingested} clips "
                f"({report.frames} frames, {report.fps:.1f} fps wall), "
                f"{report.cached} cached, {report.evicted} evicted")
        return report
