"""TrackStore: materialize pre-processed tracks once, serve them forever.

The store persists ``executor.run_clips`` outputs keyed by
``(dataset, clip, θ-fingerprint)``:

  * **θ-fingerprint** — a hash of the TRACK-RELEVANT fields of
    ``PipelineParams``.  Scheduling-only knobs (``chunk_size``) are
    excluded: tracks are bit-identical across chunk sizes by
    construction (tests/test_executor.py), so re-tuning B must not
    invalidate materialized tracks.  Any change to a field that can
    change tracks (arch, resolution, confidence, gap, proxy, tracker,
    refine) yields a new fingerprint, i.e. a new store version; stale
    versions stay on disk until ``prune()``.
  * **Layout** — one NPZ per clip under
    ``root/<dataset>/<fingerprint>/<split>_<clip>_<frames>.npz`` holding
    the packed track arrays plus the run's cost counters, and one
    ``meta.json`` per fingerprint directory describing θ.
  * **Packed representation** — all of a clip's tracks concatenated
    into one ``(N, 6)`` row array ``[frame, cx, cy, w, h, track_id]``
    with an offsets array delimiting tracks.  Query plans
    (``repro.query.plan``) scan these packed arrays with vectorized
    numpy ops; nothing at query time is per-track Python.
  * **Incremental ingest** — ``ingest(clips)`` materializes only the
    clips missing from the current version, streaming them through the
    executor with cross-clip decode prefetch (``executor.run_clips``).
    A fully-materialized split re-ingests with ZERO detector calls and
    zero decodes (asserted by tests/test_query.py).

The store itself is thread-safe (one lock around the in-memory index
and disk writes); ``QueryService`` layers concurrent query execution
and transparent cold-clip ingest on top.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.executor import ExecutorOptions, run_clips
from repro.core.pipeline import ModelBank, PipelineParams, RunResult
from repro.data.video_synth import Clip

SCHEMA_VERSION = 1

# PipelineParams fields that CANNOT change extracted tracks (pure
# scheduling knobs; see module docstring).  A denylist, so any field
# added to θ later is track-relevant — and store-invalidating — by
# default; a new scheduling-only knob must opt in here explicitly.
_SCHEDULING_ONLY = ("chunk_size",)

ClipKey = Tuple[str, str, int, int]     # (dataset, split, clip_id, n_frames)


def _track_fields(params: PipelineParams) -> Dict[str, object]:
    return {f.name: getattr(params, f.name)
            for f in dataclasses.fields(params)
            if f.name not in _SCHEDULING_ONLY}


def theta_fingerprint(params: PipelineParams) -> str:
    """Stable hex fingerprint of θ's track-relevant fields."""
    payload = _track_fields(params)
    payload["schema"] = SCHEMA_VERSION
    blob = json.dumps(payload, sort_keys=True, default=list)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def clip_key(clip: Clip) -> ClipKey:
    return (clip.profile.name, clip.split, clip.clip_id, clip.n_frames)


@dataclass
class PackedTracks:
    """One clip's tracks as packed numpy arrays (the query-scan format).

    ``rows``    — (N, 6) ``[frame, cx, cy, w, h, track_id]``, all tracks
                  concatenated in track order;
    ``offsets`` — (T+1,) int64; track i is ``rows[offsets[i]:offsets[i+1]]``.

    Derived arrays used by every plan (row→track map, per-track lengths)
    are computed once and cached; per-track pattern classification is
    computed lazily on first class-filtered query (it needs the clip's
    profile, so it cannot be precomputed dataset-independently).
    """
    rows: np.ndarray
    offsets: np.ndarray
    n_frames: int
    fps: int
    seconds: float = 0.0                    # extraction cost (RunResult)
    counters: Tuple[int, ...] = ()          # RunResult counter snapshot
    _row_track: Optional[np.ndarray] = field(default=None, repr=False)
    _classes: Optional[np.ndarray] = field(default=None, repr=False)

    @property
    def n_tracks(self) -> int:
        return len(self.offsets) - 1

    @property
    def lengths(self) -> np.ndarray:
        return np.diff(self.offsets)

    @property
    def row_track(self) -> np.ndarray:
        """(N,) track index of every row."""
        if self._row_track is None:
            self._row_track = np.repeat(
                np.arange(self.n_tracks, dtype=np.int64), self.lengths)
        return self._row_track

    def track(self, i: int) -> np.ndarray:
        return self.rows[self.offsets[i]:self.offsets[i + 1]]

    def tracks(self) -> List[np.ndarray]:
        return [self.track(i) for i in range(self.n_tracks)]

    def classes(self, profile) -> np.ndarray:
        """(T,) per-track pattern id (``metrics.classify_track``), -1
        for unclassifiable stubs.  Cached after the first call."""
        if self._classes is None:
            from repro.core.metrics import classify_track
            out = np.full(self.n_tracks, -1, np.int64)
            for i in range(self.n_tracks):
                c = classify_track(self.track(i), profile)
                if c is not None:
                    out[i] = c
            self._classes = out
        return self._classes

    @classmethod
    def pack(cls, tracks: Sequence[np.ndarray], clip: Clip,
             result: Optional[RunResult] = None) -> "PackedTracks":
        offsets = np.zeros(len(tracks) + 1, np.int64)
        parts = []
        for i, t in enumerate(tracks):
            offsets[i + 1] = offsets[i] + len(t)
            parts.append(np.asarray(t, np.float32).reshape(len(t), 6))
        rows = np.concatenate(parts) if parts \
            else np.zeros((0, 6), np.float32)
        counters = () if result is None else (
            result.frames_processed, result.detector_windows,
            result.full_frames, result.skipped_frames)
        seconds = 0.0 if result is None else float(result.seconds)
        return cls(rows, offsets, clip.n_frames, clip.profile.fps,
                   seconds, counters)


@dataclass
class IngestReport:
    """What one ``ingest`` call actually did."""
    requested: int = 0          # clips asked for
    ingested: int = 0           # clips that ran through the executor
    cached: int = 0             # clips already materialized
    frames: int = 0             # frames processed during this ingest
    seconds: float = 0.0        # summed RunResult.seconds (cost model)
    wall_seconds: float = 0.0   # wall clock of the executor sweep

    @property
    def fps(self) -> float:
        return self.frames / self.wall_seconds if self.wall_seconds > 0 \
            else 0.0


class TrackStore:
    """Persistent, versioned store of extracted tracks for one θ.

    ``set_params`` re-points the store at a different θ version: the
    in-memory index is invalidated and subsequent lookups hit the new
    fingerprint's directory (cold until re-ingested).  All public
    methods are thread-safe.
    """

    def __init__(self, root: str, bank: ModelBank,
                 params: PipelineParams,
                 options: Optional[ExecutorOptions] = None):
        self.root = root
        self.bank = bank
        self.options = options
        self._lock = threading.RLock()
        self._index: Dict[ClipKey, PackedTracks] = {}
        self.params: Optional[PipelineParams] = None
        self.fingerprint: Optional[str] = None
        self.set_params(params)

    # -- versioning -----------------------------------------------------------

    def set_params(self, params: PipelineParams) -> None:
        """Point the store at θ; a changed fingerprint invalidates the
        in-memory index (disk versions are kept until ``prune``)."""
        fp = theta_fingerprint(params)
        with self._lock:
            if fp != self.fingerprint:
                self._index.clear()
            self.params = params
            self.fingerprint = fp

    def prune(self) -> List[str]:
        """Delete on-disk versions whose fingerprint is not current.
        Returns the removed fingerprints."""
        removed = []
        with self._lock:
            if not os.path.isdir(self.root):
                return removed
            for dataset in os.listdir(self.root):
                dpath = os.path.join(self.root, dataset)
                if not os.path.isdir(dpath):
                    continue
                for fp in os.listdir(dpath):
                    if fp == self.fingerprint:
                        continue
                    vdir = os.path.join(dpath, fp)
                    for name in os.listdir(vdir):
                        os.unlink(os.path.join(vdir, name))
                    os.rmdir(vdir)
                    removed.append(fp)
        return removed

    # -- paths ----------------------------------------------------------------

    def _version_dir(self, dataset: str) -> str:
        return os.path.join(self.root, dataset, self.fingerprint)

    def _clip_path(self, key: ClipKey) -> str:
        dataset, split, clip_id, n_frames = key
        return os.path.join(self._version_dir(dataset),
                            f"{split}_{clip_id}_{n_frames}.npz")

    def _write_meta(self, dataset: str) -> None:
        vdir = self._version_dir(dataset)
        os.makedirs(vdir, exist_ok=True)
        path = os.path.join(vdir, "meta.json")
        if not os.path.exists(path):
            with open(path, "w") as f:
                json.dump({
                    "fingerprint": self.fingerprint,
                    "schema": SCHEMA_VERSION,
                    "params": self.params.describe(),
                    "theta": _track_fields(self.params),
                    "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
                }, f, indent=1, default=list)

    # -- lookup ---------------------------------------------------------------

    def has(self, clip: Clip) -> bool:
        key = clip_key(clip)
        with self._lock:
            if key in self._index:
                return True
        return os.path.exists(self._clip_path(key))

    def get(self, clip: Clip) -> Optional[PackedTracks]:
        """The clip's packed tracks, loading from disk on first touch;
        None when the clip is cold (not materialized for this θ)."""
        key = clip_key(clip)
        with self._lock:
            hit = self._index.get(key)
            if hit is not None:
                return hit
        path = self._clip_path(key)
        if not os.path.exists(path):
            return None
        with np.load(path) as z:
            packed = PackedTracks(
                rows=z["rows"], offsets=z["offsets"],
                n_frames=int(z["info"][0]), fps=int(z["info"][1]),
                seconds=float(z["seconds"][0]),
                counters=tuple(int(v) for v in z["info"][2:]))
        with self._lock:
            # racing loaders produce identical values; first write wins
            return self._index.setdefault(key, packed)

    def tracks(self, clip: Clip) -> List[np.ndarray]:
        """Convenience: the clip's tracks as the executor returned them
        (exact roundtrip through the packed arrays)."""
        packed = self.get(clip)
        if packed is None:
            raise KeyError(f"clip {clip_key(clip)} not materialized "
                           f"for θ {self.fingerprint}")
        return packed.tracks()

    # -- ingest ---------------------------------------------------------------

    def materialize(self, clip: Clip, result: RunResult) -> PackedTracks:
        """Pack one executor result and persist it."""
        key = clip_key(clip)
        packed = PackedTracks.pack(result.tracks, clip, result)
        with self._lock:
            self._write_meta(key[0])
            path = self._clip_path(key)
            tmp = path + ".tmp.npz"
            info = np.asarray(
                [packed.n_frames, packed.fps, *packed.counters], np.int64)
            np.savez(tmp, rows=packed.rows, offsets=packed.offsets,
                     info=info,
                     seconds=np.asarray([packed.seconds], np.float64))
            os.replace(tmp, path)       # atomic: readers never see partials
            self._index[key] = packed
        return packed

    def ingest(self, clips: Sequence[Clip],
               log=lambda *_: None) -> IngestReport:
        """Materialize every clip not yet in the current θ version.

        Cold clips stream through ``executor.run_clips`` — clip i+1's
        decode prefetches while clip i computes, chunks round-robin
        devices — warm clips cost one index lookup and zero model
        calls."""
        report = IngestReport(requested=len(clips))
        cold = [c for c in clips if not self.has(c)]
        report.cached = len(clips) - len(cold)
        if not cold:
            return report
        t0 = time.perf_counter()
        results, seconds = run_clips(self.bank, self.params, cold,
                                     self.options)
        for clip, res in zip(cold, results):
            self.materialize(clip, res)
            report.frames += res.frames_processed
        report.ingested = len(cold)
        report.seconds = seconds
        report.wall_seconds = time.perf_counter() - t0
        log(f"[store] ingested {report.ingested} clips "
            f"({report.frames} frames, {report.fps:.1f} fps wall), "
            f"{report.cached} cached")
        return report
