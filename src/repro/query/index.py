"""Secondary indexes over packed track arrays.

The query plan's hot loop (``repro.query.plan``) is a vectorized row
scan — O(rows) per clip per query.  The structures here let most
queries touch far fewer rows, or none at all, NoScope/Spatialyze style
(cheap filters in front of the expensive path, pushed into storage):

  * **Count histograms** — ``hist[b, f]`` is the number of surviving
    track points on frame ``f`` when the track-level predicate is
    ``len >= MIN_LEN_BUCKETS[b]``.  A count/limit/duration query whose
    predicate is indexed (min_len in the buckets, no class filter,
    region absent or provably a no-op) reads its per-frame counts
    straight from the histogram row — identical, by construction, to
    what the row scan's ``np.bincount`` would produce, so the answer is
    bit-identical with zero rows touched.
  * **Per-track bounding boxes** — ``track_bbox[t]`` is the
    ``(min_cx, min_cy, max_cx, max_cy)`` envelope of track ``t``'s
    detection centers.  Their per-bucket unions feed region pruning:
    a query region disjoint from the union skips the clip outright; a
    region CONTAINING the union makes the region predicate a no-op,
    unlocking the histogram path.
  * **``ClipSummary``** — the per-clip scalar digest
    (row/track totals, frame span, per-bucket max counts, union
    bboxes, and GRID x GRID occupancy bitmasks — the coarse spatial
    grid lets a ``Region`` skip clips whose union bbox overlaps the
    query but whose occupied cells don't).  Summaries are tiny,
    JSON-serializable, and persisted in
    the version's ``index.json`` SEPARATELY from the clip NPZ — so they
    survive eviction, and an evicted clip that the summary proves
    irrelevant to a query is skipped without being re-ingested.

All index content is derived deterministically from the packed rows,
so it never needs separate invalidation: same θ-fingerprint ⇒ same
tracks ⇒ same index.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

# Track-length floors that get a precomputed histogram row.  1 is the
# no-op filter, 2 is the paper's drop-single-detection-stubs default
# (§4.2), 3 covers the common "stable track" tightening.  Queries with
# other floors fall back to the row scan (still correct, just slower);
# skip tests use the largest bucket <= the query's floor, which stays
# sound because a higher floor only shrinks the surviving set.
MIN_LEN_BUCKETS: Tuple[int, ...] = (1, 2, 3)

# (x0, y0, x1, y1) with x1 < x0: the empty envelope, disjoint from
# every query region and contained in none.
EMPTY_BBOX: Tuple[float, float, float, float] = (
    math.inf, math.inf, -math.inf, -math.inf)

Bbox = Tuple[float, float, float, float]

# Coarse spatial occupancy grid: the unit frame split into GRID x GRID
# cells, one bit per cell (row-major, bit = y_cell * GRID + x_cell).  A
# bucket's mask has a bit set iff ANY surviving detection center falls
# in that cell — finer-grained than the union bbox, so a region that
# OVERLAPS the bbox (e.g. the empty middle between two highway lanes)
# can still prove the clip skippable when no occupied cell intersects
# it.
GRID = 4


def bbox_is_empty(bbox: Bbox) -> bool:
    return bbox[2] < bbox[0] or bbox[3] < bbox[1]


def _cell_clamp(v: np.ndarray) -> np.ndarray:
    return np.clip((v * GRID).astype(np.int64), 0, GRID - 1)


def occupancy_mask(cx: np.ndarray, cy: np.ndarray) -> int:
    """Bitmask of GRID x GRID cells containing >= 1 (cx, cy) center.
    Out-of-frame centers clamp to the border cells, which keeps the
    region test conservative (the region's cell range clamps the same
    way)."""
    if len(cx) == 0:
        return 0
    cells = _cell_clamp(np.asarray(cy)) * GRID + _cell_clamp(np.asarray(cx))
    return int(np.bitwise_or.reduce(1 << cells))


def grids_from_rows(rows: np.ndarray,
                    offsets: np.ndarray) -> Tuple[int, ...]:
    """Per-``MIN_LEN_BUCKETS`` occupancy masks derived from packed
    rows — THE definition of a clip's grids (``summarize`` and the
    stream's resume path both call this; the stream's incremental
    masks are differentially tested against it)."""
    lengths = np.diff(offsets)
    row_len = np.repeat(lengths, lengths) if len(rows) \
        else np.zeros(0, np.int64)
    out = []
    for b in MIN_LEN_BUCKETS:
        sel = row_len >= b
        out.append(occupancy_mask(rows[sel, 1], rows[sel, 2]))
    return tuple(out)


@functools.lru_cache(maxsize=4096)
def region_mask(x0: float, y0: float, x1: float, y1: float) -> int:
    """Bitmask of cells a [x0,x1] x [y0,y1] region (bounds inclusive)
    can possibly touch.  Sound: every in-region center lies in one of
    these cells (floor is monotone and both sides clamp alike).
    Cached — a standing query re-tests the same region every
    watermark."""
    cx0 = max(0, min(GRID - 1, math.floor(x0 * GRID)))
    cx1 = max(0, min(GRID - 1, math.floor(x1 * GRID)))
    cy0 = max(0, min(GRID - 1, math.floor(y0 * GRID)))
    cy1 = max(0, min(GRID - 1, math.floor(y1 * GRID)))
    mask = 0
    for gy in range(cy0, cy1 + 1):
        for gx in range(cx0, cx1 + 1):
            mask |= 1 << (gy * GRID + gx)
    return mask


@dataclass(frozen=True)
class ClipSummary:
    """Scalar digest of one clip's index — everything the planner needs
    to decide skip / histogram / scan without the packed arrays.

    ``max_count[b]`` bounds the per-frame count under min_len bucket b
    (and therefore under ANY predicate at least as strict); ``bbox[b]``
    is the union envelope of the bucket's surviving tracks; ``grid[b]``
    is the bucket's GRID x GRID occupancy bitmask (``occupancy_mask``).
    All are per ``MIN_LEN_BUCKETS`` entry.  ``grid`` is None for
    summaries persisted before the grid existed — the planner then
    falls back to the bbox-only skip test.
    """
    n_rows: int
    n_tracks: int
    max_len: int                        # longest track, in rows
    min_frame: int                      # 0 / -1 sentinels when empty
    max_frame: int
    max_count: Tuple[int, ...]          # per MIN_LEN_BUCKETS entry
    bbox: Tuple[Bbox, ...]              # per MIN_LEN_BUCKETS entry
    grid: Optional[Tuple[int, ...]] = None   # per MIN_LEN_BUCKETS entry

    def to_json(self) -> dict:
        return {
            "n_rows": self.n_rows, "n_tracks": self.n_tracks,
            "max_len": self.max_len,
            "min_frame": self.min_frame, "max_frame": self.max_frame,
            "max_count": list(self.max_count),
            # empty envelopes serialize as null (inf is not JSON)
            "bbox": [None if bbox_is_empty(b)
                     else [float(v) for v in b] for b in self.bbox],
            "grid": None if self.grid is None else list(self.grid),
        }

    @classmethod
    def from_json(cls, d: dict) -> "ClipSummary":
        grid = d.get("grid")
        return cls(
            n_rows=int(d["n_rows"]), n_tracks=int(d["n_tracks"]),
            max_len=int(d["max_len"]),
            min_frame=int(d["min_frame"]), max_frame=int(d["max_frame"]),
            max_count=tuple(int(v) for v in d["max_count"]),
            bbox=tuple(EMPTY_BBOX if b is None else tuple(b)
                       for b in d["bbox"]),
            grid=None if grid is None else tuple(int(g) for g in grid))


def build_index(rows: np.ndarray, offsets: np.ndarray,
                n_frames: int) -> Tuple[np.ndarray, np.ndarray]:
    """(hist, track_bbox) for one clip's packed arrays.

    ``hist`` is ``(len(MIN_LEN_BUCKETS), W)`` int32 with
    ``W = max(n_frames, max_frame + 1)`` — exactly the length
    ``np.bincount(frames, minlength=n_frames)`` produces in the row
    scan, so histogram-served counts are bit-identical to scanned ones.
    ``track_bbox`` is ``(T, 4)`` float32 center envelopes, the empty
    sentinel for zero-length tracks.
    """
    n_tracks = len(offsets) - 1
    lengths = np.diff(offsets)
    frames = rows[:, 0].astype(np.int64) if len(rows) \
        else np.zeros(0, np.int64)
    width = max(int(n_frames), int(frames.max()) + 1 if len(frames)
                else 0)
    hist = np.zeros((len(MIN_LEN_BUCKETS), width), np.int32)
    track_bbox = np.empty((n_tracks, 4), np.float32)
    track_bbox[:, :2] = np.inf
    track_bbox[:, 2:] = -np.inf
    if len(rows):
        row_track = np.repeat(np.arange(n_tracks, dtype=np.int64),
                              lengths)
        row_len = lengths[row_track]
        for bi, b in enumerate(MIN_LEN_BUCKETS):
            hist[bi] = np.bincount(frames[row_len >= b],
                                   minlength=width)
        cx, cy = rows[:, 1], rows[:, 2]
        np.minimum.at(track_bbox[:, 0], row_track, cx)
        np.minimum.at(track_bbox[:, 1], row_track, cy)
        np.maximum.at(track_bbox[:, 2], row_track, cx)
        np.maximum.at(track_bbox[:, 3], row_track, cy)
    return hist, track_bbox


def summarize(rows: np.ndarray, offsets: np.ndarray, hist: np.ndarray,
              track_bbox: np.ndarray,
              grid: Optional[Tuple[int, ...]] = None) -> ClipSummary:
    """Fold one clip's index arrays into the scalar ``ClipSummary``.

    ``grid`` lets a caller supply precomputed occupancy masks (the
    stream path maintains them incrementally); by default they are
    derived from the rows here."""
    lengths = np.diff(offsets)
    frames = rows[:, 0] if len(rows) else None
    max_count = tuple(int(hist[bi].max()) if hist.shape[1] else 0
                      for bi in range(len(MIN_LEN_BUCKETS)))
    bboxes: List[Bbox] = []
    for b in MIN_LEN_BUCKETS:
        sel = lengths >= b
        if sel.any() and np.isfinite(track_bbox[sel, 0]).any():
            bb = track_bbox[sel]
            bboxes.append((float(bb[:, 0].min()), float(bb[:, 1].min()),
                           float(bb[:, 2].max()), float(bb[:, 3].max())))
        else:
            bboxes.append(EMPTY_BBOX)
    return ClipSummary(
        n_rows=int(len(rows)), n_tracks=int(len(offsets) - 1),
        max_len=int(lengths.max()) if len(lengths) else 0,
        min_frame=int(frames.min()) if frames is not None else 0,
        max_frame=int(frames.max()) if frames is not None else -1,
        max_count=max_count, bbox=tuple(bboxes),
        grid=grids_from_rows(rows, offsets) if grid is None
        else tuple(grid))
