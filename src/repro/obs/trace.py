"""Span tracing: one timeline for a multi-stream MultiScope run.

The tracer collects SPANS — named wall-clock intervals tagged with the
stream (clip) they belong to, the chunk index, the emitting thread and
an optional parent span — into a bounded ring buffer, and exports them
as JSON-lines (one span per line, greppable) or Chrome trace format
(load the file at ``chrome://tracing`` or https://ui.perfetto.dev to see
a 16-camera broker run as one timeline: per-stream lanes for the
DECODE/PROXY/DETECT/TRACK stages, broker lanes showing the consolidated
flushes every stream's windows rode).

The instrumentation contract (tested by tests/test_obs.py):

  * **disabled = free.**  ``TRACER.enabled`` is False by default and
    every instrumentation site guards with one attribute read + branch
    (``if TRACER.enabled:``); no span objects, no timestamps, no locks
    are taken on the hot path while disabled.
  * **enabled = observer only.**  Spans record timings and counters that
    the pipeline already computes (or that cost O(1) alongside them);
    tracing NEVER changes tracks, plans, dispatch counts or any other
    pipeline output (asserted bit-for-bit, tracing on vs off).
  * **bounded.**  The ring buffer holds ``capacity`` spans (default
    65536); older spans fall off the back.  An always-on stream can
    leave tracing enabled without growing memory per frame.

Span naming scheme (see src/repro/obs/README.md for the full table):

  ``run``                    one executor run (a clip, or one appended
                             segment of an open clip)
  ``stage.{decode,proxy,detect,track}``   one chunk through one stage
  ``broker.detect.flush``    one BatchBroker flush (its consolidated
                             dispatches are child spans)
  ``broker.detect.dispatch`` one consolidated detector call
  ``broker.track.flush`` / ``broker.track.dispatch``   TrackBroker twin
  ``stream.append``          one SegmentIngestor.append
  ``query.run``              one QueryService.query
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Dict, List, Optional

__all__ = ["Span", "Tracer", "TRACER", "enable", "disable", "enabled",
           "export_jsonl", "export_chrome"]


class Span:
    """One recorded interval.  ``ts``/``dur`` are perf_counter
    nanoseconds (monotone across threads); ``proc`` is thread-CPU
    nanoseconds actually spent; ``dur < 0`` marks a still-open span."""

    __slots__ = ("sid", "parent", "name", "cat", "ts", "dur", "proc",
                 "tid", "stream", "chunk", "args")

    def __init__(self, sid: int, parent: Optional[int], name: str,
                 cat: str, ts: int, dur: int, proc: int, tid: int,
                 stream: Optional[str], chunk: Optional[int],
                 args: Optional[dict]):
        self.sid = sid
        self.parent = parent
        self.name = name
        self.cat = cat
        self.ts = ts
        self.dur = dur
        self.proc = proc
        self.tid = tid
        self.stream = stream
        self.chunk = chunk
        self.args = args

    def to_dict(self) -> dict:
        d = {"sid": self.sid, "name": self.name, "cat": self.cat,
             "ts_ns": self.ts, "dur_ns": self.dur, "proc_ns": self.proc,
             "tid": self.tid}
        if self.parent is not None:
            d["parent"] = self.parent
        if self.stream is not None:
            d["stream"] = self.stream
        if self.chunk is not None:
            d["chunk"] = self.chunk
        if self.args:
            d["args"] = self.args
        return d


class Tracer:
    """Thread-safe ring-buffer span collector.  One module-level
    instance (``TRACER``) is shared by every instrumentation site."""

    def __init__(self, capacity: int = 65536):
        self.enabled = False
        self._capacity = int(capacity)  # guarded-by: _lock
        self._lock = threading.Lock()
        self._spans: deque = deque(maxlen=self._capacity)  # guarded-by: _lock
        self._ids = itertools.count(1)
        self._tls = threading.local()

    # -- lifecycle ------------------------------------------------------------

    def enable(self, capacity: Optional[int] = None) -> "Tracer":
        with self._lock:
            if capacity is not None and capacity != self._capacity:
                self._capacity = int(capacity)
                self._spans = deque(self._spans, maxlen=self._capacity)
            self.enabled = True
        return self

    def disable(self) -> "Tracer":
        self.enabled = False
        return self

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()

    # -- recording ------------------------------------------------------------

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def current(self) -> Optional[int]:
        """The calling thread's innermost open context-span id."""
        st = getattr(self._tls, "stack", None)
        return st[-1] if st else None

    def emit(self, name: str, cat: str = "", *, ts: int, dur: int,
             proc: int = 0, stream: Optional[str] = None,
             chunk: Optional[int] = None, parent: Optional[int] = None,
             args: Optional[dict] = None) -> int:
        """Record one COMPLETE span whose interval the caller already
        measured (the hot-path form: the executor's stage wrapper and
        the broker flushes time themselves regardless of tracing).
        ``parent`` defaults to the calling thread's innermost open
        context span."""
        if parent is None:
            parent = self.current()
        sid = next(self._ids)
        span = Span(sid, parent, name, cat, int(ts), int(dur),
                    int(proc), threading.get_ident(), stream, chunk,
                    args)
        with self._lock:
            self._spans.append(span)
        return sid

    def open(self, name: str, cat: str = "", *,
             stream: Optional[str] = None, chunk: Optional[int] = None,
             parent: Optional[int] = None,
             args: Optional[dict] = None) -> Span:
        """Open a span now; close it later with ``close``.  Used for
        long-lived roots (one executor run) whose children are emitted
        from other threads against an explicit parent id."""
        if parent is None:
            parent = self.current()
        span = Span(next(self._ids), parent, name, cat,
                    time.perf_counter_ns(), -1, 0,
                    threading.get_ident(), stream, chunk, args)
        with self._lock:
            self._spans.append(span)
        return span

    def close(self, span: Span, args: Optional[dict] = None) -> None:
        span.dur = time.perf_counter_ns() - span.ts
        if args:
            span.args = {**(span.args or {}), **args}

    @contextmanager
    def span(self, name: str, cat: str = "", *,
             stream: Optional[str] = None, chunk: Optional[int] = None,
             args: Optional[dict] = None):
        """Context-manager span; nested spans on the same thread parent
        to it automatically.  Callers still guard with ``if
        TRACER.enabled:`` so the disabled path allocates nothing."""
        if not self.enabled:
            yield None
            return
        sp = self.open(name, cat, stream=stream, chunk=chunk, args=args)
        st = self._stack()
        st.append(sp.sid)
        c0 = time.thread_time_ns()
        try:
            yield sp
        finally:
            st.pop()
            sp.proc = time.thread_time_ns() - c0
            self.close(sp)

    # -- reading / export -----------------------------------------------------

    def snapshot(self) -> List[Span]:
        with self._lock:
            return list(self._spans)

    def export_jsonl(self, path: str) -> int:
        """One span per line (open spans exported with ``dur_ns=-1``).
        Returns the number of spans written."""
        spans = sorted(self.snapshot(), key=lambda s: s.ts)
        with open(path, "w") as f:
            for s in spans:
                f.write(json.dumps(s.to_dict()) + "\n")
        return len(spans)

    def export_chrome(self, path: str) -> int:
        """Chrome trace format (JSON array of events): one pid lane per
        stream (unattributed spans land on pid 0 "(shared)"), tid = the
        emitting thread, timestamps in microseconds sorted ascending.
        Open in chrome://tracing or Perfetto."""
        spans = sorted(self.snapshot(), key=lambda s: s.ts)
        pids: Dict[str, int] = {}
        events: List[dict] = []
        for s in spans:
            lane = s.stream if s.stream is not None else "(shared)"
            pid = pids.setdefault(lane, len(pids))
            args = dict(s.args or {})
            if s.chunk is not None:
                args["chunk"] = s.chunk
            if s.proc:
                args["thread_cpu_ms"] = round(s.proc / 1e6, 4)
            events.append({
                "name": s.name, "cat": s.cat or "span", "ph": "X",
                "ts": s.ts / 1e3, "dur": max(s.dur, 0) / 1e3,
                "pid": pid, "tid": s.tid, "args": args,
            })
        meta = [{"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                 "args": {"name": lane}}
                for lane, pid in pids.items()]
        with open(path, "w") as f:
            json.dump(meta + events, f)
        return len(events)


TRACER = Tracer()


def enable(capacity: Optional[int] = None) -> Tracer:
    """Turn tracing on (module-level convenience)."""
    return TRACER.enable(capacity)


def disable() -> Tracer:
    return TRACER.disable()


def enabled() -> bool:
    return TRACER.enabled


def export_jsonl(path: str) -> int:
    return TRACER.export_jsonl(path)


def export_chrome(path: str) -> int:
    return TRACER.export_chrome(path)
