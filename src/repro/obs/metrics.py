"""Metrics: one namespaced counter/gauge/histogram registry for the
whole pipeline, plus the shared stage-timing assembly and the
per-watermark drift monitors.

Before this module each subsystem grew its own ad-hoc counters —
``Detector.dispatches``, the executor's ``stage_seconds`` dicts, the
standing queries' ``rows_scanned``, the store's eviction totals — with
no way to read them in one place or compare them across runs.  The
registry is the aggregate source of truth: instrumented sites keep
their per-instance attributes (tests and benchmarks assert against
those, bit-compatible) AND fold every increment into a namespaced
registry metric, so ``REGISTRY.snapshot()`` is the whole system's
state in one dict.

Naming scheme (full table in src/repro/obs/README.md):

  ``executor.dispatch.{proxy,detect,track}``   device dispatches
  ``executor.stage.{name}.{wall,process}_seconds``   stage histograms
  ``detector.dispatches``                      every detect_batch call
  ``broker.{detect,track}.{dispatches,units_in}``  consolidated calls
  ``broker.{detect,track}.fill``               per-flush occupancy
  ``stream.append.{wall,store,standing}_seconds``  live-path latencies
  ``stream.watermark_lag_seconds``             store-landing lag
  ``stream.watermark[{dataset}/{clip}]``       per-clip gauges
  ``query.{scan,ingest}_seconds``              per-query split
  ``query.clips.{scanned,skipped,indexed}``    plan-phase counters
  ``standing.rows_{scanned,skipped}``          delta-fold exactness
  ``store.{evictions,evicted_bytes}``          budget enforcement

Counters and gauges are always on (one lock + int per event, far off
any per-frame path); histograms retain a bounded window.  ``reset()``
zeroes values IN PLACE so call sites may cache metric objects at import
time.
"""
from __future__ import annotations

import math
import threading
from collections import deque
from typing import Dict, List, Mapping, Optional, Sequence

__all__ = ["Counter", "Gauge", "Histogram", "Provider", "Registry",
           "REGISTRY", "RunProfile", "DriftMonitor", "stage_block",
           "merge_stage_blocks", "assert_stage_sane", "interp_quantile",
           "drift_enabled", "enable_drift", "disable_drift"]

# wall and thread-CPU clocks have independent resolutions; a stage sum
# may lag its wall sum by at most this before assert_stage_sane trips
_CLOCK_SLACK = 2e-3


class Counter:
    """Monotone (but settable, for bench resets) integer metric."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0                     # guarded-by: _lock

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    def set(self, v: int) -> None:
        with self._lock:
            self._value = int(v)

    def reset(self) -> None:
        self.set(0)

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins float metric (queue depths, watermark lag)."""

    __slots__ = ("_value",)

    def __init__(self):
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    def reset(self) -> None:
        self._value = 0.0

    @property
    def value(self) -> float:
        return self._value


def interp_quantile(sorted_vals: Sequence[float], q: float) -> float:
    """Linearly interpolated quantile over an already-sorted sequence
    (the PR-4 ``latency_report`` convention: an even-length list's
    median averages the two middle values rather than reporting the
    upper one).  Shared by ``Histogram.summary`` and the SLO engine."""
    if not sorted_vals:
        return 0.0
    pos = q * (len(sorted_vals) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = pos - lo
    return sorted_vals[lo] * (1 - frac) + sorted_vals[hi] * frac


class Histogram:
    """Running count/sum/min/max plus a bounded window of recent
    observations for percentile summaries.  ``summary()`` quantiles are
    computed over the retained window (default 4096 samples)."""

    __slots__ = ("_lock", "count", "total", "min", "max", "_window")

    def __init__(self, window: int = 4096):
        self._lock = threading.Lock()
        self.count = 0                      # guarded-by: _lock
        self.total = 0.0                    # guarded-by: _lock
        self.min = math.inf                 # guarded-by: _lock
        self.max = -math.inf                # guarded-by: _lock
        self._window: deque = deque(maxlen=window)  # guarded-by: _lock

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
            self._window.append(v)

    def reset(self) -> None:
        with self._lock:
            self.count = 0
            self.total = 0.0
            self.min = math.inf
            self.max = -math.inf
            self._window.clear()

    def window(self) -> List[float]:
        """Copy of the retained observation window (newest last) — the
        SLO engine's rolling-quantile input."""
        with self._lock:
            return list(self._window)

    def summary(self) -> dict:
        # min/max (and everything else) are read under the lock: a
        # concurrent observe() between unlocked reads could report a
        # max from a sample the count does not include (the PR-9 race)
        with self._lock:
            count, total = self.count, self.total
            vmin, vmax = self.min, self.max
            vals = sorted(self._window)
        if not count:
            return {"count": 0}
        return {
            "count": count,
            "mean": total / count,
            "min": vmin,
            "max": vmax,
            "p50": interp_quantile(vals, 0.50),
            "p95": interp_quantile(vals, 0.95),
            "p99": interp_quantile(vals, 0.99),
        }

    @property
    def value(self) -> dict:
        return self.summary()


class Provider:
    """Callable-backed read-only metric: ``value`` invokes the
    registered callable at snapshot time (DriftMonitor summaries ride
    the registry this way — nothing is copied per append, the snapshot
    reads the live monitor).  ``reset()`` is a no-op: the provider's
    source owns its state.  A failing callable yields ``None`` rather
    than breaking ``snapshot()``."""

    __slots__ = ("_fn",)

    def __init__(self):
        self._fn = None

    def set_fn(self, fn) -> None:
        self._fn = fn

    def reset(self) -> None:
        pass

    @property
    def value(self):
        fn = self._fn
        if fn is None:
            return None
        try:
            return fn()
        except Exception:
            return None


class Registry:
    """Name -> metric.  ``counter``/``gauge``/``histogram`` create on
    first use and return the same object thereafter (a name keeps its
    kind: asking for a different kind under the same name raises)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}   # guarded-by: _lock

    def _get(self, name: str, kind, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = kind(**kw)
            elif not isinstance(m, kind):
                raise TypeError(
                    f"metric {name!r} is {type(m).__name__}, "
                    f"not {kind.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, window: int = 4096) -> Histogram:
        return self._get(name, Histogram, window=window)

    def provider(self, name: str, fn) -> Provider:
        """Register (or re-point) a callable-backed metric: its current
        return value appears under ``name`` in ``snapshot()``.  Last
        registration wins — a re-opened stream's fresh DriftMonitor
        replaces the sealed one's under the same instance label."""
        p = self._get(name, Provider)
        p.set_fn(fn)
        return p

    def get(self, name: str):
        """The live metric object registered under ``name`` (None when
        absent) — lets readers reach ``Histogram.window()`` without
        touching registry internals."""
        with self._lock:
            return self._metrics.get(name)

    def snapshot(self, prefix: str = "") -> dict:
        """{name: value} for counters/gauges, {name: summary dict} for
        histograms; optionally filtered by name prefix."""
        with self._lock:
            items = sorted(self._metrics.items())
        return {name: m.value for name, m in items
                if name.startswith(prefix)}

    def reset(self, prefix: str = "") -> None:
        """Zero matching metrics IN PLACE (cached references stay
        valid)."""
        with self._lock:
            items = list(self._metrics.items())
        for name, m in items:
            if name.startswith(prefix):
                m.reset()


REGISTRY = Registry()

# drift collection costs a little numpy per PROXY chunk (per-frame
# positive-cell fractions), so it is opt-in like tracing
_DRIFT_ENABLED = False


def enable_drift() -> None:
    global _DRIFT_ENABLED
    _DRIFT_ENABLED = True


def disable_drift() -> None:
    global _DRIFT_ENABLED
    _DRIFT_ENABLED = False


def drift_enabled() -> bool:
    return _DRIFT_ENABLED


# ---------------------------------------------------------------------------
# Stage-timing assembly — the ONE place RunResult/AppendReport blocks
# are built and folded (executor.finish builds, the benches merge)
# ---------------------------------------------------------------------------

def stage_block(wall: Mapping[str, float],
                proc: Mapping[str, float]) -> Dict[str, Dict[str, float]]:
    """Assemble the ``stage_seconds`` block carried by ``RunResult`` and
    ``AppendReport``: stage -> {"wall": s, "process": s}."""
    return {s: {"wall": float(wall[s]), "process": float(proc.get(s, 0.0))}
            for s in wall}


def empty_stage_block(stages: Sequence[str]) -> Dict[str, Dict[str, float]]:
    return {s: {"wall": 0.0, "process": 0.0} for s in stages}


def merge_stage_blocks(blocks) -> Dict[str, Dict[str, float]]:
    """Sum any iterable of ``stage_seconds`` blocks (None entries are
    skipped) — the aggregation the benches previously hand-rolled."""
    out: Dict[str, Dict[str, float]] = {}
    for block in blocks:
        if not block:
            continue
        for st, d in block.items():
            e = out.setdefault(st, {"wall": 0.0, "process": 0.0})
            e["wall"] += d.get("wall", 0.0)
            e["process"] += d.get("process", 0.0)
    return out


def assert_stage_sane(block: Optional[Mapping[str, Mapping[str, float]]],
                      slack: float = _CLOCK_SLACK) -> None:
    """Per stage, thread-CPU seconds can never exceed wall seconds
    (each stage call's CPU is measured on the thread that ran it over
    the same interval as its wall clock) — a violation means the
    assembly double-counted.  ``slack`` absorbs clock resolution."""
    for st, d in (block or {}).items():
        wall, proc = d.get("wall", 0.0), d.get("process", 0.0)
        assert wall + slack >= proc, \
            f"stage {st!r}: process {proc:.4f}s exceeds wall " \
            f"{wall:.4f}s — stage timing was double-counted"
        assert wall >= 0.0 and proc >= 0.0, (st, d)


class RunProfile:
    """Per-run stage timings + dispatch counters: the single source the
    executor's ``RunResult`` (and through it the ingestor's
    ``AppendReport``) reads its ``stage_seconds``/``dispatches`` blocks
    from.  Thread-safe — decode may run on several pool workers."""

    __slots__ = ("_lock", "wall", "proc", "disp")

    def __init__(self, stages: Sequence[str]):
        self._lock = threading.Lock()
        self.wall = {s: 0.0 for s in stages}    # guarded-by: _lock
        self.proc = {s: 0.0 for s in stages}    # guarded-by: _lock
        self.disp: Dict[str, int] = {}          # guarded-by: _lock

    def note_stage(self, name: str, wall: float, proc: float) -> None:
        with self._lock:
            self.wall[name] += wall
            self.proc[name] += proc

    def dispatch(self, name: str, n: int = 1) -> None:
        with self._lock:
            self.disp[name] = self.disp.get(name, 0) + n

    def dispatches(self, name: str) -> int:
        with self._lock:
            return self.disp.get(name, 0)

    def stage_seconds(self) -> Dict[str, Dict[str, float]]:
        with self._lock:
            return stage_block(self.wall, self.proc)

    def publish(self, registry: Registry = REGISTRY,
                prefix: str = "executor") -> None:
        """Fold this run's totals into the global registry (called once
        per run by ``ClipExecutor.finish``)."""
        with self._lock:
            wall, proc = dict(self.wall), dict(self.proc)
            disp = dict(self.disp)
        for st in wall:
            registry.histogram(
                f"{prefix}.stage.{st}.wall_seconds").observe(wall[st])
            registry.histogram(
                f"{prefix}.stage.{st}.process_seconds").observe(proc[st])
        for name, n in disp.items():
            registry.counter(f"{prefix}.dispatch.{name}").inc(n)


# ---------------------------------------------------------------------------
# Drift monitors (per-watermark, per-stream) — the future online
# tuner's input: has the content this θ was tuned for moved?
# ---------------------------------------------------------------------------

class DriftMonitor:
    """Per-watermark proxy-score and track-count distributions with a
    current-vs-trailing-window delta.

    Every ``observe`` records one watermark's mean proxy positive-cell
    fraction (how much of the frame the proxy wants detected — the
    paper's θ sweeps move exactly this) and the visible track count.
    ``summary()`` reports histograms over the retained window plus, for
    each quantity, the mean over the most recent ``window`` watermarks
    minus the mean over the ``trailing`` watermarks before them — a
    persistent non-zero delta is content drift, the signal Chameleon
    re-tunes on."""

    def __init__(self, window: int = 8, trailing: int = 32,
                 proxy_bins: int = 10):
        self.window = max(1, int(window))
        self.trailing = max(1, int(trailing))
        self.proxy_bins = int(proxy_bins)
        self._lock = threading.Lock()
        # guarded-by: _lock
        self._entries: deque = deque(maxlen=self.window + self.trailing)

    def observe(self, watermark: int,
                proxy_fracs: Optional[Sequence[float]] = None,
                track_count: Optional[int] = None) -> None:
        pf = None
        if proxy_fracs is not None and len(proxy_fracs):
            pf = float(sum(proxy_fracs) / len(proxy_fracs))
        with self._lock:
            self._entries.append((int(watermark), pf, track_count))

    def _delta(self, vals: List[float]) -> dict:
        cur = vals[-self.window:]
        trail = vals[:-self.window][-self.trailing:]
        out = {"mean": sum(vals) / len(vals),
               "current_mean": sum(cur) / len(cur)}
        if trail:
            tm = sum(trail) / len(trail)
            out["trailing_mean"] = tm
            out["delta"] = out["current_mean"] - tm
        return out

    def _hist(self, vals: List[float], lo: float, hi: float,
              bins: int) -> List[int]:
        counts = [0] * bins
        width = (hi - lo) / bins if hi > lo else 1.0
        for v in vals:
            counts[min(bins - 1, max(0, int((v - lo) / width)))] += 1
        return counts

    def summary(self) -> dict:
        with self._lock:
            entries = list(self._entries)
        if not entries:
            return {"watermarks": 0}
        out: dict = {"watermarks": len(entries),
                     "last_watermark": entries[-1][0]}
        proxy = [e[1] for e in entries if e[1] is not None]
        tracks = [float(e[2]) for e in entries if e[2] is not None]
        if proxy:
            out["proxy_score"] = self._delta(proxy)
            out["proxy_score"]["hist"] = self._hist(
                proxy, 0.0, 1.0, self.proxy_bins)
        if tracks:
            out["track_count"] = self._delta(tracks)
            hi = max(tracks) + 1.0
            out["track_count"]["hist"] = self._hist(
                tracks, 0.0, hi, min(10, int(hi)))
        return out

    def drifted(self, proxy_tol: float = 0.1,
                tracks_tol: float = 2.0) -> bool:
        """True when either distribution's current-window mean moved
        beyond tolerance vs the trailing window."""
        s = self.summary()
        p = abs(s.get("proxy_score", {}).get("delta", 0.0))
        t = abs(s.get("track_count", {}).get("delta", 0.0))
        return p > proxy_tol or t > tracks_tol
