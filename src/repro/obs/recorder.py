"""Flight recorder: a bounded on-disk JSONL ring plus a crash black
box.

The ring (``ring-NNNNNN.jsonl`` segment files under one directory,
oldest segment deleted when the segment cap is hit) holds whatever the
serving plane feeds it — closed spans, metric deltas between scrapes,
fired alert events — so an operator can reconstruct the minutes before
an incident without having had tracing exporters wired up in advance.

``dump()`` is the black box: on an executor/ingestor/query exception it
writes ``dump-NNNNNN.json`` with the failing span's lineage (the open
span stack of the crashing thread, walked parent-by-parent), the last
``span_tail`` closed spans, a full registry snapshot, the traceback,
and — on the ingest path — the tracker-checkpoint sidecar path an
operator resumes from.  The SAME exception propagating through nested
hooks (ingestor append -> executor finish) produces ONE dump: the
first hook writes it, later hooks merge their context into it.

Hooks call the module-level :func:`crash_dump`, which is a no-op until
:func:`install` has attached a recorder — failure paths stay free for
every program that never asked for a black box, and a broken recorder
never turns a pipeline crash into a different crash (every disk error
is swallowed).
"""
from __future__ import annotations

import json
import os
import threading
import time
import traceback
from typing import Dict, List, Optional

from .metrics import REGISTRY, Registry
from .trace import TRACER, Tracer

__all__ = ["FlightRecorder", "install", "uninstall", "active",
           "crash_dump"]

_RING_PREFIX = "ring-"
_DUMP_PREFIX = "dump-"


class FlightRecorder:
    """Bounded JSONL ring + crash dumps under one directory.

    ``segment_records`` caps records per ring segment file and
    ``segments`` caps the number of segment files kept, so the ring's
    disk footprint is bounded no matter how long the fleet runs.
    ``span_tail`` is how many recent closed spans a crash dump
    carries."""

    def __init__(self, root: str, segment_records: int = 2048,
                 segments: int = 4, span_tail: int = 128):
        self.root = root
        self.segment_records = max(1, int(segment_records))
        self.segments = max(1, int(segments))
        self.span_tail = max(1, int(span_tail))
        os.makedirs(root, exist_ok=True)
        self._lock = threading.Lock()
        existing = self._ring_files()
        self._seg = (int(existing[-1][len(_RING_PREFIX):-6]) + 1
                     if existing else 0)     # guarded-by: _lock
        self._seg_count = 0                  # guarded-by: _lock
        self._last_sid = 0                   # guarded-by: _lock
        self._last_values: Dict[str, object] = {}   # guarded-by: _lock
        self._dump_n = 0                     # guarded-by: _lock
        self._dumped: Dict[int, str] = {}    # guarded-by: _lock

    # -- ring -----------------------------------------------------------------

    def _ring_files(self) -> List[str]:
        try:
            names = os.listdir(self.root)
        except FileNotFoundError:
            return []
        return sorted(n for n in names if n.startswith(_RING_PREFIX)
                      and n.endswith(".jsonl"))

    # holds-lock: _lock
    def _write(self, rec: dict) -> None:
        if self._seg_count >= self.segment_records:
            self._seg += 1
            self._seg_count = 0
        path = os.path.join(self.root,
                            f"{_RING_PREFIX}{self._seg:06d}.jsonl")
        if self._seg_count == 0:
            for stale in self._ring_files()[:-(self.segments - 1) or None]:
                if stale != os.path.basename(path):
                    try:
                        os.remove(os.path.join(self.root, stale))
                    except OSError:
                        pass
        with open(path, "a") as f:
            f.write(json.dumps(rec, default=str) + "\n")
        self._seg_count += 1

    def record(self, kind: str, **payload) -> None:
        """Append one ring record: ``{"kind": ..., "t": ..., **payload}``."""
        rec = {"kind": kind, "t": time.time(), **payload}
        with self._lock:
            self._write(rec)

    def poll(self, tracer: Tracer = TRACER,
             registry: Registry = REGISTRY) -> Dict[str, int]:
        """Fold the system's new state into the ring: closed spans the
        ring has not seen yet, plus deltas of every numeric metric
        since the previous poll.  Called per ``/metrics`` scrape."""
        spans = [s for s in tracer.snapshot()
                 if s.dur >= 0]
        snap = registry.snapshot()
        with self._lock:
            fresh = [s for s in spans if s.sid > self._last_sid]
            if fresh:
                self._last_sid = max(s.sid for s in fresh)
            for s in fresh:
                self._write({"kind": "span", "t": s.ts, **s.to_dict()})
            delta = {}
            for name, v in snap.items():
                if not isinstance(v, (int, float)):
                    continue
                prev = self._last_values.get(name)
                if v != prev:
                    delta[name] = v
                    self._last_values[name] = v
            if delta:
                self._write({"kind": "metrics", "t": time.time(),
                             "delta": delta})
        return {"spans": len(fresh), "metrics": len(delta)}

    def record_alert(self, event: dict) -> None:
        self.record("alert", **event)

    def tail(self, n: int = 50) -> List[dict]:
        """The last ``n`` ring records, oldest first."""
        out: List[dict] = []
        with self._lock:
            files = self._ring_files()
        for name in reversed(files):
            if len(out) >= n:
                break
            try:
                with open(os.path.join(self.root, name)) as f:
                    lines = f.readlines()
            except OSError:
                continue
            recs = []
            for line in lines:
                try:
                    recs.append(json.loads(line))
                except ValueError:
                    pass
            out = recs[-(n - len(out)):] + out
        return out[-n:]

    # -- the black box --------------------------------------------------------

    def _lineage(self, tracer: Tracer) -> List[dict]:
        """The failing span's ancestry, innermost first.

        Crash hooks run in ``except`` clauses — by then the failing
        span's context manager may already have popped it off the
        thread stack and closed it.  Starting from the innermost span
        still open (``tracer.current()``), descend the crashing
        thread's newest-child chain to recover the failing span, then
        walk parent-by-parent back to the root."""
        spans = {s.sid: s for s in tracer.snapshot()}
        tid = threading.get_ident()
        sid = tracer.current()
        while True:
            child = max((s for s in spans.values()
                         if s.tid == tid and s.parent == sid),
                        key=lambda s: s.sid, default=None)
            if child is None:
                break
            sid = child.sid
        chain: List[dict] = []
        while sid is not None and sid in spans:
            s = spans[sid]
            chain.append(s.to_dict())
            sid = s.parent
        return chain

    def dump(self, reason: str, exc: Optional[BaseException] = None,
             checkpoint: Optional[str] = None,
             extra: Optional[dict] = None,
             tracer: Tracer = TRACER,
             registry: Registry = REGISTRY) -> str:
        """Write (or enrich) a crash dump and return its path.

        Dedupe: the same exception OBJECT seen again (an inner hook's
        dump propagating through an outer hook) merges the new
        reason/checkpoint/extra into the existing file instead of
        writing a second dump."""
        closed = [s.to_dict() for s in tracer.snapshot()
                  if s.dur >= 0][-self.span_tail:]
        lineage = self._lineage(tracer)
        err = None
        if exc is not None:
            err = {"type": type(exc).__name__, "message": str(exc),
                   "traceback": "".join(traceback.format_exception(
                       type(exc), exc, exc.__traceback__))}
        with self._lock:
            prior = self._dumped.get(id(exc)) if exc is not None else None
            if prior is not None and os.path.exists(prior):
                try:
                    with open(prior) as f:
                        doc = json.load(f)
                except (OSError, ValueError):
                    doc = {}
                doc.setdefault("reasons", [doc.get("reason")])
                doc["reasons"].append(reason)
                if checkpoint is not None:
                    doc["checkpoint"] = checkpoint
                if extra:
                    doc.setdefault("extra", {}).update(extra)
                if not doc.get("lineage") and lineage:
                    doc["lineage"] = lineage
                with open(prior, "w") as f:
                    json.dump(doc, f, indent=2, default=str)
                return prior
            path = os.path.join(
                self.root, f"{_DUMP_PREFIX}{self._dump_n:06d}.json")
            self._dump_n += 1
            if exc is not None:
                self._dumped[id(exc)] = path
            doc = {"reason": reason, "t": time.time(), "error": err,
                   "lineage": lineage, "spans": closed,
                   "metrics": registry.snapshot(),
                   "checkpoint": checkpoint, "extra": extra or {}}
            with open(path, "w") as f:
                json.dump(doc, f, indent=2, default=str)
            self._write({"kind": "dump", "t": time.time(),
                         "reason": reason, "path": path})
        return path

    def dumps(self) -> List[str]:
        """Paths of every crash dump written so far, oldest first."""
        try:
            names = os.listdir(self.root)
        except FileNotFoundError:
            return []
        return [os.path.join(self.root, n) for n in sorted(names)
                if n.startswith(_DUMP_PREFIX) and n.endswith(".json")]


# ---------------------------------------------------------------------------
# Module-level black-box hook surface: failure paths call crash_dump()
# unconditionally; it costs one global read until install() is called.
# ---------------------------------------------------------------------------

_RECORDER: Optional[FlightRecorder] = None


def install(recorder: FlightRecorder) -> FlightRecorder:
    """Attach the process-wide flight recorder (crash hooks activate)."""
    global _RECORDER
    _RECORDER = recorder
    return recorder


def uninstall() -> None:
    global _RECORDER
    _RECORDER = None


def active() -> Optional[FlightRecorder]:
    return _RECORDER


def crash_dump(reason: str, exc: Optional[BaseException] = None,
               checkpoint: Optional[str] = None,
               extra: Optional[dict] = None) -> Optional[str]:
    """Black-box entry point for executor/ingestor/query failure paths:
    no recorder installed -> None; a recorder that itself fails ->
    None (the original exception keeps propagating untouched)."""
    rec = _RECORDER
    if rec is None:
        return None
    try:
        return rec.dump(reason, exc, checkpoint=checkpoint, extra=extra)
    except Exception:
        return None
