"""``python -m repro.obs`` — operator CLI for the telemetry serving
plane.

Subcommands (all stdlib-only; none import jax/numpy, so they run on a
bare operator box or the dependency-free lint runner):

* ``scrape``      GET an exporter's ``/metrics`` and print it
* ``snapshot``    GET ``/snapshot`` and pretty-print the JSON
* ``tail``        print the last N flight-recorder ring records
* ``dump``        print the newest crash dump (black-box readout)
* ``serve-smoke`` self-contained exporter smoke: synthetic registry ->
  live server -> real HTTP scrapes -> exposition/health-schema
  validation -> induced crash -> flight-recorder dump on disk.  CI's
  ``obs-serve-smoke`` job runs this and uploads the artifacts.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
import urllib.error
import urllib.request
from typing import List, Optional

_DEFAULT_URL = "http://127.0.0.1:9108"

# one exposition sample line: name{labels} value
_SAMPLE_RE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^{}]*\})? "
    r"[-+]?([0-9]*\.)?[0-9]+([eE][-+]?[0-9]+)?$")


def _get(url: str, timeout: float = 5.0) -> str:
    with urllib.request.urlopen(url, timeout=timeout) as resp:
        return resp.read().decode()


def _cmd_scrape(args) -> int:
    sys.stdout.write(_get(args.url.rstrip("/") + args.path))
    return 0


def _cmd_snapshot(args) -> int:
    doc = json.loads(_get(args.url.rstrip("/") + "/snapshot"))
    json.dump(doc, sys.stdout, indent=2, default=str)
    sys.stdout.write("\n")
    return 0


def _cmd_tail(args) -> int:
    from repro.obs.recorder import FlightRecorder
    rec = FlightRecorder(args.dir)
    for r in rec.tail(args.n):
        sys.stdout.write(json.dumps(r, default=str) + "\n")
    return 0


def _cmd_dump(args) -> int:
    from repro.obs.recorder import FlightRecorder
    rec = FlightRecorder(args.dir)
    dumps = rec.dumps()
    if not dumps:
        sys.stderr.write(f"no crash dumps under {args.dir}\n")
        return 1
    with open(dumps[-1]) as f:
        sys.stdout.write(f.read().rstrip("\n") + "\n")
    return 0


def validate_exposition(text: str) -> int:
    """Every line must be a comment or a well-formed sample; returns
    the sample count (raises AssertionError otherwise)."""
    samples = 0
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        assert _SAMPLE_RE.match(line), \
            f"malformed exposition line: {line!r}"
        samples += 1
    assert samples > 0, "exposition carried no samples"
    return samples


def validate_health(doc: dict) -> None:
    """The /healthz JSON schema the CI smoke (and operators) rely on."""
    assert doc.get("status") in ("ok", "warn", "fail"), doc
    comps = doc.get("components")
    assert isinstance(comps, dict) and comps, doc
    for name, c in comps.items():
        assert c.get("status") in ("ok", "warn", "fail"), (name, c)
        for key in ("value", "warn", "fail", "metric"):
            assert key in c, (name, key)


def _smoke_registry():
    """A synthetic-but-representative registry: every metric family
    the health components and default SLO rules watch."""
    from repro.obs.metrics import Registry
    reg = Registry()
    reg.counter("stream.appends").inc(48)
    reg.counter("query.count").inc(12)
    h = reg.histogram("stream.append.wall_seconds")
    for i in range(32):
        h.observe(0.010 + 0.001 * (i % 7))
    q = reg.histogram("query.scan_seconds")
    for i in range(16):
        q.observe(0.0005 * (1 + i % 3))
    for cam in ("camA", "camB"):
        reg.gauge(f"stream.watermark[{cam}]").set(480.0)
        reg.gauge(f"stream.watermark_lag_seconds[{cam}]").set(0.25)
    reg.gauge("broker.detect.queue_depth").set(3.0)
    reg.gauge("broker.track.queue_depth").set(1.0)
    reg.gauge("executor.decode.queue_depth").set(2.0)
    reg.gauge("store.bytes").set(1.5e6)
    reg.gauge("store.budget_bytes").set(64e6)
    reg.provider(
        "stream.drift[camA]",
        lambda: {"watermarks": 8, "last_watermark": 480})
    return reg


def _cmd_serve_smoke(args) -> int:
    from repro.obs.recorder import FlightRecorder
    from repro.obs.serve import ObsServer
    from repro.obs.slo import AlertRule, SloEngine
    from repro.obs.trace import Tracer

    out = args.out
    os.makedirs(out, exist_ok=True)
    reg = _smoke_registry()
    tr = Tracer()
    tr.enable()
    rec = FlightRecorder(os.path.join(out, "flight"))
    # one rule tightened far below the synthetic latencies, so the
    # smoke also proves an alert EDGE fires and lands on the ring
    rules = [AlertRule("append_latency", "stream.append.wall_seconds",
                       objective=0.001, quantile=0.95, budget=0.01)]
    slo = SloEngine(rules, registry=reg, recorder=rec)

    with ObsServer(port=args.port, registry=reg, tracer=tr,
                   slo=slo, recorder=rec) as server:
        base = server.url
        metrics = _get(base + "/metrics")
        n = validate_exposition(metrics)
        healthz = json.loads(_get(base + "/healthz"))
        validate_health(healthz)
        snap = json.loads(_get(base + "/snapshot"))
        assert snap["metrics"]["stream.appends"] == 48, snap["metrics"]
        assert snap["metrics"]["stream.drift[camA]"]["watermarks"] == 8
        assert snap["health"]["status"] in ("ok", "warn", "fail")
        try:
            _get(base + "/nope")
        except urllib.error.HTTPError as e:
            assert e.code == 404, e.code
        else:
            raise AssertionError("unknown route did not 404")
        # the tightened SLO must have fired on the /healthz tick
        assert slo.report()["rules"]["append_latency"]["state"] \
            in ("warn", "page"), slo.report()
        assert any(r.get("kind") == "alert" for r in rec.tail(100)), \
            "alert event never reached the flight ring"
        # induced crash inside a traced span -> black-box dump
        try:
            with tr.span("stream.append", "stream", stream="camA"):
                raise ValueError("induced smoke crash")
        except ValueError as exc:
            path = rec.dump("smoke.crash", exc,
                            checkpoint="camA/ckpt.npz",
                            tracer=tr, registry=reg)
        with open(path) as f:
            dump = json.load(f)
        assert dump["error"]["type"] == "ValueError", dump["error"]
        assert dump["checkpoint"] == "camA/ckpt.npz"
        assert any(s["name"] == "stream.append"
                   for s in dump["lineage"]), dump["lineage"]

    with open(os.path.join(out, "metrics.txt"), "w") as f:
        f.write(metrics)
    with open(os.path.join(out, "healthz.json"), "w") as f:
        json.dump(healthz, f, indent=2)
    with open(os.path.join(out, "snapshot.json"), "w") as f:
        json.dump(snap, f, indent=2)
    print(f"[obs-serve-smoke] OK: {n} exposition samples, health="
          f"{healthz['status']}, dump={os.path.relpath(path, out)}")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="telemetry serving-plane CLI")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("scrape", help="GET /metrics and print it")
    p.add_argument("--url", default=_DEFAULT_URL)
    p.add_argument("--path", default="/metrics")
    p.set_defaults(fn=_cmd_scrape)

    p = sub.add_parser("snapshot", help="GET /snapshot, pretty-print")
    p.add_argument("--url", default=_DEFAULT_URL)
    p.set_defaults(fn=_cmd_snapshot)

    p = sub.add_parser("tail", help="print recent flight-ring records")
    p.add_argument("--dir", required=True,
                   help="flight-recorder directory")
    p.add_argument("-n", type=int, default=50)
    p.set_defaults(fn=_cmd_tail)

    p = sub.add_parser("dump", help="print the newest crash dump")
    p.add_argument("--dir", required=True,
                   help="flight-recorder directory")
    p.set_defaults(fn=_cmd_dump)

    p = sub.add_parser("serve-smoke",
                       help="self-contained exporter smoke (CI)")
    p.add_argument("--out", default="OBS_SMOKE",
                   help="artifact directory")
    p.add_argument("--port", type=int, default=0)
    p.set_defaults(fn=_cmd_serve_smoke)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
