"""Observability: tracing spans + metrics registry for the pipeline.

``from repro import obs`` then ``obs.enable()`` to trace,
``obs.REGISTRY.snapshot()`` to read metrics.  See obs/README.md for
the naming scheme and the no-perturbation contract.
"""
from .trace import (Span, Tracer, TRACER, enable, disable, enabled,
                    export_jsonl, export_chrome)
from .metrics import (Counter, Gauge, Histogram, Registry, REGISTRY,
                      RunProfile, DriftMonitor, stage_block,
                      empty_stage_block, merge_stage_blocks,
                      assert_stage_sane, drift_enabled, enable_drift,
                      disable_drift)

__all__ = [
    "Span", "Tracer", "TRACER", "enable", "disable", "enabled",
    "export_jsonl", "export_chrome",
    "Counter", "Gauge", "Histogram", "Registry", "REGISTRY",
    "RunProfile", "DriftMonitor", "stage_block", "empty_stage_block",
    "merge_stage_blocks", "assert_stage_sane",
    "drift_enabled", "enable_drift", "disable_drift",
]
