"""Observability: tracing spans + metrics registry for the pipeline.

``from repro import obs`` then ``obs.enable()`` to trace,
``obs.REGISTRY.snapshot()`` to read metrics.  See obs/README.md for
the naming scheme and the no-perturbation contract.

The serving plane (``obs.serve.ObsServer`` — /metrics, /healthz,
/snapshot over HTTP), the SLO engine (``obs.slo``) and the crash
flight recorder (``obs.recorder``) load lazily: importing ``repro.obs``
on the hot path pays for none of them.
"""
from .trace import (Span, Tracer, TRACER, enable, disable, enabled,
                    export_jsonl, export_chrome)
from .metrics import (Counter, Gauge, Histogram, Provider, Registry,
                      REGISTRY, RunProfile, DriftMonitor, stage_block,
                      empty_stage_block, merge_stage_blocks,
                      assert_stage_sane, interp_quantile,
                      drift_enabled, enable_drift, disable_drift)

__all__ = [
    "Span", "Tracer", "TRACER", "enable", "disable", "enabled",
    "export_jsonl", "export_chrome",
    "Counter", "Gauge", "Histogram", "Provider", "Registry", "REGISTRY",
    "RunProfile", "DriftMonitor", "stage_block", "empty_stage_block",
    "merge_stage_blocks", "assert_stage_sane", "interp_quantile",
    "drift_enabled", "enable_drift", "disable_drift",
    "serve", "slo", "recorder",
]

_LAZY_SUBMODULES = ("serve", "slo", "recorder")


def __getattr__(name: str):
    if name in _LAZY_SUBMODULES:
        import importlib
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute "
                         f"{name!r}")
