"""``ObsServer``: the background HTTP exporter.

A stdlib ``ThreadingHTTPServer`` on a daemon thread serves the routes
registered in the module-level ``ROUTES`` table (the ``@route``
decorator — the table is the lintable endpoint surface, and the mount
point the future query front end extends).  Not calling ``start()``
costs nothing: no socket, no thread, no per-request work ever runs.

Request handling only READS shared state — ``Registry.snapshot()``,
``health_report`` over it, an ``SloEngine.tick()`` (which samples
gauges and histogram windows), an optional ``FlightRecorder.poll()``
— so a scraper hammering ``/metrics`` during a 16-stream broker run
leaves tracks, dispatch counts, and the span ledger bit-identical
(tests/test_obs_serve.py).

Bind with ``port=0`` to take an ephemeral port (``.port`` reports the
bound one); the default bind address is loopback — this is an
operator surface, not a public one.
"""
from __future__ import annotations

import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Dict, Optional, Tuple

from ..metrics import REGISTRY, Registry
from ..trace import TRACER, Tracer
from .exposition import CONTENT_TYPE, render_prometheus
from .health import default_components, health_report

__all__ = ["ObsServer", "route", "ROUTES"]

# path -> handler(server) -> (status, content_type, body_bytes)
ROUTES: Dict[str, Callable[["ObsServer"], Tuple[int, str, bytes]]] = {}


def route(path: str):
    """Register a GET handler under ``path``.  Endpoint paths are part
    of the observable surface: the obs README's endpoint table and the
    ``obs-naming`` lint pass check them both directions."""
    def deco(fn):
        ROUTES[path] = fn
        return fn
    return deco


def _json_body(doc: dict) -> bytes:
    return (json.dumps(doc, indent=2, default=str) + "\n").encode()


@route("/metrics")
def _serve_metrics(server: "ObsServer") -> Tuple[int, str, bytes]:
    if server.recorder is not None:
        server.recorder.poll(server.tracer, server.registry)
    body = render_prometheus(server.registry.snapshot())
    return 200, CONTENT_TYPE, body.encode()


@route("/healthz")
def _serve_healthz(server: "ObsServer") -> Tuple[int, str, bytes]:
    if server.slo is not None:
        server.slo.tick()
    doc = health_report(server.registry.snapshot(), server.components)
    if server.slo is not None:
        doc["slo"] = server.slo.report()["rules"]
    status = 503 if doc["status"] == "fail" else 200
    return status, "application/json", _json_body(doc)


@route("/snapshot")
def _serve_snapshot(server: "ObsServer") -> Tuple[int, str, bytes]:
    if server.slo is not None:
        server.slo.tick()
    snap = server.registry.snapshot()
    doc = {
        "metrics": snap,
        "health": health_report(snap, server.components),
        "slo": server.slo.report() if server.slo is not None else None,
        "spans": len(server.tracer.snapshot())
        if server.tracer.enabled else 0,
        "serve": server.stats(),
    }
    return 200, "application/json", _json_body(doc)


class _Handler(BaseHTTPRequestHandler):
    server_version = "repro-obs"

    def do_GET(self) -> None:          # noqa: N802 (stdlib API name)
        c0 = time.thread_time()
        try:
            self._handle_get()
        finally:
            self.server.obs._account(time.thread_time() - c0)

    def _handle_get(self) -> None:
        path = self.path.split("?", 1)[0]
        fn = ROUTES.get(path)
        if fn is None:
            body = _json_body({"error": f"no route {path!r}",
                               "routes": sorted(ROUTES)})
            self._reply(404, "application/json", body)
            return
        try:
            status, ctype, body = fn(self.server.obs)
        except Exception as exc:      # a broken reader must not kill the thread
            body = _json_body({"error": f"{type(exc).__name__}: {exc}"})
            self._reply(500, "application/json", body)
            return
        self._reply(status, ctype, body)

    def _reply(self, status: int, ctype: str, body: bytes) -> None:
        self.send_response(status)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        try:
            self.wfile.write(body)
        except (BrokenPipeError, ConnectionResetError):
            pass                      # scraper went away mid-reply

    def log_message(self, fmt, *args) -> None:
        pass                          # scrapes must not spam stderr


class _Http(ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True
    obs: "ObsServer"


class ObsServer:
    """The exporter: construct, ``start()``, scrape, ``stop()``.

    Optional collaborators: ``components`` (health thresholds;
    defaults to :func:`default_components`), ``slo`` (an ``SloEngine``
    ticked per health/snapshot request), ``recorder`` (a
    ``FlightRecorder`` polled per metrics scrape)."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0, *,
                 registry: Registry = REGISTRY,
                 tracer: Tracer = TRACER,
                 components: Optional[list] = None,
                 slo=None, recorder=None):
        self.host = host
        self.requested_port = int(port)
        self.registry = registry
        self.tracer = tracer
        self.components = components if components is not None \
            else default_components()
        self.slo = slo
        self.recorder = recorder
        self._httpd: Optional[_Http] = None
        self._thread: Optional[threading.Thread] = None
        self._stats_lock = threading.Lock()
        self._requests = 0               # guarded-by: _stats_lock
        self._handler_cpu = 0.0          # guarded-by: _stats_lock

    def _account(self, cpu: float) -> None:
        with self._stats_lock:
            self._requests += 1
            self._handler_cpu += cpu

    def stats(self) -> Dict[str, float]:
        """Self-accounting: requests served and the handler threads'
        own CPU seconds (``time.thread_time`` per request), i.e. what
        serving actually costs the process.  Benchmarks read this to
        bound exporter overhead directly instead of differencing two
        noisy end-to-end timings."""
        with self._stats_lock:
            return {"requests": self._requests,
                    "handler_cpu_seconds": round(self._handler_cpu, 6)}

    @property
    def port(self) -> int:
        if self._httpd is None:
            return self.requested_port
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "ObsServer":
        if self._httpd is not None:
            return self
        httpd = _Http((self.host, self.requested_port), _Handler)
        httpd.obs = self
        self._httpd = httpd
        self._thread = threading.Thread(
            target=httpd.serve_forever, kwargs={"poll_interval": 0.1},
            daemon=True, name="repro-obs-serve")
        self._thread.start()
        return self

    def stop(self) -> None:
        httpd, th = self._httpd, self._thread
        self._httpd = self._thread = None
        if httpd is not None:
            httpd.shutdown()
            httpd.server_close()
        if th is not None:
            th.join(timeout=5.0)

    def __enter__(self) -> "ObsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
