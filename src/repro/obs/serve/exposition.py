"""Prometheus text exposition (format version 0.0.4) rendered from a
``Registry.snapshot()`` dict.

The registry's naming scheme maps onto Prometheus' naming rules
mechanically:

* dotted paths become underscore paths (``stream.appends`` ->
  ``stream_appends``);
* a per-stream instance label ``name[caldot1/train0]`` becomes a
  ``{stream="caldot1/train0"}`` label pair on the shared family name;
* histogram summaries render as Prometheus summaries — one
  ``{quantile="…"}`` sample per interpolated quantile plus ``_sum``
  and ``_count`` — min/max stay JSON-only (``/snapshot``);
* provider metrics whose value is a dict (DriftMonitor summaries) are
  not representable as flat samples and are skipped here (they ride
  ``/snapshot`` in full).

Values are ints (counters) or floats (gauges): the renderer decides
sample shape from the VALUE, so it needs no side channel about metric
kinds and works on any snapshot dict.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

__all__ = ["render_prometheus", "CONTENT_TYPE"]

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

_NAME_SANITIZE = re.compile(r"[^a-zA-Z0-9_:]")
_INSTANCE = re.compile(r"^(?P<base>[^\[\]]+)\[(?P<inst>[^\[\]]*)\]$")
_QUANTILES = ("p50", "p95", "p99")


def _split_instance(name: str) -> Tuple[str, str]:
    m = _INSTANCE.match(name)
    if m:
        return m.group("base"), m.group("inst")
    return name, ""


def _prom_name(base: str) -> str:
    out = _NAME_SANITIZE.sub("_", base)
    if out and out[0].isdigit():
        out = "_" + out
    return out


def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace(
        "\n", "\\n")


def _fmt(v: float) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    return repr(float(v))


def render_prometheus(snapshot: Dict[str, object]) -> str:
    """The snapshot as exposition text (one trailing newline; empty
    snapshot -> empty string)."""
    families: Dict[str, List[str]] = {}
    types: Dict[str, str] = {}
    for name in sorted(snapshot):
        value = snapshot[name]
        base, inst = _split_instance(name)
        fam = _prom_name(base)
        labels = ""
        if inst:
            labels = '{stream="%s"}' % _escape_label(inst)
        if isinstance(value, bool) or isinstance(value, (int, float)):
            kind = "counter" if isinstance(value, int) \
                and not isinstance(value, bool) else "gauge"
            types.setdefault(fam, kind)
            families.setdefault(fam, []).append(
                f"{fam}{labels} {_fmt(value)}")
        elif isinstance(value, dict) and "count" in value:
            types.setdefault(fam, "summary")
            lines = families.setdefault(fam, [])
            count = value.get("count", 0)
            mean = value.get("mean", 0.0)
            for key in _QUANTILES:
                if key in value:
                    q = "0." + key[1:]
                    sep = "," if labels else ""
                    inner = labels[1:-1] + sep if labels else ""
                    lines.append(
                        f'{fam}{{{inner}quantile="{q}"}} '
                        f"{_fmt(float(value[key]))}")
            lines.append(f"{fam}_sum{labels} "
                         f"{_fmt(float(mean) * count)}")
            lines.append(f"{fam}_count{labels} {int(count)}")
        # anything else (drift provider dicts, None) is JSON-only
    out: List[str] = []
    for fam in sorted(families):
        out.append(f"# TYPE {fam} {types[fam]}")
        out.extend(families[fam])
    return "\n".join(out) + ("\n" if out else "")
