"""Component health, derived from registry gauges via declarative
thresholds — the ``/healthz`` payload.

A :class:`HealthComponent` names one subsystem and the gauge (or
per-instance gauge prefix, trailing ``[``) whose current value grades
it: ``ok`` below ``warn``, ``warn`` at or above it, ``fail`` at or
above ``fail``.  ``ratio_of`` divides the watched gauge by a second
gauge first (store bytes over budget bytes).  A component whose gauge
was never registered reports ``ok`` with ``"value": None`` — a
subsystem that is not running is not unhealthy, it is absent (the
decode pool only exists in pooled runs, brokers only in broker runs).

The overall status is the worst component's; the HTTP layer maps
``ok``/``warn`` to 200 and ``fail`` to 503 so a load balancer can act
on the grade without parsing the body.

Component names are part of the observable surface: the obs README's
health-component table and the ``obs-naming`` lint pass check them
both directions.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional

__all__ = ["HealthComponent", "default_components", "health_report"]

_ORDER = {"ok": 0, "warn": 1, "fail": 2}


@dataclass(frozen=True)
class HealthComponent:
    """One graded subsystem: ``metric`` (gauge name, or prefix ending
    in ``[`` meaning "worst instance") against warn/fail thresholds."""

    name: str
    metric: str
    warn: float
    fail: float
    description: str = ""
    ratio_of: Optional[str] = None


def default_components() -> List[HealthComponent]:
    """The serving plane's stock component set — every live-path
    backpressure signal the registry already carries."""
    return [
        HealthComponent(
            "decode_pool", metric="executor.decode.queue_depth",
            warn=64.0, fail=512.0,
            description="undecoded chunks queued on the shared "
                        "DecodePool"),
        HealthComponent(
            "broker_detect", metric="broker.detect.queue_depth",
            warn=64.0, fail=512.0,
            description="detector windows waiting for a BatchBroker "
                        "flush"),
        HealthComponent(
            "broker_track", metric="broker.track.queue_depth",
            warn=64.0, fail=512.0,
            description="tracker steps waiting for a TrackBroker "
                        "flush"),
        HealthComponent(
            "ingest_lag", metric="stream.watermark_lag_seconds[",
            warn=5.0, fail=30.0,
            description="slowest stream's append wall time behind its "
                        "watermark"),
        HealthComponent(
            "store_budget", metric="store.bytes",
            ratio_of="store.budget_bytes", warn=0.9, fail=1.0,
            description="TrackStore disk footprint over its eviction "
                        "budget"),
    ]


def _value_for(component: HealthComponent,
               snapshot: Dict[str, object]) -> Optional[float]:
    metric = component.metric
    if metric.endswith("["):
        vals = [float(v) for name, v in snapshot.items()
                if name.startswith(metric[:-1] + "[")
                and isinstance(v, (int, float))]
        value = max(vals) if vals else None
    else:
        v = snapshot.get(metric)
        value = float(v) if isinstance(v, (int, float)) else None
    if value is None:
        return None
    if component.ratio_of is not None:
        denom = snapshot.get(component.ratio_of)
        if not isinstance(denom, (int, float)) or denom <= 0:
            return None
        value /= float(denom)
    return value


def health_report(snapshot: Dict[str, object],
                  components: Optional[List[HealthComponent]] = None
                  ) -> dict:
    """Grade every component against one registry snapshot.  Returns
    the ``/healthz`` document: ``{"status", "time", "components":
    {name: {"status", "value", "warn", "fail", "metric",
    "description"}}}``."""
    comps = components if components is not None \
        else default_components()
    out: Dict[str, dict] = {}
    worst = "ok"
    for c in comps:
        value = _value_for(c, snapshot)
        if value is None:
            status = "ok"
        elif value >= c.fail:
            status = "fail"
        elif value >= c.warn:
            status = "warn"
        else:
            status = "ok"
        if _ORDER[status] > _ORDER[worst]:
            worst = status
        out[c.name] = {"status": status, "value": value,
                       "warn": c.warn, "fail": c.fail,
                       "metric": c.metric,
                       "description": c.description}
    return {"status": worst, "time": time.time(), "components": out}
