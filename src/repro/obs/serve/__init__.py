"""The telemetry serving plane: an HTTP surface over ``obs.REGISTRY``.

``ObsServer`` (a background stdlib ``ThreadingHTTPServer``) exposes

* ``/metrics``  — Prometheus text exposition of the registry,
* ``/snapshot`` — the full JSON state (registry incl. drift providers
  and stage-seconds histograms, component health, SLO verdicts),
* ``/healthz``  — declarative component health (200 ok/warn, 503 fail).

Serving is strictly PULL: nothing runs, allocates, or locks until a
request arrives, and a concurrent scraper only ever reads — the
no-perturbation contract of ``repro.obs`` extends to the wire
(asserted by tests/test_obs_serve.py against a 16-stream broker run).
This is the repo's first HTTP surface, shaped so the future query
front end can mount beside these routes.
"""
from .exposition import render_prometheus
from .health import HealthComponent, default_components, health_report
from .server import ObsServer, route

__all__ = ["ObsServer", "route", "render_prometheus",
           "HealthComponent", "default_components", "health_report"]
