"""SLO engine: rolling-window quantiles + error budgets over the
metrics registry, with declarative alert rules firing structured
events.

Everything here is PULL-based: ``SloEngine.tick()`` evaluates every
rule against the registry's current state and is invoked by whoever
wants fresh verdicts (the serving plane ticks on each ``/healthz`` and
``/snapshot`` request, a test or operator script ticks directly).  No
background thread, no cost while nobody asks — the same
zero-cost-when-idle contract the rest of the obs layer keeps.

A rule watches either

* a **histogram** — its retained observation window IS the rolling
  window (``stream.append.wall_seconds``, ``query.scan_seconds``), or
* a **gauge prefix** — per-instance gauges (``stream.
  watermark_lag_seconds[...]``) are sampled into the engine's own
  bounded deque on every tick, so the rolling window spans scrapes.

Per tick a rule computes its interpolated quantile and the fraction of
window observations over the objective ("bad fraction").  The error
budget is the allowed bad fraction: ``budget_remaining = 1 -
bad/budget`` (negative = budget blown).  State transitions fire
:class:`AlertEvent` s — ``warn`` when the quantile first exceeds the
objective, ``page`` when the budget is exhausted, ``resolved`` on
recovery — which land on the flight-recorder ring and bump the
``slo.alerts_fired`` counter.  Steady breaches do NOT re-fire: an
operator sees edges, not a firehose.

Rule names are part of the observable surface: the obs README's
alert-rule table and the ``obs-naming`` lint pass check them both
directions, like span/metric names.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

from .metrics import REGISTRY, Registry, interp_quantile

__all__ = ["AlertRule", "AlertEvent", "SloEngine", "default_rules"]


@dataclass(frozen=True)
class AlertRule:
    """One declarative SLO: ``quantile`` of ``metric``'s rolling window
    must stay under ``objective``, with at most ``budget`` of the
    window's observations allowed over it.

    ``source`` is ``"histogram"`` (metric names a registry histogram)
    or ``"gauge"`` (metric is a gauge-name prefix; every matching
    per-instance gauge is sampled into a ``window``-bounded deque per
    tick)."""

    name: str
    metric: str
    objective: float
    quantile: float = 0.95
    budget: float = 0.02
    source: str = "histogram"
    window: int = 256
    min_samples: int = 4


@dataclass
class AlertEvent:
    """One structured alert edge (what the recorder ring stores)."""

    rule: str
    severity: str               # "warn" | "page" | "resolved"
    value: float                # the quantile that triggered the edge
    objective: float
    quantile: float
    bad_fraction: float
    budget_remaining: float
    at: float = field(default_factory=time.time)

    def to_dict(self) -> dict:
        return {"rule": self.rule, "severity": self.severity,
                "value": self.value, "objective": self.objective,
                "quantile": self.quantile,
                "bad_fraction": self.bad_fraction,
                "budget_remaining": self.budget_remaining,
                "at": self.at}


def default_rules() -> List[AlertRule]:
    """The live path's three latency SLOs (objectives are deliberately
    loose defaults — a deployment tightens them per camera fleet)."""
    return [
        AlertRule("ingest_watermark_lag",
                  "stream.watermark_lag_seconds[", objective=5.0,
                  quantile=0.95, source="gauge"),
        AlertRule("append_latency", "stream.append.wall_seconds",
                  objective=2.0, quantile=0.95),
        AlertRule("query_latency", "query.scan_seconds",
                  objective=0.25, quantile=0.95),
    ]


class SloEngine:
    """Evaluates a rule set against a registry on demand (``tick``)."""

    def __init__(self, rules: Optional[List[AlertRule]] = None,
                 registry: Registry = REGISTRY, recorder=None,
                 history: int = 256):
        self.rules = list(rules) if rules is not None else default_rules()
        self.registry = registry
        self.recorder = recorder
        self._lock = threading.Lock()
        self._samples: Dict[str, Deque[float]] = {
            r.name: deque(maxlen=r.window) for r in self.rules
            if r.source == "gauge"}          # guarded-by: _lock
        self._state: Dict[str, str] = {}     # guarded-by: _lock
        self._last: Dict[str, dict] = {}     # guarded-by: _lock
        self._events: Deque[AlertEvent] = deque(maxlen=history)  # guarded-by: _lock
        self._fired = REGISTRY.counter("slo.alerts_fired")

    def _window_for(self, rule: AlertRule) -> List[float]:   # holds-lock: _lock
        if rule.source == "gauge":
            snap = self.registry.snapshot(prefix=rule.metric.rstrip("["))
            buf = self._samples[rule.name]
            for name, v in sorted(snap.items()):
                if isinstance(v, (int, float)):
                    buf.append(float(v))
            return list(buf)
        m = self.registry.get(rule.metric)
        return m.window() if m is not None and hasattr(m, "window") \
            else []

    def tick(self, now: Optional[float] = None) -> List[AlertEvent]:
        """Evaluate every rule; return (and record) the alert EDGES
        this tick produced."""
        now = time.time() if now is None else now
        fired: List[AlertEvent] = []
        with self._lock:
            for rule in self.rules:
                vals = sorted(self._window_for(rule))
                n = len(vals)
                if n < rule.min_samples:
                    self._last[rule.name] = {
                        "state": self._state.get(rule.name, "ok"),
                        "samples": n}
                    continue
                q = interp_quantile(vals, rule.quantile)
                bad = sum(1 for v in vals if v > rule.objective) / n
                remaining = 1.0 - (bad / rule.budget
                                   if rule.budget > 0 else float(bad > 0))
                if q <= rule.objective:
                    state = "ok"
                elif remaining <= 0.0:
                    state = "page"
                else:
                    state = "warn"
                prev = self._state.get(rule.name, "ok")
                if state != prev:
                    sev = state if state != "ok" else "resolved"
                    ev = AlertEvent(rule.name, sev, q, rule.objective,
                                    rule.quantile, bad, remaining,
                                    at=now)
                    fired.append(ev)
                    self._events.append(ev)
                self._state[rule.name] = state
                self._last[rule.name] = {
                    "state": state, "samples": n, "value": q,
                    "objective": rule.objective,
                    "bad_fraction": bad,
                    "budget_remaining": remaining}
        if fired:
            self._fired.inc(len(fired))
            rec = self.recorder
            if rec is not None:
                for ev in fired:
                    rec.record_alert(ev.to_dict())
        return fired

    def report(self) -> dict:
        """Per-rule verdicts from the LAST tick plus recent events
        (call ``tick()`` first for fresh numbers)."""
        with self._lock:
            return {
                "rules": {r.name: dict(self._last.get(r.name,
                                                      {"state": "ok",
                                                       "samples": 0}))
                          for r in self.rules},
                "events": [e.to_dict() for e in self._events],
            }

    def recent_events(self, n: int = 50) -> List[AlertEvent]:
        with self._lock:
            return list(self._events)[-n:]
