"""Pallas TPU kernels for the perf-critical compute hot spots.

Each kernel subpackage follows the same layout:

  kernel.py — ``pl.pallas_call`` + explicit ``BlockSpec`` VMEM tiling,
              written for the TPU target (MXU-aligned tiles, sequential
              grid axes for accumulation).
  ops.py    — the public jit'd wrapper.  Dispatches to the Pallas kernel
              on TPU and to a memory-equivalent pure-jnp implementation on
              CPU (this container), so models lower identically everywhere.
  ref.py    — the pure-jnp oracle used by tests (``interpret=True`` runs
              the kernel body on CPU and is asserted allclose against it).

Kernels:
  flash_attention — block-tiled online-softmax causal attention (prefill).
  decode_attention — single-token GQA attention over a long KV cache.
  ssd_scan — Mamba2 state-space-duality chunked scan.
  proxy_score — the paper's proxy head: fused 1x1-conv + sigmoid +
                threshold producing the binary cell grid.
  window_gather — the paper's spatial skipping: gather 32-aligned windows
                  from a frame via a scalar-prefetched window table;
                  window_gather_batch gathers one size class across a
                  CHUNK of frames (the chunked engine's hot path).
  proxy_plan — fused proxy head + threshold + detector-grid mapping:
               emits the mapped positive-cell grid and per-frame plan
               stats (count + bbox) on-device, so only plan-sized
               tensors cross back to the host instead of score maps.
  assign — batched Hungarian assignment (Jonker-Volgenant shortest
           augmenting path), one (N, N) cost matrix per grid row;
           mirrors ``core.hungarian._hungarian_np`` including
           first-index tie-breaking.
"""
from __future__ import annotations

import jax

_FORCE: dict = {"mode": None}   # None=auto | "pallas" | "ref"


def set_kernel_mode(mode) -> None:
    """Force kernel dispatch: None (auto), 'pallas', or 'ref'."""
    assert mode in (None, "pallas", "ref")
    _FORCE["mode"] = mode


def use_pallas() -> bool:
    if _FORCE["mode"] == "pallas":
        return True
    if _FORCE["mode"] == "ref":
        return False
    return jax.default_backend() == "tpu"
