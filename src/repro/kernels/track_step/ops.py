"""Public fused track-step op with backend dispatch.

``track_step(...)`` computes one tracker step for K concurrent streams
in ONE dispatch: detection features, match logits, cost assembly, JV
assignment and both GRU batches (see ``kernel.py`` for the slot layout
and ``kernels/README.md`` for the contract).

Dispatch: Pallas on TPU (interpret=True when forced elsewhere); the
default CPU path is the same ``step_core`` vmapped as plain jnp, so
both paths share one algorithm bit for bit.  ``ref.py`` is the numpy
oracle.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import numpy as np

from repro.core import fastmath as fm
from repro.kernels import use_pallas
from repro.kernels.track_step.kernel import step_core, track_step_pallas

# flat operand order for the tracker heads, as produced by
# ``core.tracker._host_params``; biases are reshaped to (1, n)
PARAM_ORDER: Tuple[str, ...] = (
    "det_proj/w", "det_proj/b",
    "gru/wz", "gru/wr", "gru/wh", "gru/bz", "gru/br", "gru/bh",
    "match/w0", "match/b0", "match/w1", "match/b1")

# the log1p-of-integer-gap table as a kernel operand, (T, 1) f32
LOG1P_TABLE_2D = fm.LOG1P_TABLE[:, None]


def pack_params(np_params: Dict[str, np.ndarray]
                ) -> Tuple[np.ndarray, ...]:
    """Flatten ``_host_params`` output into the kernel operand tuple."""
    out = []
    for key in PARAM_ORDER:
        v = np.asarray(np_params[key], np.float32)
        if v.ndim == 1:
            v = v[None, :]
        out.append(v)
    return tuple(out)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


_step_vmapped = jax.jit(jax.vmap(step_core,
                                 in_axes=(0,) * 8 + (None,) * 14))


@jax.jit
def track_step(h_r, tbox_r, alive_r, te_gap_r, te_match, x, dbox, dvalid,
               thr, params, table):
    """h_r (K, Q, H), tbox_r (K, Q, 4), alive_r/te_gap_r/te_match/dvalid
    (K, Q), x (K, Q, e), dbox (K, Q, 4) f32; thr (1, 1) f32; params the
    ``pack_params`` tuple; table (T, 1) f32 (``LOG1P_TABLE_2D``).

    Returns (matched (K, Q) int32 det column per ranked row or -1,
    h_upd (K, Q, H), h_new (K, Q, H))."""
    if use_pallas():
        return track_step_pallas(h_r, tbox_r, alive_r, te_gap_r, te_match,
                                 x, dbox, dvalid, thr, params, table,
                                 interpret=_interpret())
    return _step_vmapped(h_r, tbox_r, alive_r, te_gap_r, te_match, x,
                         dbox, dvalid, thr, *params, table[:, 0])
