"""CI smoke: track_step_pallas (interpret) must match the numpy oracle
bit-for-bit (the fastmath host==device contract).

Also home of :func:`track_operands`, the random-operand builder shared
with the kernel micro-benchmarks.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.track_step import pack_params, track_step_ref
from repro.kernels.track_step.kernel import track_step_pallas
from repro.kernels.track_step.ops import LOG1P_TABLE_2D


def track_operands(rng, K, Q, H, e, M):
    """Random track-step operands honoring the slot contract (live
    tracks / valid detections as prefixes, integer te gaps)."""
    def g(*s):
        return rng.standard_normal(s).astype(np.float32)

    params = {
        "det_proj/w": g(e + 6, e) * 0.5, "det_proj/b": g(e) * 0.1,
        "gru/wz": g(e + H, H) * 0.5, "gru/wr": g(e + H, H) * 0.5,
        "gru/wh": g(e + H, H) * 0.5,
        "gru/bz": g(H) * 0.1, "gru/br": g(H) * 0.1, "gru/bh": g(H) * 0.1,
        "match/w0": g(H + e + 6, M) * 0.5, "match/b0": g(M) * 0.1,
        "match/w1": g(M, 1) * 0.5, "match/b1": g(1) * 0.1,
    }
    shapes = [(K, Q, H), (K, Q, 4), (K, Q), (K, Q), (K, Q),
              (K, Q, e), (K, Q, 4), (K, Q)]
    arrs = [np.zeros(s, np.float32) for s in shapes]
    h_r, tbox_r, alive_r, te_gap_r, te_match, x, dbox, dvalid = arrs
    for k in range(K):
        T = int(rng.integers(0, Q + 1))
        n = int(rng.integers(0, Q + 1))
        h_r[k, :T] = g(T, H) * 0.5
        tbox_r[k, :T] = rng.random((T, 4), np.float32)
        alive_r[k, :T] = 1.0
        te_gap_r[k, :T] = rng.integers(1, 9, T)
        te_match[k] = float(rng.integers(0, 9))
        x[k, :n] = g(n, e) * 0.5
        dbox[k, :n] = rng.random((n, 4), np.float32)
        dvalid[k, :n] = 1.0
    return arrs, np.full((1, 1), 0.35, np.float32), params


def smoke() -> None:
    rng = np.random.default_rng(0)
    for K, Q, H, e, M in [(2, 8, 16, 8, 16), (3, 16, 24, 16, 24)]:
        arrs, thr, np_params = track_operands(rng, K, Q, H, e, M)
        packed = pack_params(np_params)
        ref = track_step_ref(*arrs, thr, packed, LOG1P_TABLE_2D)
        pal = track_step_pallas(*[jnp.asarray(a) for a in arrs],
                                jnp.asarray(thr), packed,
                                LOG1P_TABLE_2D, interpret=True)
        for r, p in zip(ref, pal):
            np.testing.assert_array_equal(np.asarray(p), r)
