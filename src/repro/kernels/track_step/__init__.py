"""Fused device tracker step: GRU + match head + JV assignment.

One dispatch computes a whole tracker step for a padded slot layout —
detection features, relative-motion match logits, cost-matrix assembly
and the Jonker-Volgenant assignment (reusing ``kernels.assign``'s
``solve_one``), plus the GRU updates for matched and new tracks.  See
``kernels/README.md`` for the slot layout and sentinel contract.
"""
from repro.kernels.track_step.ops import (PARAM_ORDER, pack_params,
                                          track_step)
from repro.kernels.track_step.ref import track_step_ref

__all__ = ["track_step", "track_step_ref", "pack_params", "PARAM_ORDER"]
