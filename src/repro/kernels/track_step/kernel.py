"""Fused tracker-step kernel body (Pallas) and its shared jnp core.

One grid cell per stream: the slot state, the frame's padded detections
and the small tracker heads all fit in VMEM (Q <= a few hundred slots,
embed/rnn dims <= 128), so a step is a single block — detection
features, the (Q, Q) match-logit matrix, the cost assembly and the JV
assignment (``kernels.assign.solve_one``, run inline) plus both GRU
batches execute without touching HBM in between.  The batch axis (K
concurrent streams, the TrackBroker's consolidation axis) is
embarrassingly parallel.

Numerics contract: every transcendental and every multiply-add routes
through ``repro.core.fastmath``'s ``jx_*`` flavor, which is constructed
to be bit-identical to the numpy ``np_*`` flavor used by the host
tracker twins and by ``ref.py`` — that is what makes interpret == ref
exact and ``DeviceTracker`` == ``RecurrentTracker`` exact.

Slot layout (row space is RANK order — callers gather slots so live
tracks form a prefix in active-list order; dead rows trail):
  h_r      (Q, H)  GRU hidden state per ranked slot
  tbox_r   (Q, 4)  last box per ranked slot
  alive_r  (Q,)    1.0 live / 0.0 dead
  te_gap_r (Q,)    frames since the slot's last appended detection
  x        (Q, e)  crop embeddings, valid detections as a prefix
  dbox     (Q, 4)  detection boxes
  dvalid   (Q,)    1.0 real detection / 0.0 padding
  te_match (Q,)    frames since the previously processed frame
                   (broadcast scalar; 0 on the first frame)
Forbidden pairs (dead row, padding column, or match probability below
threshold) cost ``hungarian.FORBIDDEN_DEVICE``; pairs whose solved cost
is >= FORBIDDEN_DEVICE / 2 are reported unmatched (-1).  The JV solve
is restricted to the canonical ``hungarian.assoc_side`` square derived
from the LIVE/VALID counts (``solve_one``'s dynamic ``eff_n``), because
f32 JV is not padding-invariant; with that restriction, results are
invariant to the slot count (the broker pads streams to a common
bucket, the chunk scan carries max_tracks + D slots) and bit-identical
to the host's ``hungarian_device_np``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import fastmath as fm
from repro.core.fastmath import jx_matmul as _dot
from repro.core.hungarian import FORBIDDEN_DEVICE
from repro.kernels.assign.kernel import solve_one

# jax renamed TPUCompilerParams -> CompilerParams; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

_F32 = jnp.float32
_EIGHTH = np.float32(0.125)
_ONE = np.float32(1.0)
_FORBID = np.float32(FORBIDDEN_DEVICE)
_HALF_FORBID = np.float32(FORBIDDEN_DEVICE / 2)


def _det_feats(x, boxes, te, dp_w, dp_b, table):
    """jnp twin of ``RecurrentTracker._det_feats_np``."""
    extra = jnp.stack([boxes[:, 0], boxes[:, 1], boxes[:, 2], boxes[:, 3],
                       te * _EIGHTH, fm.jx_log1p_int(te, table)], axis=1)
    d = jnp.concatenate([x, extra], axis=1)
    return fm.jx_tanh(_dot(d, dp_w) + dp_b)


def _gru(h, feat, wz, wr, wh, bz, br, bh):
    """jnp twin of ``RecurrentTracker._gru_np`` (single-multiply blend)."""
    hf = jnp.concatenate([feat, h], axis=-1)
    z = fm.jx_sigmoid(_dot(hf, wz) + bz)
    r = fm.jx_sigmoid(_dot(hf, wr) + br)
    hf2 = jnp.concatenate([feat, r * h], axis=-1)
    cand = fm.jx_tanh(_dot(hf2, wh) + bh)
    return h + z * (cand - h)


def step_core(h_r, tbox_r, alive_r, te_gap_r, te_match, x, dbox, dvalid,
              thr, dp_w, dp_b, wz, wr, wh, bz, br, bh,
              m_w0, m_b0, m_w1, m_b1, table):
    """One fused tracker step for one stream (shapes as in the module
    docstring; ``thr``/``table`` per ``ops.track_step``).

    Returns (matched_r (Q,) int32 det column per ranked row or -1,
    h_upd_r (Q, H) GRU update assuming the row matched its column,
    h_new (Q, H) GRU start state per detection column)."""
    Q, H = h_r.shape
    e = x.shape[1]
    feats_m = _det_feats(x, dbox, te_match, dp_w, dp_b, table)

    # relative features + match logits (twin of _match_np)
    d = dbox[None, :, :] - tbox_r[:, None, :]
    tesafe = jnp.maximum(te_match, _ONE)[None, :, None]
    rel = jnp.concatenate([d[..., :2], d[..., :2] / tesafe, d[..., 2:]],
                          axis=-1)
    pair = jnp.concatenate([
        jnp.broadcast_to(h_r[:, None], (Q, Q, H)),
        jnp.broadcast_to(feats_m[None], (Q, Q, e)),
        rel,
    ], axis=-1)
    hid = fm.jx_tanh(_dot(pair.reshape(Q * Q, -1), m_w0) + m_b0)
    logits = (_dot(hid, m_w1) + m_b1).reshape(Q, Q)

    # cost assembly: below-threshold, dead-row and padding-column pairs
    # all cost the finite device sentinel
    probs = fm.jx_sigmoid(logits)
    cost = jnp.where(probs >= thr, _ONE - probs, _FORBID)
    ok_pair = (alive_r[:, None] > 0) & (dvalid[None, :] > 0)
    cost = jnp.where(ok_pair, cost, _FORBID)

    # restrict the solve to the canonical assoc_side square (pow2
    # bucket of the live/valid counts, floor 8) so the result matches
    # the host twin bit for bit at ANY slot count Q
    t_cnt = jnp.sum(alive_r > 0).astype(jnp.int32)
    n_cnt = jnp.sum(dvalid > 0).astype(jnp.int32)
    need = jnp.maximum(jnp.maximum(t_cnt, n_cnt), 8)
    side = jax.lax.fori_loop(
        0, 16, lambda _, s: jnp.where(s < need, s * 2, s), jnp.int32(8))
    cols = solve_one(cost, eff_n=jnp.minimum(side, Q))
    got = jnp.take_along_axis(cost, cols[:, None].astype(jnp.int32),
                              axis=1)[:, 0]
    matched_r = jnp.where(got < _HALF_FORBID, cols, -1).astype(jnp.int32)

    # GRU updates: matched rows against their solved column (within-track
    # gap te), new-track starts against every column (te = 0, h = 0);
    # rows are per-sample independent, so callers select what applies
    xg = jnp.take(x, cols, axis=0)
    bg = jnp.take(dbox, cols, axis=0)
    feats_g = _det_feats(xg, bg, te_gap_r, dp_w, dp_b, table)
    h_upd_r = _gru(h_r, feats_g, wz, wr, wh, bz, br, bh)
    feats_0 = _det_feats(x, dbox, jnp.zeros_like(te_match), dp_w, dp_b,
                         table)
    h_new = _gru(jnp.zeros_like(h_r), feats_0, wz, wr, wh, bz, br, bh)
    return matched_r, h_upd_r, h_new


def _track_step_kernel(h_ref, tbox_ref, alive_ref, te_gap_ref,
                       te_match_ref, x_ref, dbox_ref, dvalid_ref, thr_ref,
                       dpw_ref, dpb_ref, wz_ref, wr_ref, wh_ref, bz_ref,
                       br_ref, bh_ref, mw0_ref, mb0_ref, mw1_ref, mb1_ref,
                       tab_ref, matched_ref, hupd_ref, hnew_ref):
    matched, h_upd, h_new = step_core(
        h_ref[...][0], tbox_ref[...][0], alive_ref[...][0],
        te_gap_ref[...][0], te_match_ref[...][0], x_ref[...][0],
        dbox_ref[...][0], dvalid_ref[...][0], thr_ref[...][0, 0],
        dpw_ref[...], dpb_ref[...], wz_ref[...], wr_ref[...], wh_ref[...],
        bz_ref[...], br_ref[...], bh_ref[...], mw0_ref[...], mb0_ref[...],
        mw1_ref[...], mb1_ref[...], tab_ref[...][:, 0])
    matched_ref[...] = matched[None]
    hupd_ref[...] = h_upd[None]
    hnew_ref[...] = h_new[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def track_step_pallas(h_r, tbox_r, alive_r, te_gap_r, te_match, x, dbox,
                      dvalid, thr, params, table, *,
                      interpret: bool = False):
    """Batched fused step: leading K axis on the 8 stream arrays; the 12
    head parameters, the threshold (1, 1) and the log1p table (T, 1) are
    shared across the grid."""
    K, Q, H = h_r.shape
    e = x.shape[2]

    def stream(shape):
        return pl.BlockSpec((1,) + shape, lambda k: (k,) + (0,) * len(shape))

    def shared(arr):
        nd = arr.ndim
        return pl.BlockSpec(arr.shape, lambda k: (0,) * nd)

    in_specs = [
        stream((Q, H)), stream((Q, 4)), stream((Q,)), stream((Q,)),
        stream((Q,)), stream((Q, e)), stream((Q, 4)), stream((Q,)),
        shared(thr),
    ] + [shared(p) for p in params] + [shared(table)]
    return pl.pallas_call(
        _track_step_kernel,
        grid=(K,),
        in_specs=in_specs,
        out_specs=(stream((Q,)), stream((Q, H)), stream((Q, H))),
        out_shape=(jax.ShapeDtypeStruct((K, Q), jnp.int32),
                   jax.ShapeDtypeStruct((K, Q, H), _F32),
                   jax.ShapeDtypeStruct((K, Q, H), _F32)),
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL,)),
        interpret=interpret,
        name="track_step",
    )(h_r, tbox_r, alive_r, te_gap_r, te_match, x, dbox, dvalid, thr,
      *params, table)
