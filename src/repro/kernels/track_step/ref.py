"""Numpy oracle for the fused track-step kernel.

Same slot layout and operand order as ``ops.track_step``; every
transcendental and multiply-add routes through ``fastmath``'s ``np_*``
flavor and the assignment through ``hungarian.solve_device_np`` (the
f32 JV twin), so the output is bit-identical to the kernel in interpret
mode — asserted by the kernels CI gate and the property tests.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

from repro.core import fastmath as fm
from repro.core.hungarian import (FORBIDDEN_DEVICE, assoc_side,
                                  solve_device_np)

_ONE = np.float32(1.0)
_EIGHTH = np.float32(0.125)
_FORBID = np.float32(FORBIDDEN_DEVICE)
_HALF_FORBID = np.float32(FORBIDDEN_DEVICE / 2)


def _det_feats_np(x, boxes, te, dp_w, dp_b, table):
    idx = np.clip(np.asarray(te).astype(np.int32), 0, len(table) - 1)
    extra = np.stack([boxes[:, 0], boxes[:, 1], boxes[:, 2], boxes[:, 3],
                      te * _EIGHTH, table[idx]], axis=1)
    d = np.concatenate([x, extra], axis=1)
    return fm.np_tanh(fm.np_matmul(d, dp_w) + dp_b)


def _gru_np(h, feat, wz, wr, wh, bz, br, bh):
    hf = np.concatenate([feat, h], axis=-1)
    z = fm.np_sigmoid(fm.np_matmul(hf, wz) + bz)
    r = fm.np_sigmoid(fm.np_matmul(hf, wr) + br)
    hf2 = np.concatenate([feat, r * h], axis=-1)
    cand = fm.np_tanh(fm.np_matmul(hf2, wh) + bh)
    return fm.np_fmadd(z, cand - h, h)


def _step_ref_one(h_r, tbox_r, alive_r, te_gap_r, te_match, x, dbox,
                  dvalid, thr, params, table):
    dp_w, dp_b, wz, wr, wh, bz, br, bh, m_w0, m_b0, m_w1, m_b1 = params
    Q, H = h_r.shape
    e = x.shape[1]
    feats_m = _det_feats_np(x, dbox, te_match, dp_w, dp_b, table)

    d = dbox[None, :, :] - tbox_r[:, None, :]
    tesafe = np.maximum(te_match, _ONE)[None, :, None]
    rel = np.concatenate([d[..., :2], d[..., :2] / tesafe, d[..., 2:]],
                         axis=-1)
    pair = np.concatenate([
        np.broadcast_to(h_r[:, None], (Q, Q, H)),
        np.broadcast_to(feats_m[None], (Q, Q, e)),
        rel,
    ], axis=-1)
    hid = fm.np_tanh(fm.np_matmul(pair.reshape(Q * Q, -1), m_w0)
                     + m_b0)
    logits = (fm.np_matmul(hid, m_w1) + m_b1).reshape(Q, Q)

    probs = fm.np_sigmoid(logits)
    cost = np.where(probs >= thr, _ONE - probs, _FORBID)
    ok_pair = (alive_r[:, None] > 0) & (dvalid[None, :] > 0)
    cost = np.where(ok_pair, cost, _FORBID).astype(np.float32)

    # canonical assoc square from the live/valid counts (twin of the
    # kernel's dynamic eff_n restriction); rows past it report col 0
    side = min(assoc_side(int((alive_r > 0).sum()),
                          int((dvalid > 0).sum())), Q)
    cols = np.zeros((Q,), np.int32)
    cols[:side] = solve_device_np(cost[:side, :side])
    got = np.take_along_axis(cost, cols[:, None], axis=1)[:, 0]
    matched_r = np.where(got < _HALF_FORBID, cols, -1).astype(np.int32)

    feats_g = _det_feats_np(x[cols], dbox[cols], te_gap_r, dp_w, dp_b,
                            table)
    h_upd_r = _gru_np(h_r, feats_g, wz, wr, wh, bz, br, bh)
    feats_0 = _det_feats_np(x, dbox, np.zeros_like(te_match), dp_w, dp_b,
                            table)
    h_new = _gru_np(np.zeros_like(h_r), feats_0, wz, wr, wh, bz, br, bh)
    return matched_r, h_upd_r, h_new


def track_step_ref(h_r, tbox_r, alive_r, te_gap_r, te_match, x, dbox,
                   dvalid, thr, params: Sequence[np.ndarray], table
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Numpy twin of ``ops.track_step`` (same shapes, leading K axis;
    ``table`` accepts the (T, 1) operand or a flat (T,) table)."""
    table = np.asarray(table, np.float32).reshape(-1)
    thr = np.float32(np.asarray(thr).reshape(-1)[0])
    K = h_r.shape[0]
    matched = []
    h_upd = []
    h_new = []
    for k in range(K):
        m, hu, hn = _step_ref_one(
            np.asarray(h_r[k], np.float32),
            np.asarray(tbox_r[k], np.float32),
            np.asarray(alive_r[k], np.float32),
            np.asarray(te_gap_r[k], np.float32),
            np.asarray(te_match[k], np.float32),
            np.asarray(x[k], np.float32),
            np.asarray(dbox[k], np.float32),
            np.asarray(dvalid[k], np.float32),
            thr, [np.asarray(p, np.float32) for p in params], table)
        matched.append(m)
        h_upd.append(hu)
        h_new.append(hn)
    return (np.stack(matched), np.stack(h_upd), np.stack(h_new))
