"""Public batched-assignment op with backend dispatch.

``assign_batch(costs)`` takes a stack of SQUARE finite cost matrices
(K, N, N) and returns the min-cost matched column per row, (K, N) int32 —
a full permutation per matrix.  Rectangular problems and forbidden pairs
are handled by the host wrapper ``repro.core.hungarian.hungarian_batch``,
which pads to square with a finite sentinel and filters afterwards.

Dispatch: Pallas on TPU (interpret=True when forced elsewhere); the
default CPU path is the same JV solver vmapped as plain jnp, so both
paths share one algorithm and tie-breaking.
"""
from __future__ import annotations

import jax

from repro.kernels import use_pallas
from repro.kernels.assign.kernel import assign_pallas, solve_one


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


_solve_vmapped = jax.jit(jax.vmap(solve_one))


@jax.jit
def assign_batch(costs):
    """costs: (K, N, N) finite f32 (all entries < hungarian.BIG/2).

    Returns (K, N) int32: matched column per row."""
    if use_pallas():
        return assign_pallas(costs, interpret=_interpret())
    return _solve_vmapped(costs)
