"""CI smoke: assign_pallas (interpret) must match the jnp reference
bit-for-bit.  Costs are quantized to multiples of 1/64 so f32 potential
arithmetic is exact and tie-breaking must agree."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.assign.kernel import assign_pallas
from repro.kernels.assign.ref import assign_ref


def smoke() -> None:
    rng = np.random.default_rng(0)
    for K, N in [(1, 1), (3, 4), (2, 9)]:
        costs = rng.integers(0, 256, (K, N, N)).astype(np.float32) / 64.0
        got = np.asarray(assign_pallas(jnp.asarray(costs),
                                       interpret=True))
        np.testing.assert_array_equal(got, assign_ref(costs))
        for k in range(K):
            assert sorted(got[k]) == list(range(N))   # permutation
