"""Differential reference for the batched assignment kernel: each square
cost matrix solved independently by the host Jonker-Volgenant solver
(``repro.core.hungarian._hungarian_np``), float64.

The kernel contract is a FULL permutation over finite costs — forbidden
entries must be clamped to a large-but-finite sentinel well below
``hungarian.BIG`` before calling, so the host solver reports every pair.
"""
from __future__ import annotations

import numpy as np

from repro.core.hungarian import _hungarian_np


def assign_ref(costs) -> np.ndarray:
    """costs: (K, N, N) finite, all entries < hungarian.BIG/2.

    Returns (K, N) int32: matched column per row (a permutation)."""
    costs = np.asarray(costs, np.float64)
    K, N, M = costs.shape
    assert N == M, "assign kernel operates on square (padded) matrices"
    out = np.full((K, N), -1, np.int32)
    for k in range(K):
        for r, c in _hungarian_np(costs[k]):
            out[k, r] = c
    return out
