"""Batched min-cost assignment (Jonker-Volgenant) as a Pallas kernel.

One grid cell per cost matrix: the whole (N, N) matrix lives in VMEM and
the augmenting-path search runs as ``lax.while_loop``s over (N+1,)-vectors
— association matrices are tiny (N <= max_tracks = 64), so a matrix is a
single block and the batch axis is embarrassingly parallel.

The solver mirrors ``repro.core.hungarian._hungarian_np`` (potentials +
augmenting paths, first-index argmin tie-break) but runs in float32 and
returns the FULL permutation; forbidden-pair filtering happens on the
wrapper side.  Callers must clamp sentinel costs to a finite value small
enough that f32 potential updates keep real cost differences resolvable
(see ``hungarian.hungarian_batch``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def solve_one(cost, eff_n=None):
    """cost: (N, N) finite f32 -> (N,) int32 matched column per row.

    Jonker-Volgenant with 1-indexed potential vectors, exactly the
    update order of ``_hungarian_np`` (so equal-cost tie-breaking
    matches the numpy path when the arithmetic is exact).

    ``eff_n`` (dynamic int32, default the static N) restricts the solve
    to the leading (eff_n, eff_n) submatrix: rows past it are skipped,
    columns past it never enter an argmin, so every f32 potential
    update touches exactly the values a direct (eff_n, eff_n) solve
    would — BIT-identical results regardless of the padded size N.
    That matters because JV arithmetic is NOT padding-invariant: a
    forced forbidden match pushes sentinel-scale deltas through the
    potentials, and f32 rounding of real-cost differences then depends
    on which padding columns the search walked.  Rows at or past
    ``eff_n`` report column 0."""
    N = cost.shape[0]
    a = jnp.pad(cost.astype(jnp.float32), ((1, 0), (1, 0)))  # row/col 0 dummy
    rows1 = jnp.arange(N + 1, dtype=jnp.int32)
    eff = jnp.int32(N) if eff_n is None else \
        jnp.asarray(eff_n, jnp.int32)
    col_ok = rows1 <= eff

    def outer(i, carry):
        u, v, p = carry
        # skipped rows (i > eff_n) park p[0] at 0: both while loops'
        # conditions are then false on entry, so the row is a no-op —
        # crucially WITHOUT lax.cond, which vmap turns into a select
        # that executes the loop body even for skipped rows (and an
        # all-masked argmin then never terminates)
        p = p.at[0].set(jnp.where(i <= eff, i, 0))

        def scan_cond(c):
            j0, _u, _v, _way, _minv, _used = c
            return p[j0] != 0

        def scan_body(c):
            j0, u, v, way, minv, used = c
            used = used.at[j0].set(True)
            i0 = p[j0]
            cur = a[i0] - u[i0] - v                      # (N+1,)
            free = ~used
            take = free & (cur < minv)
            minv = jnp.where(take, cur, minv)
            way = jnp.where(take, j0, way)
            masked = jnp.where(free & col_ok, minv, jnp.inf)
            j1 = jnp.argmin(masked).astype(jnp.int32)    # first index on ties
            delta = masked[j1]
            # u[p[j]] += delta over used columns j (matched rows are
            # distinct, so the O(N^2) membership mask is a safe scatter)
            row_hit = ((p[None, :] == rows1[:, None]) & used[None, :]).any(1)
            u = jnp.where(row_hit, u + delta, u)
            v = jnp.where(used, v - delta, v)
            minv = jnp.where(free, minv - delta, minv)
            return j1, u, v, way, minv, used

        j0, u, v, way, _, _ = jax.lax.while_loop(
            scan_cond, scan_body,
            (jnp.int32(0), u, v, jnp.zeros(N + 1, jnp.int32),
             jnp.full(N + 1, jnp.inf, jnp.float32),
             jnp.zeros(N + 1, bool)))

        def aug_body(c):
            j0, p = c
            j1 = way[j0]
            return j1, p.at[j0].set(p[j1])

        _, p = jax.lax.while_loop(lambda c: c[0] != 0, aug_body, (j0, p))
        return u, v, p

    u0 = jnp.zeros(N + 1, jnp.float32)
    p0 = jnp.zeros(N + 1, jnp.int32)
    _, _, p = jax.lax.fori_loop(1, N + 1, outer, (u0, u0, p0))
    # invert: p[j] = row matched to col j (1-indexed) -> col per row.
    # Columns past eff_n stay at p == 0; route them to the explicit
    # out-of-bounds index N so mode="drop" discards them (p - 1 would
    # be -1, which jnp WRAPS to the last row before the bounds check),
    # leaving skipped rows at col 0 like the numpy twin
    idx = jnp.where(p[1:] > 0, p[1:] - 1, jnp.int32(N))
    return jnp.zeros(N, jnp.int32).at[idx].set(
        jnp.arange(N, dtype=jnp.int32), mode="drop")


def _assign_kernel(cost_ref, out_ref):
    out_ref[...] = solve_one(cost_ref[...][0])[None, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def assign_pallas(costs, *, interpret: bool = False):
    """costs: (K, N, N) finite f32 -> (K, N) int32 column per row."""
    K, N, M = costs.shape
    assert N == M, "assign kernel operates on square (padded) matrices"
    return pl.pallas_call(
        _assign_kernel,
        grid=(K,),
        in_specs=[pl.BlockSpec((1, N, N), lambda k: (k, 0, 0))],
        out_specs=pl.BlockSpec((1, N), lambda k: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((K, N), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL,)),
        interpret=interpret,
        name="assign",
    )(costs.astype(jnp.float32))
