from repro.kernels.assign.ops import assign_batch  # noqa: F401
