"""Mamba2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

The SSD insight: the linear recurrence over a CHUNK of Q tokens can be
rewritten as dense matmuls (MXU work) plus a tiny sequential state carry
between chunks:

  intra:  y  = [(C @ B^T) .* decay_mask] @ (dt * x)        (Q,Q)@(Q,P)
  inter:  y += exp(L) .* (C @ state^T)                      (Q,N)@(N,P)
  carry:  state = exp(L_Q) * state + (x * w)^T @ B          (P,Q)@(Q,N)

with L the within-chunk cumulative log-decay and w_j = exp(L_Q - L_j)*dt_j.

Tiling: grid = (B, H, S/Q) with the chunk axis SEQUENTIAL; the (P, N) fp32
state lives in VMEM scratch and carries across chunks.  Q = chunk 128 and
P, N multiples of 8 keep all three matmuls MXU-aligned.  B/C are shared
across heads (n_groups = 1): their blocks are indexed by (b, c) only, so
Mosaic re-fetches them once per head sweep rather than per (head, chunk).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref,
                y_ref, fin_ref, state_ref, *, Q: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, :, 0:1].astype(jnp.float32)       # (Q, 1) — see ops.py
    Bc = b_ref[0, :, :].astype(jnp.float32)          # (Q, N)
    Cc = c_ref[0, :, :].astype(jnp.float32)          # (Q, N)
    A = a_ref[0]                                     # scalar (SMEM)
    Dh = d_ref[0]

    s = dt * A                                       # (Q, 1) log-decays
    L = jnp.cumsum(s, axis=0)                        # (Q, 1)
    # decay_mask[t, j] = exp(L_t - L_j) for j <= t else 0
    diff = L - L.reshape(1, Q)                       # (Q, Q)
    ti = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ji = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    mask = ji <= ti
    decay = jnp.where(mask, jnp.exp(diff), 0.0)

    G = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, Q)
    G = G * decay
    xdt = x * dt                                      # (Q, P)
    y = jax.lax.dot_general(G, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, P)

    state = state_ref[...]                            # (P, N)
    y += jnp.exp(L) * jax.lax.dot_general(
        Cc, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)           # (Q, P)
    y += Dh * x
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state carry
    LQ = L[Q - 1]                                     # scalar-ish (1,)
    w = jnp.exp(LQ - L) * dt                          # (Q, 1)
    state_new = jnp.exp(LQ) * state + jax.lax.dot_general(
        x * w, Bc, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # (P, N)
    state_ref[...] = state_new

    @pl.when(ci == n_chunks - 1)
    def _fin():
        fin_ref[0, 0, :, :] = state_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x, dt, A, B, C, D, *, chunk: int = 128,
                    interpret: bool = False):
    """x: (b, S, H, P); dt: (b, S, H); A, D: (H,); B, C: (b, S, N).

    Returns (y (b,S,H,P), final_state (b,H,P,N) fp32).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    n_chunks = S // Q
    grid = (b, H, n_chunks)

    kernel = functools.partial(_ssd_kernel, Q=Q, n_chunks=n_chunks)
    y, fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda bi, h, c: (bi, c, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda bi, h, c: (bi, c, h)),
            pl.BlockSpec((1,), lambda bi, h, c: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, Q, N), lambda bi, h, c: (bi, c, 0)),
            pl.BlockSpec((1, Q, N), lambda bi, h, c: (bi, c, 0)),
            pl.BlockSpec((1,), lambda bi, h, c: (h,),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda bi, h, c: (bi, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bi, h, c: (bi, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((b, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                                 pltpu.ARBITRARY)),
        interpret=interpret,
        name="ssd_scan",
    )(x, dt.astype(jnp.float32), A.astype(jnp.float32), B, C,
      D.astype(jnp.float32))
    return y, fin
