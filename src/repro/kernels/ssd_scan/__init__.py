from repro.kernels.ssd_scan.ops import ssd_scan, ssd_step  # noqa: F401
