"""Public SSD-scan op with backend dispatch, plus the O(1) decode step.

CPU fallback: the same chunked math as the kernel, vectorized over
(batch, heads) with a lax.scan over chunks — peak temp memory is
O(b * H * Q^2) per chunk, never O(S^2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import use_pallas
from repro.kernels.ssd_scan.kernel import ssd_scan_pallas


def _chunked_jnp(x, dt, A, B, C, D, chunk: int):
    b, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    n = S // Q
    xf = x.astype(jnp.float32).reshape(b, n, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(b, n, Q, H)
    Bf = B.astype(jnp.float32).reshape(b, n, Q, N)
    Cf = C.astype(jnp.float32).reshape(b, n, Q, N)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))

    ti = jnp.arange(Q)[:, None]
    ji = jnp.arange(Q)[None, :]
    tri = ji <= ti                                     # (Q, Q)

    def step(state, inp):                              # state (b,H,P,N)
        xc, dtc, Bc, Cc = inp                          # (b,Q,H,P) etc.
        s = dtc * A[None, None, :]                     # (b,Q,H)
        L = jnp.cumsum(s, axis=1)                      # (b,Q,H)
        diff = L[:, :, None, :] - L[:, None, :, :]     # (b,Q,Q,H)
        decay = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        G = jnp.einsum("btn,bsn->bts", Cc, Bc)         # (b,Q,Q)
        M = G[..., None] * decay                       # (b,t,s,H)
        xdt = xc * dtc[..., None]                      # (b,Q,H,P)
        y = jnp.einsum("btsh,bshp->bthp", M, xdt)
        y += jnp.exp(L)[..., None] * jnp.einsum(
            "btn,bhpn->bthp", Cc, state)
        y += D[None, None, :, None] * xc
        LQ = L[:, -1, :]                               # (b,H)
        w = jnp.exp(LQ[:, None, :] - L) * dtc          # (b,Q,H)
        state = jnp.exp(LQ)[..., None, None] * state + jnp.einsum(
            "bshp,bsn->bhpn", xc * w[..., None], Bc)
        return state, y

    init = jnp.zeros((b, H, P, N), jnp.float32)
    final, ys = jax.lax.scan(step, init, xs)           # ys (n,b,Q,H,P)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, S, H, P).astype(x.dtype)
    return y, final


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 128):
    """Mamba2 SSD over a full sequence.

    x: (b, S, H, P); dt: (b, S, H) post-softplus; A: (H,) negative;
    B, C: (b, S, N); D: (H,).  Returns (y, final_state (b,H,P,N) fp32).

    S is padded up to a chunk multiple with dt=0 steps (decay exp(0)=1 and
    zero input update), which leaves y and the final state exact.
    """
    S = x.shape[1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    if use_pallas():
        y, fin = ssd_scan_pallas(x, dt, A, B, C, D, chunk=chunk)
    else:
        y, fin = _chunked_jnp(x, dt, A, B, C, D, chunk)
    return (y[:, :S] if pad else y), fin


@jax.jit
def ssd_step(state, x_t, dt_t, A, B_t, C_t, D):
    """Single-token decode update (no kernel needed: O(P*N) per head).

    state: (b, H, P, N) fp32; x_t: (b, H, P); dt_t: (b, H);
    B_t, C_t: (b, N).  Returns (y_t (b,H,P), new_state).
    """
    a = jnp.exp(dt_t.astype(jnp.float32) * A[None, :])          # (b,H)
    upd = (dt_t[..., None, None] * x_t.astype(jnp.float32)[..., :, None]
           * B_t.astype(jnp.float32)[:, None, None, :])
    state = a[..., None, None] * state + upd
    y = jnp.einsum("bhpn,bn->bhp", state, C_t.astype(jnp.float32))
    y = y + D[None, :, None] * x_t
    return y.astype(x_t.dtype), state
