"""CI smoke: ssd_scan_pallas (interpret) vs the jnp oracle."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_scan_ref


def smoke() -> None:
    for b, S, H, P, N, Q in [(2, 64, 4, 16, 8, 16),
                             (1, 128, 2, 32, 16, 32)]:
        ks = jax.random.split(jax.random.PRNGKey(3), 6)
        x = jax.random.normal(ks[0], (b, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H))) * 0.5
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        B = jax.random.normal(ks[3], (b, S, N)) * 0.5
        C = jax.random.normal(ks[4], (b, S, N)) * 0.5
        D = jax.random.normal(ks[5], (H,)) * 0.1
        yr, sr = ssd_scan_ref(x, dt, A, B, C, D)
        yp, sp = ssd_scan_pallas(x, dt, A, B, C, D, chunk=Q,
                                 interpret=True)
        np.testing.assert_allclose(np.asarray(yp), np.asarray(yr),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(sp), np.asarray(sr),
                                   atol=1e-4)
