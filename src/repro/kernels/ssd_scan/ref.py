"""Pure-jnp oracle for the Mamba2 SSD scan: the direct per-timestep
recurrence (O(S) sequential steps — slow but unambiguous).

Per head h with state S_t in R^{P x N}:
    a_t = exp(dt_t * A_h)                       (A_h < 0)
    S_t = a_t * S_{t-1} + dt_t * (x_t outer B_t)
    y_t = S_t @ C_t + D_h * x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, A, B, C, D, init_state=None):
    """x: (b, S, H, P); dt: (b, S, H) post-softplus; A: (H,) negative;
    B, C: (b, S, N) (single group); D: (H,).

    Returns (y, final_state): y (b, S, H, P), final_state (b, H, P, N).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    if init_state is None:
        init_state = jnp.zeros((b, H, P, N), jnp.float32)

    def step(state, inp):
        xt, dtt, Bt, Ct = inp               # (b,H,P), (b,H), (b,N), (b,N)
        a = jnp.exp(dtt * A[None, :])       # (b,H)
        upd = (dtt[..., None, None] * xt[..., :, None]
               * Bt[:, None, None, :])       # (b,H,P,N)
        state = a[..., None, None] * state + upd
        y = jnp.einsum("bhpn,bn->bhp", state, Ct) \
            + D[None, :, None] * xt
        return state, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    final, ys = jax.lax.scan(step, init_state, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)    # (b, S, H, P)
    return y, final
