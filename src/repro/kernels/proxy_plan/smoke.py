"""CI smoke: proxy_plan_pallas (interpret) must match the jnp reference
bit-for-bit (the plan fast paths depend on identical mapped grids)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.proxy_plan.kernel import proxy_plan_pallas
from repro.kernels.proxy_plan.ops import span_matrix
from repro.kernels.proxy_plan.ref import proxy_plan_ref


def smoke() -> None:
    rng = np.random.default_rng(0)
    for B, hp, wp, C, hc, wc in [(2, 20, 32, 16, 5, 8),
                                 (3, 6, 8, 16, 9, 11)]:
        feat = rng.standard_normal((B, hp, wp, C)).astype(np.float32)
        w = rng.standard_normal(C).astype(np.float32)
        span_y = jnp.asarray(span_matrix(hc, hp))
        span_x = jnp.asarray(span_matrix(wc, wp))
        gp, sp = proxy_plan_pallas(feat, w, 0.1, 0.5, span_y, span_x,
                                   interpret=True)
        gr, sr = proxy_plan_ref(feat, w, 0.1, 0.5, span_y, span_x)
        np.testing.assert_array_equal(np.asarray(gp), np.asarray(gr))
        np.testing.assert_array_equal(np.asarray(sp), np.asarray(sr))
