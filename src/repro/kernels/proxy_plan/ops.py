"""Public fused proxy-plan op with backend dispatch.

``proxy_plan(feat, w, b, threshold, grid_hw=(hc, wc))`` fuses the proxy
head (1x1 conv + sigmoid + threshold), the proxy->detector grid mapping
of ``pipeline.map_proxy_grid``, and the per-frame plan-stat reduction
into one device dispatch, so only the (B, hc, wc) int8 grid and a
(B, 8) int32 stats row cross back to the host — replacing the
score -> host -> ``map_proxy_grid`` -> ``plan_chunk`` round-trip over
the full (B, hp, wp) score map.

The span matrices replicate ``map_proxy_grid``'s source-span index
arithmetic exactly; both backends produce grids bit-identical to the
host path (integer span counts are exact in f32).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import use_pallas
from repro.kernels.proxy_plan.kernel import proxy_plan_pallas
from repro.kernels.proxy_plan.ref import proxy_plan_ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.lru_cache(maxsize=None)
def span_matrix(n_dst: int, n_src: int) -> np.ndarray:
    """(n_dst, n_src) 0/1 f32: row i covers ``map_proxy_grid``'s source
    span [ys_i, ye_i) of destination cell i."""
    idx = np.arange(n_dst)
    ys = np.minimum((idx * n_src) // n_dst, n_src - 1)
    ye = np.minimum(((idx + 1) * n_src + n_src - 1) // n_dst, n_src)
    ye = np.maximum(ye, ys + 1)
    src = np.arange(n_src)
    return ((src[None, :] >= ys[:, None])
            & (src[None, :] < ye[:, None])).astype(np.float32)


@functools.partial(jax.jit, static_argnames=("grid_hw",))
def proxy_plan(feat, w, b, threshold, *, grid_hw):
    """feat: (B, hp, wp, C) proxy features; w: (C,); b, threshold:
    scalars; grid_hw: static (hc, wc) detector grid.

    Returns (mapped (B, hc, wc) int8, stats (B, 8) int32 rows
    [count, ymin, ymax, xmin, xmax, 0, 0, 0] over the mapped grid)."""
    hc, wc = grid_hw
    _, hp, wp, _ = feat.shape
    span_y = jnp.asarray(span_matrix(hc, hp))
    span_x = jnp.asarray(span_matrix(wc, wp))
    if use_pallas():
        return proxy_plan_pallas(feat, w, b, threshold, span_y, span_x,
                                 interpret=_interpret())
    return proxy_plan_ref(feat, w, b, threshold, span_y, span_x)
