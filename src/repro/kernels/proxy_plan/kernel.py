"""Fused proxy-score + threshold + window-plan grid as a Pallas kernel.

Extends the ``proxy_score`` fusion one stage further (§3.3 -> §3.4): the
positive-cell grid never leaves the device.  One grid cell per frame:

  matvec head (MXU) -> sigmoid -> threshold        (as proxy_score)
  span_y @ pos @ span_x^T > 0                      (map to detector grid)
  count + bbox reduction over the mapped grid      (plan stats)

The span matrices are 0/1 constants from ``map_proxy_grid``'s index
arithmetic, so the two small matmuls compute exact integer span-counts —
"any positive in span" is count > 0, bit-identical to the host
integral-image path.  The (B, 8) int32 stats row [count, ymin, ymax,
xmin, xmax, 0, 0, 0] lets the host planner emit the window list for the
common single-cluster case without touching the grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.proxy_plan.ref import STATS_W

# jax renamed TPUCompilerParams -> CompilerParams; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _plan_kernel(f_ref, w_ref, b_ref, t_ref, sy_ref, sx_ref,
                 grid_ref, stats_ref):
    f = f_ref[...][0].astype(jnp.float32)               # (hp, wp, C)
    hp, wp, C = f.shape
    w = w_ref[...].astype(jnp.float32)                  # (C, 1)
    logits = jax.lax.dot_general(
        f.reshape(hp * wp, C), w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0] + b_ref[0]
    s = jax.nn.sigmoid(logits)
    pos = (s > t_ref[0]).astype(jnp.float32).reshape(hp, wp)
    sy = sy_ref[...]                                    # (hc, hp)
    sx = sx_ref[...]                                    # (wc, wp)
    cnt = jax.lax.dot_general(
        sy, pos, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)             # (hc, wp)
    cnt = jax.lax.dot_general(
        cnt, sx, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)             # (hc, wc)
    mapped = cnt > 0.5
    hc, wc = mapped.shape
    grid_ref[...] = mapped.astype(jnp.int8)[None]
    ri = jax.lax.broadcasted_iota(jnp.int32, (hc, wc), 0)
    ci = jax.lax.broadcasted_iota(jnp.int32, (hc, wc), 1)
    count = jnp.sum(mapped.astype(jnp.int32))
    ymin = jnp.min(jnp.where(mapped, ri, hc))
    ymax = jnp.max(jnp.where(mapped, ri, -1))
    xmin = jnp.min(jnp.where(mapped, ci, wc))
    xmax = jnp.max(jnp.where(mapped, ci, -1))
    zero = count * 0
    stats_ref[...] = jnp.stack(
        [count, ymin, ymax, xmin, xmax, zero, zero, zero])[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def proxy_plan_pallas(feat, w, b, threshold, span_y, span_x, *,
                      interpret: bool = False):
    """feat: (B, hp, wp, C); w: (C,); b, threshold: scalars;
    span_y: (hc, hp) f32; span_x: (wc, wp) f32.

    Returns (mapped (B, hc, wc) int8, stats (B, STATS_W) int32)."""
    B, hp, wp, C = feat.shape
    hc, wc = span_y.shape[0], span_x.shape[0]
    return pl.pallas_call(
        _plan_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, hp, wp, C), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((C, 1), lambda i: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((hc, hp), lambda i: (0, 0)),
            pl.BlockSpec((wc, wp), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, hc, wc), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, STATS_W), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, hc, wc), jnp.int8),
            jax.ShapeDtypeStruct((B, STATS_W), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL,)),
        interpret=interpret,
        name="proxy_plan",
    )(feat, w.reshape(C, 1),
      jnp.asarray(b, jnp.float32).reshape(1),
      jnp.asarray(threshold, jnp.float32).reshape(1),
      span_y.astype(jnp.float32), span_x.astype(jnp.float32))
