from repro.kernels.proxy_plan.ops import proxy_plan, span_matrix  # noqa: F401
