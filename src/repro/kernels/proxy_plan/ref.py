"""Pure-jnp oracle for the fused proxy-plan kernel.

Head (1x1 conv + sigmoid + threshold) exactly as ``proxy_score_ref``,
then the proxy->detector grid mapping of ``pipeline.map_proxy_grid``
expressed as two 0/1 span-matrix contractions: span-any == span-count > 0
and counts are small integers, exact in f32, so the mapped grid is
bit-identical to the host integral-image path.  Per-frame plan stats
(positive count + bounding box on the mapped grid) ride along so the host
planner can take its fast paths without re-reducing the grid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

STATS_W = 8     # [count, ymin, ymax, xmin, xmax, 0, 0, 0]


def proxy_plan_ref(feat, w, b, threshold, span_y, span_x):
    """feat: (B, hp, wp, C); w: (C,); b, threshold: scalars;
    span_y: (hc, hp) f32 0/1; span_x: (wc, wp) f32 0/1.

    Returns (mapped (B, hc, wc) int8 detector grid,
             stats (B, STATS_W) int32)."""
    logits = jnp.einsum("bhwc,c->bhw", feat.astype(jnp.float32),
                        w.astype(jnp.float32)) + b
    pos = (jax.nn.sigmoid(logits) > threshold).astype(jnp.float32)
    cnt = jnp.einsum("yh,bhw->byw", span_y, pos)
    cnt = jnp.einsum("byw,xw->byx", cnt, span_x)
    mapped = cnt > 0.5
    hc, wc = span_y.shape[0], span_x.shape[0]
    yi = jnp.arange(hc, dtype=jnp.int32)
    xi = jnp.arange(wc, dtype=jnp.int32)
    rows_any = mapped.any(axis=2)
    cols_any = mapped.any(axis=1)
    count = mapped.sum(axis=(1, 2)).astype(jnp.int32)
    ymin = jnp.min(jnp.where(rows_any, yi, hc), axis=1)
    ymax = jnp.max(jnp.where(rows_any, yi, -1), axis=1)
    xmin = jnp.min(jnp.where(cols_any, xi, wc), axis=1)
    xmax = jnp.max(jnp.where(cols_any, xi, -1), axis=1)
    zero = jnp.zeros_like(count)
    stats = jnp.stack([count, ymin, ymax, xmin, xmax, zero, zero, zero],
                      axis=1).astype(jnp.int32)
    return mapped.astype(jnp.int8), stats
