"""CI smoke: flash_attention_pallas (interpret) vs the jnp oracle."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref

_TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def smoke() -> None:
    for dtype in (jnp.float32, jnp.bfloat16):
        for B, Sq, Skv, Hq, Hkv, D, causal in [
                (2, 128, 128, 4, 2, 64, True),
                (1, 64, 256, 4, 4, 32, False)]:
            ks = jax.random.split(jax.random.PRNGKey(0), 3)
            q = jax.random.normal(ks[0], (B, Sq, Hq, D)).astype(dtype)
            k = jax.random.normal(ks[1], (B, Skv, Hkv, D)).astype(dtype)
            v = jax.random.normal(ks[2], (B, Skv, Hkv, D)).astype(dtype)
            ref = flash_attention_ref(q, k, v, causal=causal)
            pal = flash_attention_pallas(q, k, v, causal=causal,
                                         block_q=64, block_k=64,
                                         interpret=True)
            tol = _TOL[dtype]
            np.testing.assert_allclose(np.asarray(pal, np.float32),
                                       np.asarray(ref, np.float32),
                                       atol=tol, rtol=tol)
