"""Public flash-attention op with backend dispatch.

On TPU: the Pallas kernel.  Elsewhere (this CPU container, including the
512-fake-device dry-run): a memory-equivalent chunked jnp implementation —
``lax.scan`` over KV blocks with online softmax, so peak temp memory is
O(S * block) rather than O(S^2) and the dry-run's memory_analysis reflects
the flash schedule, not a naive score matrix.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import use_pallas
from repro.kernels.flash_attention.kernel import flash_attention_pallas

NEG_INF = float(jnp.finfo(jnp.float32).min)


def _chunked_jnp(q, k, v, *, causal: bool, sm_scale: float, block_k: int,
                 kv_valid: int = 0):
    """Online-softmax over KV chunks; same math as the kernel.
    kv_valid > 0 masks KV positions >= kv_valid (padding)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    group = Hq // Hkv
    bk = min(block_k, Skv)
    assert Skv % bk == 0
    n_blocks = Skv // bk
    qf = q.astype(jnp.float32) * sm_scale
    # fold q heads onto kv heads: (B, Sq, Hkv, group, D)
    qf = qf.reshape(B, Sq, Hkv, group, D)
    kf = k.astype(jnp.float32).reshape(B, n_blocks, bk, Hkv, D)
    vf = v.astype(jnp.float32).reshape(B, n_blocks, bk, Hkv, D)
    kf = jnp.moveaxis(kf, 1, 0)          # (n, B, bk, Hkv, D)
    vf = jnp.moveaxis(vf, 1, 0)

    qpos = jnp.arange(Sq) + (Skv - Sq)   # absolute query positions

    def step(carry, blk):
        m, l, acc = carry
        kb, vb, ki = blk
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kb)      # (B,Sq,Hkv,g,bk)
        kpos = ki * bk + jnp.arange(bk)
        if causal:
            mask = kpos[None, :] <= qpos[:, None]        # (Sq, bk)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        if kv_valid:
            s = jnp.where((kpos < kv_valid)[None, None, None, None, :],
                          s, NEG_INF)
        m_cur = s.max(axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + p.sum(axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum("bqhgk,bkhd->bqhgd", p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, Hkv, group, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, group, 1), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, group, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kf, vf, jnp.arange(n_blocks)))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l).reshape(B, Sq, Hq, D)
    return out.astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "sm_scale",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True,
                    sm_scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128):
    """Multi-head/GQA attention.  q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D).

    Softmax in fp32; output in q.dtype.  Non-block-multiple sequence
    lengths are zero-padded; padded KV columns are masked (causal padding
    on the right is self-masking, cross/bidirectional padding is masked
    via kv_valid), and padded query rows are sliced off.
    """
    if sm_scale is None:
        sm_scale = 1.0 / (q.shape[-1] ** 0.5)
    B, Sq, Hq, D = q.shape
    Skv = k.shape[1]
    pad_q = (-Sq) % min(block_q, max(Sq, 1))
    pad_k = (-Skv) % min(block_k, max(Skv, 1))
    kv_valid = Skv if pad_k else 0
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    if causal and (pad_q or pad_k) and Sq != Skv:
        # padding shifts the causal diagonal (queries sit at the END of
        # the kv axis); only same-length or unpadded cases are exercised
        raise NotImplementedError(
            "causal attention with ragged Sq != Skv padding")
    if use_pallas():
        out = flash_attention_pallas(
            q, k, v, causal=causal, sm_scale=float(sm_scale),
            block_q=block_q, block_k=block_k, kv_valid=kv_valid)
    else:
        out = _chunked_jnp(q, k, v, causal=causal,
                           sm_scale=float(sm_scale), block_k=block_k,
                           kv_valid=kv_valid)
    return out[:, :Sq] if pad_q else out
