"""Pure-jnp oracle for flash attention (naive O(S^2), materializes scores).

Used only by tests on small shapes; the memory-bounded jnp fallback lives in
ops.py and the TPU kernel in kernel.py.  All three must agree.
"""
from __future__ import annotations

import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, sm_scale=None,
                        kv_len=None):
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D); GQA by head repetition.

    kv_len: optional (B,) int32 — valid KV prefix length (decode masking).
    Returns (B, Sq, Hq, D) in q.dtype; softmax in fp32.
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kf) * sm_scale
    neg = jnp.finfo(jnp.float32).min
    if causal:
        # query i (at absolute position Skv - Sq + i) sees keys <= that pos
        qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
        kpos = jnp.arange(Skv)[None, :]
        scores = jnp.where((kpos <= qpos)[None, None], scores, neg)
    if kv_len is not None:
        mask = jnp.arange(Skv)[None, :] < kv_len[:, None]   # (B, Skv)
        scores = jnp.where(mask[:, None, None, :], scores, neg)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    return out.astype(q.dtype)


# pre-rename alias: the twin of flash_attention_pallas is named after it
attention_ref = flash_attention_ref
