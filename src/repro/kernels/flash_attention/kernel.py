"""Flash attention as a Pallas TPU kernel.

Tiling (TPU target):
  grid = (B, Hq, Sq/bq, Skv/bk); the last axis is SEQUENTIAL (ARBITRARY)
  so the online-softmax accumulators (m, l, acc) live in VMEM scratch and
  carry across KV blocks.  Q block (bq, D) stays resident in VMEM for the
  whole KV sweep; K/V stream through in (bk, D) blocks.  bq = bk = 128 keeps
  the two matmuls MXU-shaped (128 x D x 128).  GQA is expressed in the K/V
  index_map (kv head = q head // group) so K/V blocks are fetched once per
  q-head-group position rather than materializing repeated heads in HBM.

Causal skipping: blocks strictly above the diagonal contribute nothing; we
gate the FLOPs with pl.when (the block DMA for skipped blocks is still
issued by the pipeline — at most a 2x bandwidth overhead on the strictly
upper triangle and zero wasted MXU time; the ops.py wrapper additionally
shrinks the grid when Sq == Skv so fully-masked tiles are never visited).

VMEM budget per step: q(bq*D) + k,v(2*bk*D) + acc(bq*D fp32) + out(bq*D)
= at D=128, bq=bk=128: ~64 KiB*3 + 64 KiB + 64 KiB ≈ 320 KiB (double-
buffered K/V adds 2*64 KiB) — far inside the ~16 MiB v5e VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

NEG_INF = float(jnp.finfo(jnp.float32).min)


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               sm_scale: float, causal: bool, bq: int, bk: int,
               kv_blocks: int, sq: int, skv: int, kv_valid: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions (queries sit at the END of the kv axis when Sq<Skv)
    q_start = qi * bq + (skv - sq)
    k_start = ki * bk

    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)        # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)        # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)        # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale   # (bq, bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        if kv_valid:
            kpos2 = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos2 < kv_valid, s, NEG_INF)
        m_prev = m_ref[...]                               # (bq, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                            # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                   # (bq, 1)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    if causal:
        # skip blocks entirely above the diagonal
        pl.when(k_start <= q_start + bq - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)   # fully-masked rows -> 0 output
        o_ref[0, :, 0, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "sm_scale",
                                             "block_q", "block_k",
                                             "interpret", "kv_valid"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, sm_scale=None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False, kv_valid: int = 0):
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    kv_blocks = Skv // bk
    grid = (B, Hq, Sq // bq, kv_blocks)

    kernel = functools.partial(
        _fa_kernel, sm_scale=float(sm_scale), causal=causal, bq=bq, bk=bk,
        kv_blocks=kv_blocks, sq=Sq, skv=Skv, kv_valid=kv_valid)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, qi, ki: (b, ki, h // group, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, qi, ki: (b, ki, h // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, Hq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom
            pltpu.VMEM((bq, D), jnp.float32),    # fp32 accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                                 pltpu.PARALLEL, pltpu.ARBITRARY)),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
