from repro.kernels.window_gather.ops import (window_gather,  # noqa: F401
                                             window_gather_batch)
