"""Public window-gather op with backend dispatch.

The CPU fallback uses vmapped dynamic_slice (pixel origins); the Pallas
path takes 32-aligned cell origins, matching the proxy's cell grid.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import jax

from repro.kernels import use_pallas
from repro.kernels.window_gather.kernel import window_gather_pallas, CELL
from repro.kernels.window_gather.ref import window_gather_ref


@functools.partial(jax.jit, static_argnames=("win_h", "win_w", "cell"))
def window_gather(frame, cell_origins, *, win_h: int, win_w: int,
                  cell: int = CELL):
    """Crop n windows of (win_h, win_w) px from frame at cell-aligned
    origins.  frame: (H, W, C); cell_origins: (n, 2) int32 (cy, cx)."""
    if use_pallas():
        return window_gather_pallas(frame, cell_origins,
                                    win_h=win_h, win_w=win_w, cell=cell)
    return window_gather_ref(frame, cell_origins * cell,
                             win_h=win_h, win_w=win_w)
