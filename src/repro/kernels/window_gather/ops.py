"""Public window-gather ops with backend dispatch.

Two entry points:

  * ``window_gather`` — crop n same-size windows from ONE frame;
  * ``window_gather_batch`` — crop n same-size windows from a CHUNK of
    frames via a (frame, cy, cx) window table.  This is what the chunked
    execution engine calls: one dispatch per (size class, bucket) for the
    whole chunk.

Dispatch: on TPU the Pallas kernel runs natively; when the Pallas path is
forced off-TPU (``set_kernel_mode("pallas")``) the same kernel body runs
under ``interpret=True``.  The default CPU path is the memory-equivalent
vmapped ``dynamic_slice`` oracle.  The Pallas path takes cell-aligned
origins, matching the proxy's cell grid; the oracle takes pixels, so the
wrappers scale.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import jax

from repro.kernels import use_pallas
from repro.kernels.window_gather.kernel import (CELL, window_gather_pallas,
                                                window_gather_batch_pallas)
from repro.kernels.window_gather.ref import (window_gather_ref,
                                             window_gather_batch_ref)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("win_h", "win_w", "cell"))
def window_gather(frame, cell_origins, *, win_h: int, win_w: int,
                  cell: int = CELL):
    """Crop n windows of (win_h, win_w) px from frame at cell-aligned
    origins.  frame: (H, W, C); cell_origins: (n, 2) int32 (cy, cx)."""
    if use_pallas():
        return window_gather_pallas(frame, cell_origins,
                                    win_h=win_h, win_w=win_w, cell=cell,
                                    interpret=_interpret())
    return window_gather_ref(frame, cell_origins * cell,
                             win_h=win_h, win_w=win_w)


@functools.partial(jax.jit, static_argnames=("win_h", "win_w", "cell"))
def window_gather_batch(frames, window_table, *, win_h: int, win_w: int,
                        cell: int = CELL):
    """Crop n windows of (win_h, win_w) px from a chunk of frames.

    frames: (B, H, W, C); window_table: (n, 3) int32 rows
    (frame_idx, cy, cx) in CELL coordinates.  Returns
    (n, win_h, win_w, C)."""
    if use_pallas():
        return window_gather_batch_pallas(frames, window_table,
                                          win_h=win_h, win_w=win_w,
                                          cell=cell,
                                          interpret=_interpret())
    tbl = window_table * jnp.asarray([1, cell, cell], jnp.int32)
    return window_gather_batch_ref(frames, tbl, win_h=win_h, win_w=win_w)
