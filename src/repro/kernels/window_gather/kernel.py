"""Proxy-gated window gather as a Pallas TPU kernel (the paper's spatial
skipping, §3.3, as a TPU DMA pattern).

On GPU the paper batch-crops rectangular windows and feeds them to a
detector compiled at k fixed sizes.  The TPU analogue: window origins are
32-aligned by construction (the proxy scores 32x32 cells), so each window
is an integer grid of 32x32 cell tiles and the crop becomes a pure
HBM->VMEM block copy driven by a SCALAR-PREFETCHED window table — the
origin table is prefetched to SMEM before the grid runs, and the input
``index_map`` reads it to aim each block DMA.  No gather HLO, no
materialized index arrays; one DMA per 32x32x C tile.

grid = (n_windows, win_h/32, win_w/32); one pallas_call per window-size
class (the paper's "initialize the detector at each of k sizes").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CELL = 32


def _gather_kernel(tbl_ref, frame_ref, out_ref):
    del tbl_ref
    out_ref[0] = frame_ref[...]


def _gather_batch_kernel(tbl_ref, frames_ref, out_ref):
    del tbl_ref
    out_ref[...] = frames_ref[...]


@functools.partial(jax.jit, static_argnames=("win_h", "win_w", "cell",
                                             "interpret"))
def window_gather_pallas(frame, cell_origins, *, win_h: int, win_w: int,
                         cell: int = CELL, interpret: bool = False):
    """frame: (H, W, C) with H, W multiples of ``cell``; cell_origins:
    (n, 2) int32 CELL coordinates (cy, cx) of each window's top-left cell.

    Returns (n, win_h, win_w, C).  cell=32 is the paper's grid; the
    reduced CPU pipeline uses 16.
    """
    H, W, C = frame.shape
    assert H % cell == 0 and W % cell == 0, (H, W)
    assert win_h % cell == 0 and win_w % cell == 0, (win_h, win_w)
    n = cell_origins.shape[0]
    gh, gw = win_h // cell, win_w // cell

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n, gh, gw),
        in_specs=[
            pl.BlockSpec(
                (cell, cell, C),
                lambda i, gy, gx, tbl: (tbl[i, 0] + gy, tbl[i, 1] + gx, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, cell, cell, C), lambda i, gy, gx, tbl: (i, gy, gx, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, win_h, win_w, C), frame.dtype),
        interpret=interpret,
        name="window_gather",
    )(cell_origins.astype(jnp.int32), frame)


@functools.partial(jax.jit, static_argnames=("win_h", "win_w", "cell",
                                             "interpret"))
def window_gather_batch_pallas(frames, window_table, *, win_h: int,
                               win_w: int, cell: int = CELL,
                               interpret: bool = False):
    """Cross-frame window gather: crop n windows of one size class from a
    CHUNK of frames in a single pallas_call (the chunked engine's hot
    path — one call per (size class, bucket) instead of one per frame).

    frames: (B, H, W, C) with H, W multiples of ``cell``; window_table:
    (n, 3) int32 rows (frame_idx, cy, cx) — cell coordinates of each
    window's top-left corner in its source frame.  Returns
    (n, win_h, win_w, C).  The table is scalar-prefetched to SMEM so each
    32x32xC tile is still a single aimed block DMA, now indexed by frame
    as well as position.
    """
    B, H, W, C = frames.shape
    assert H % cell == 0 and W % cell == 0, (H, W)
    assert win_h % cell == 0 and win_w % cell == 0, (win_h, win_w)
    n = window_table.shape[0]
    gh, gw = win_h // cell, win_w // cell

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n, gh, gw),
        in_specs=[
            pl.BlockSpec(
                (1, cell, cell, C),
                lambda i, gy, gx, tbl: (tbl[i, 0], tbl[i, 1] + gy,
                                        tbl[i, 2] + gx, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, cell, cell, C), lambda i, gy, gx, tbl: (i, gy, gx, 0)),
    )
    return pl.pallas_call(
        _gather_batch_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, win_h, win_w, C), frames.dtype),
        interpret=interpret,
        name="window_gather_batch",
    )(window_table.astype(jnp.int32), frames)
