"""CI smoke: window_gather_pallas (interpret) must be an exact copy of
the crop slices (ref takes pixel origins, kernel takes cell coords)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.window_gather.kernel import window_gather_pallas
from repro.kernels.window_gather.ref import window_gather_ref


def smoke() -> None:
    frame = jax.random.normal(jax.random.PRNGKey(7), (160, 256, 3))
    for wh, ww in [(64, 96), (32, 32)]:
        oc = jnp.array([[0, 0], [1, 2], [2, 3]], jnp.int32)
        oc = jnp.minimum(oc, jnp.array([(160 - wh) // 32,
                                        (256 - ww) // 32]))
        ref = window_gather_ref(frame, oc * 32, win_h=wh, win_w=ww)
        pal = window_gather_pallas(frame, oc, win_h=wh, win_w=ww,
                                   interpret=True)
        np.testing.assert_array_equal(np.asarray(pal), np.asarray(ref))
