"""Pure-jnp oracle for window gathering (vmapped dynamic_slice crops)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("win_h", "win_w"))
# repro-lint: disable=kernel-contract -- ref takes pixel origins; the kernel takes cell coordinates (callers pass origin_cells * cell); units differ by contract
def window_gather_ref(frame, origins, *, win_h: int, win_w: int):
    """frame: (H, W, C); origins: (n, 2) int32 pixel (y, x) top-left corners.

    Returns (n, win_h, win_w, C) crops.  Origins must satisfy
    0 <= y <= H - win_h (the ops layer clamps; callers use 32-aligned cells).
    """
    H, W, C = frame.shape

    def crop(origin):
        y = jnp.clip(origin[0], 0, H - win_h)
        x = jnp.clip(origin[1], 0, W - win_w)
        return jax.lax.dynamic_slice(frame, (y, x, 0), (win_h, win_w, C))

    return jax.vmap(crop)(origins)


@functools.partial(jax.jit, static_argnames=("win_h", "win_w"))
def window_gather_batch_ref(frames, window_table, *, win_h: int,
                            win_w: int):
    """frames: (B, H, W, C); window_table: (n, 3) int32 rows
    (frame_idx, y_px, x_px).  Returns (n, win_h, win_w, C) crops."""
    B, H, W, C = frames.shape

    def crop(row):
        b = jnp.clip(row[0], 0, B - 1)
        y = jnp.clip(row[1], 0, H - win_h)
        x = jnp.clip(row[2], 0, W - win_w)
        return jax.lax.dynamic_slice(frames, (b, y, x, 0),
                                     (1, win_h, win_w, C))[0]

    return jax.vmap(crop)(window_table)
