"""Pure-jnp oracle for window gathering (vmapped dynamic_slice crops)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("win_h", "win_w"))
def window_gather_ref(frame, origins, *, win_h: int, win_w: int):
    """frame: (H, W, C); origins: (n, 2) int32 pixel (y, x) top-left corners.

    Returns (n, win_h, win_w, C) crops.  Origins must satisfy
    0 <= y <= H - win_h (the ops layer clamps; callers use 32-aligned cells).
    """
    H, W, C = frame.shape

    def crop(origin):
        y = jnp.clip(origin[0], 0, H - win_h)
        x = jnp.clip(origin[1], 0, W - win_w)
        return jax.lax.dynamic_slice(frame, (y, x, 0), (win_h, win_w, C))

    return jax.vmap(crop)(origins)
