"""Single-token (decode) GQA attention as a Pallas TPU kernel.

Decode attention is MEMORY-bound: every step sweeps the whole KV cache from
HBM and does O(S*D) FLOPs per head — arithmetic intensity ~1 FLOP/byte, far
below the v5e ridge (~240), so the kernel's only job is to stream K/V at
full HBM bandwidth and avoid materializing repeated GQA heads.

Tiling:
  grid = (B, Hkv, S/bk) with the KV axis SEQUENTIAL; the GQA q-group (G =
  Hq/Hkv) is packed into the MXU M dimension: q block (G, D) x k block
  (bk, D)^T -> (G, bk) scores.  bk = 512 amortizes the per-block overhead
  over a deep HBM stream.  kv_len lives in SMEM (one scalar per batch row)
  and masks the ragged tail block; pl.when skips FLOPs for fully-invalid
  blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

NEG_INF = float(jnp.finfo(jnp.float32).min)


def _dec_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                *, sm_scale: float, bk: int, kv_blocks: int):
    ki = pl.program_id(2)
    kv_len = len_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = ki * bk

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32)         # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)         # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)         # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale   # (G, bk)
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(kpos < kv_len, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sm_scale", "block_k",
                                             "interpret"))
def decode_attention_pallas(q, k, v, kv_len, *, sm_scale=None,
                            block_k: int = 512, interpret: bool = False):
    """q: (B, Hq, D); k, v: (B, S, Hkv, D); kv_len: (B,) -> (B, Hq, D)."""
    B, Hq, D = q.shape
    _, S, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    bk = min(block_k, S)
    assert S % bk == 0
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    kv_blocks = S // bk
    grid = (B, Hkv, kv_blocks)
    qg = q.reshape(B, Hkv, G, D)

    kernel = functools.partial(_dec_kernel, sm_scale=float(sm_scale),
                               bk=bk, kv_blocks=kv_blocks)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ki: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                                 pltpu.ARBITRARY)),
        interpret=interpret,
        name="decode_attention",
    )(kv_len.astype(jnp.int32), qg, k, v)
    return out.reshape(B, Hq, D)
