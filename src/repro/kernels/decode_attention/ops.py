"""Public decode-attention op with backend dispatch.

CPU fallback: a single masked einsum over the cache.  The (B, Hq, S) score
tensor is small relative to the cache itself (S*Hq*4 vs S*Hkv*D*2*2 bytes
per row), so unlike prefill no chunking is needed for memory parity.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax.numpy as jnp
import jax

from repro.kernels import use_pallas
from repro.kernels.decode_attention.kernel import decode_attention_pallas

NEG_INF = float(jnp.finfo(jnp.float32).min)


def _jnp_fallback(q, k, v, kv_len, *, sm_scale: float):
    B, Hq, D = q.shape
    _, S, Hkv, _ = k.shape
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D) * sm_scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, kf)
    mask = jnp.arange(S)[None, :] < kv_len[:, None]        # (B, S)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, vf)
    return out.reshape(B, Hq, D).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("sm_scale", "block_k"))
def decode_attention(q, k, v, kv_len, *, sm_scale: Optional[float] = None,
                     block_k: int = 512):
    """Single-token GQA attention over a KV cache.

    q: (B, Hq, D); k, v: (B, S, Hkv, D); kv_len: (B,) int32 valid lengths.
    """
    if sm_scale is None:
        sm_scale = 1.0 / (q.shape[-1] ** 0.5)
    if use_pallas():
        return decode_attention_pallas(q, k, v, kv_len,
                                       sm_scale=float(sm_scale),
                                       block_k=block_k)
    return _jnp_fallback(q, k, v, kv_len, sm_scale=float(sm_scale))
