"""Pure-jnp oracle for single-token decode attention (delegates to the
flash-attention oracle with Sq=1 and a kv_len mask)."""
from __future__ import annotations

from repro.kernels.flash_attention.ref import flash_attention_ref


def decode_attention_ref(q, k, v, kv_len, *, sm_scale=None):
    """q: (B, Hq, D); k, v: (B, S, Hkv, D); kv_len: (B,) int32.

    Returns (B, Hq, D).  Non-causal within the valid prefix (the new token
    attends to every cached position < kv_len, including itself if the
    caller already wrote it into the cache).
    """
    out = flash_attention_ref(q[:, None], k, v, causal=False,
                              sm_scale=sm_scale, kv_len=kv_len)
    return out[:, 0]
