"""CI smoke: decode_attention_pallas (interpret) vs the jnp oracle."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref

_TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def smoke() -> None:
    for dtype in (jnp.float32, jnp.bfloat16):
        for B, S, Hq, Hkv, D, bk in [(2, 256, 8, 2, 64, 64),
                                     (3, 128, 4, 4, 32, 128)]:
            ks = jax.random.split(jax.random.PRNGKey(2), 4)
            q = jax.random.normal(ks[0], (B, Hq, D)).astype(dtype)
            k = jax.random.normal(ks[1], (B, S, Hkv, D)).astype(dtype)
            v = jax.random.normal(ks[2], (B, S, Hkv, D)).astype(dtype)
            kvlen = jax.random.randint(ks[3], (B,), 1, S + 1)
            ref = decode_attention_ref(q, k, v, kvlen)
            pal = decode_attention_pallas(q, k, v, kvlen, block_k=bk,
                                          interpret=True)
            tol = _TOL[dtype]
            np.testing.assert_allclose(np.asarray(pal, np.float32),
                                       np.asarray(ref, np.float32),
                                       atol=tol, rtol=tol)
