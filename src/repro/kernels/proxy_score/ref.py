"""Pure-jnp oracle for the fused proxy head (1x1 conv + sigmoid +
threshold -> binary cell grid)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def proxy_score_ref(feat, w, b, threshold):
    """feat: (B, Hc, Wc, C) penultimate proxy features; w: (C,); b: scalar.

    Returns (scores (B, Hc, Wc) fp32 sigmoid, positive (B, Hc, Wc) int8).
    """
    logits = jnp.einsum("bhwc,c->bhw", feat.astype(jnp.float32),
                        w.astype(jnp.float32)) + b
    scores = jax.nn.sigmoid(logits)
    pos = (scores > threshold).astype(jnp.int8)
    return scores, pos
