from repro.kernels.proxy_score.ops import proxy_score  # noqa: F401
