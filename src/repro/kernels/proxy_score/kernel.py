"""Fused proxy-model head as a Pallas TPU kernel (the paper's §3.3 scorer).

The segmentation proxy ends with a 1x1 conv to one channel, a sigmoid, and
a threshold that yields the binary positive-cell grid.  Running these as
separate XLA ops costs two extra HBM round-trips of the (B, Hc, Wc) score
map; at proxy rates (every sampled frame) the head is bandwidth-bound, so
we fuse matvec + sigmoid + compare into one VMEM-resident epilogue.

Tiling: spatial cells are flattened to rows; block = (bm, C) rows of
features x a (C, 1) weight column resident in VMEM across the whole grid
(index_map pins it to block 0).  bm = 256 rows keeps the matvec in one MXU
pass per block at C <= 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _head_kernel(f_ref, w_ref, b_ref, t_ref, score_ref, pos_ref):
    f = f_ref[...].astype(jnp.float32)                  # (bm, C)
    w = w_ref[...].astype(jnp.float32)                  # (C, 1)
    logits = jax.lax.dot_general(
        f, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0] + b_ref[0]
    s = jax.nn.sigmoid(logits)
    score_ref[...] = s
    pos_ref[...] = (s > t_ref[0]).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def proxy_score_pallas(feat, w, b, threshold, *, block_m: int = 256,
                       interpret: bool = False):
    """feat: (B, Hc, Wc, C); w: (C,); b, threshold: scalars.

    Returns (scores (B, Hc, Wc) fp32, positive (B, Hc, Wc) int8).
    """
    B, Hc, Wc, C = feat.shape
    rows = B * Hc * Wc
    bm = min(block_m, rows)
    pad = (-rows) % bm
    f2 = feat.reshape(rows, C)
    if pad:
        f2 = jnp.pad(f2, ((0, pad), (0, 0)))
    n_blocks = (rows + pad) // bm

    scores, pos = pl.pallas_call(
        _head_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((bm, C), lambda i: (i, 0)),
            pl.BlockSpec((C, 1), lambda i: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows + pad,), jnp.float32),
            jax.ShapeDtypeStruct((rows + pad,), jnp.int8),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL,)),
        interpret=interpret,
        name="proxy_score",
    )(f2, w.reshape(C, 1),
      jnp.asarray(b, jnp.float32).reshape(1),
      jnp.asarray(threshold, jnp.float32).reshape(1))
    scores = scores[:rows].reshape(B, Hc, Wc)
    pos = pos[:rows].reshape(B, Hc, Wc)
    return scores, pos
