"""CI smoke: proxy_score_pallas (interpret) vs the jnp oracle."""
from __future__ import annotations

import jax
import numpy as np

from repro.kernels.proxy_score.kernel import proxy_score_pallas
from repro.kernels.proxy_score.ref import proxy_score_ref


def smoke() -> None:
    for B, Hc, Wc, C in [(2, 7, 13, 32), (3, 8, 8, 64)]:
        ks = jax.random.split(jax.random.PRNGKey(6), 2)
        feat = jax.random.normal(ks[0], (B, Hc, Wc, C))
        w = jax.random.normal(ks[1], (C,))
        sr, pr = proxy_score_ref(feat, w, 0.3, 0.5)
        sp, pp = proxy_score_pallas(feat, w, 0.3, 0.5, block_m=32,
                                    interpret=True)
        np.testing.assert_allclose(np.asarray(sp), np.asarray(sr),
                                   atol=1e-6)
        # thresholded int8 grid must be exact (plan paths depend on it)
        np.testing.assert_array_equal(np.asarray(pp), np.asarray(pr))
