"""Public fused proxy-head op with backend dispatch."""
from __future__ import annotations

import functools

import jax

from repro.kernels import use_pallas
from repro.kernels.proxy_score.kernel import proxy_score_pallas
from repro.kernels.proxy_score.ref import proxy_score_ref


@jax.jit
def proxy_score(feat, w, b, threshold):
    """Fused 1x1-conv + sigmoid + threshold -> (scores, positive grid).

    feat: (B, Hc, Wc, C); w: (C,); b, threshold: scalars.
    """
    if use_pallas():
        return proxy_score_pallas(feat, w, b, threshold)
    return proxy_score_ref(feat, w, b, threshold)
