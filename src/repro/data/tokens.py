"""Deterministic, skippable LM token pipeline.

Requirements from the fault-tolerance substrate:
  * ``batch_at(step)`` is a pure function of (seed, step) — restart/replay
    after a checkpoint restore regenerates the exact batch with no state
    (counter-based Philox, no sequential RNG);
  * shard-aware: ``batch_at(step, shard, n_shards)`` returns the rows a
    data-parallel host owns, so hosts never exchange input data.

The synthetic corpus is a fixed random BIGRAM chain per seed: token t+1 is
drawn from a sparse row distribution of token t.  This gives a learnable
signal (a trained LM beats the unigram entropy) while requiring no corpus
files — used by the ~100M-param training example to show loss descent.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np


@dataclass
class TokenPipeline:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    branching: int = 8      # successors per token in the bigram chain

    def __post_init__(self) -> None:
        rng = np.random.Generator(np.random.Philox(key=self.seed))
        # fixed sparse bigram structure: each token has `branching`
        # successors with Zipf-ish probabilities
        self._succ = rng.integers(
            0, self.vocab_size, size=(self.vocab_size, self.branching),
            dtype=np.int32)
        w = 1.0 / np.arange(1, self.branching + 1)
        self._cum = np.cumsum(w / w.sum()).astype(np.float32)

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1
                 ) -> Dict[str, np.ndarray]:
        assert self.batch % n_shards == 0
        rows = self.batch // n_shards
        rng = np.random.Generator(np.random.Philox(
            key=self.seed + 1, counter=step * n_shards + shard))
        toks = np.empty((rows, self.seq_len), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab_size, size=rows)
        u = rng.random((rows, self.seq_len), dtype=np.float32)
        for t in range(1, self.seq_len):
            choice = np.searchsorted(self._cum, u[:, t])
            toks[:, t] = self._succ[toks[:, t - 1], choice]
        return {"tokens": toks,
                "loss_mask": np.ones((rows, self.seq_len), np.int8)}

    def bigram_entropy(self) -> float:
        """Entropy (nats/token) of the chain — the floor a perfect model
        reaches; used by the example to show the LM is actually learning."""
        w = np.diff(np.concatenate([[0.0], self._cum]))
        return float(-(w * np.log(w)).sum())
