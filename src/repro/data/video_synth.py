"""Deterministic synthetic video with exact ground-truth tracks.

The container has no ffmpeg or real video, so the evaluation reproduces the
paper's WORKLOAD STRUCTURE instead of its pixels: each of the 7 dataset
profiles (caldot1, caldot2, tokyo, uav, warsaw, amsterdam, jackson) defines
a camera scene with spatial paths (lanes / turning movements), object
density, object size, and speed matching the qualitative description in
§4 (busy junctions vs sparse scenes vs aerial).  Objects are rendered as
filled rectangles with per-object color over a textured background, so a
small CNN detector is learnable but not trivial (background clutter +
additive noise).

Determinism: everything derives from counter-based Philox keyed on
(profile, split, clip, frame) — any frame can be rendered independently at
any resolution (the paper's "decode at detector resolution": rendering
cost genuinely scales with pixel count, preserving the decode-cost
structure that Chameleon/MultiScope exploit).

Ground truth per clip: full tracks (frame, cx, cy, w, h, track_id,
pattern_id), pattern counts (the paper's hand-label format), and per-frame
boxes (for MOTA).
"""
from __future__ import annotations

import math
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# world units: the native frame is 1.0 x 1.0; pixels scale at render time
Point = Tuple[float, float]


@dataclass(frozen=True)
class PathSpec:
    """One spatial pattern: a polyline from entry to exit."""
    name: str
    waypoints: Tuple[Point, ...]
    weight: float = 1.0          # relative spawn probability


@dataclass(frozen=True)
class Profile:
    name: str
    paths: Tuple[PathSpec, ...]
    spawn_rate: float            # expected objects entering per frame
    speed: Tuple[float, float]   # world units / frame (min, max)
    size: Tuple[float, float]    # object size fraction of frame (min, max)
    fps: int = 8
    n_patterns: int = 0          # 0 -> len(paths); counting granularity
    clutter: int = 6             # static background distractor rects

    def patterns(self) -> int:
        return self.n_patterns or len(self.paths)


def _line(*pts: Point) -> Tuple[Point, ...]:
    return tuple(pts)


def _interp(waypoints: Sequence[Point], t: float) -> Point:
    """t in [0, 1] along the polyline (arc-length parametrized)."""
    pts = np.asarray(waypoints, np.float64)
    seg = np.linalg.norm(np.diff(pts, axis=0), axis=1)
    total = seg.sum()
    if total <= 0:
        return tuple(pts[0])
    d = t * total
    acc = 0.0
    for i, s in enumerate(seg):
        if d <= acc + s or i == len(seg) - 1:
            u = 0.0 if s == 0 else (d - acc) / s
            p = pts[i] * (1 - u) + pts[i + 1] * u
            return float(p[0]), float(p[1])
        acc += s
    return tuple(pts[-1])


# ---------------------------------------------------------------------------
# The 7 dataset profiles
# ---------------------------------------------------------------------------

def _junction(name: str, spawn: float, speed=(0.010, 0.020),
              size=(0.055, 0.095), fps=8, turns: int = 8) -> Profile:
    """4-way junction with through + turn movements (tokyo/warsaw/jackson
    style).  Patterns = turning movements."""
    c = 0.5
    arms = {"n": (c, -0.1), "s": (c, 1.1), "w": (-0.1, c), "e": (1.1, c)}
    moves = [("n", "s"), ("s", "n"), ("w", "e"), ("e", "w"),
             ("n", "e"), ("s", "w"), ("w", "n"), ("e", "s")][:turns]
    paths = []
    for a, b in moves:
        paths.append(PathSpec(f"{a}->{b}",
                              _line(arms[a], (c, c), arms[b])))
    return Profile(name, tuple(paths), spawn, speed, size, fps)


def _highway(name: str, spawn: float, size=(0.05, 0.09),
             fps=8) -> Profile:
    paths = (
        PathSpec("nb", _line((0.35, 1.1), (0.42, -0.1))),
        PathSpec("sb", _line((0.58, -0.1), (0.65, 1.1))),
    )
    return Profile(name, paths, spawn, (0.022, 0.034), size, fps)


PROFILES: Dict[str, Profile] = {
    # highways: 2 patterns, medium density, fast small objects
    "caldot1": _highway("caldot1", spawn=0.22),
    "caldot2": _highway("caldot2", spawn=0.15, size=(0.045, 0.075)),
    # busy city junctions: objects in (almost) every frame
    "tokyo": _junction("tokyo", spawn=0.30, turns=4),
    "warsaw": _junction("warsaw", spawn=0.36, turns=8),
    # aerial drone: many small slow objects, 8 turning movements
    "uav": _junction("uav", spawn=0.25, speed=(0.006, 0.012),
                     size=(0.030, 0.050), fps=5, turns=8),
    # sparse scenes: long empty stretches (proxy models shine here)
    "amsterdam": Profile(
        "amsterdam",
        (PathSpec("quay-we", _line((-0.1, 0.62), (1.1, 0.58))),
         PathSpec("quay-ew", _line((1.1, 0.72), (-0.1, 0.76))),),
        spawn_rate=0.02, speed=(0.008, 0.014), size=(0.060, 0.100)),
    "jackson": _junction("jackson", spawn=0.03, turns=4),
}

DATASETS = tuple(PROFILES)     # the 7 evaluation datasets


# ---------------------------------------------------------------------------
# Clip generation
# ---------------------------------------------------------------------------

@dataclass
class TrackGT:
    track_id: int
    pattern_id: int
    frames: np.ndarray           # (n,) int32 frame indices
    boxes: np.ndarray            # (n, 4) fp32 (cx, cy, w, h) world units


# static background layers, one per (clip, resolution) — tiny and reused
# by every frame of a clip (the tuner re-renders the same clips at many
# resolutions, hence the cap).  The executor's decode workers render
# concurrently (one thread per in-flight clip), so mutations are locked;
# values are deterministic per key, so racing lookups at worst recompute.
_BG_CACHE: Dict[Tuple, np.ndarray] = {}
_BG_CACHE_MAX = 256
_COLOR_CACHE: Dict[Tuple, np.ndarray] = {}
_COLOR_CACHE_MAX = 8192
_CACHE_LOCK = threading.Lock()


@dataclass
class Clip:
    profile: Profile
    split: str
    clip_id: int
    n_frames: int
    tracks: List[TrackGT] = field(default_factory=list)
    _boxes_index: Optional[Dict[int, np.ndarray]] = \
        field(default=None, repr=False, compare=False)

    # -- labels ----------------------------------------------------------------
    def pattern_counts(self) -> np.ndarray:
        """The paper's hand-label format: unique objects per pattern."""
        counts = np.zeros(self.profile.patterns(), np.int64)
        for t in self.tracks:
            counts[t.pattern_id] += 1
        return counts

    def boxes_at(self, frame: int) -> np.ndarray:
        """(n, 5) [cx, cy, w, h, track_id] world units, objects visible
        in ``frame``.  Indexed once per clip (render calls this for
        every frame; scanning all tracks each time dominated it)."""
        if self._boxes_index is None:
            idx: Dict[int, List[np.ndarray]] = {}
            for t in self.tracks:
                for i, f in enumerate(t.frames):
                    idx.setdefault(int(f), []).append(np.concatenate(
                        [t.boxes[i], [float(t.track_id)]]))
            object.__setattr__(self, "_boxes_index", {
                f: np.stack(rows).astype(np.float32)
                for f, rows in idx.items()})
        return self._boxes_index.get(
            frame, np.zeros((0, 5), np.float32))

    # -- rendering ---------------------------------------------------------------
    def _background(self, width: int, height: int) -> np.ndarray:
        """Static scene layer (gradient + clutter): identical for every
        frame of a clip, so it is built once per (clip, resolution) and
        copied per frame.  Decode cost still scales with W*H (copy,
        object draws and per-frame noise are all full-frame)."""
        key = (self.profile.name, self.split, self.clip_id, width,
               height)
        with _CACHE_LOCK:
            bg = _BG_CACHE.get(key)
        if bg is not None:
            return bg
        brng = _rng(self.profile.name, self.split, self.clip_id, 3, 0)
        gx = brng.uniform(0.25, 0.45)
        gy = brng.uniform(0.25, 0.45)
        yy = np.linspace(0, 1, height, dtype=np.float32)[:, None]
        xx = np.linspace(0, 1, width, dtype=np.float32)[None, :]
        bg = (0.35 + gx * xx + gy * yy)[..., None] * np.ones(
            3, np.float32)
        # static clutter rectangles (buildings/markings)
        for _ in range(self.profile.clutter):
            cx, cy = brng.uniform(0.05, 0.95, 2)
            w, h = brng.uniform(0.04, 0.16, 2)
            col = brng.uniform(0.2, 0.8, 3).astype(np.float32)
            _draw_rect(bg, cx, cy, w, h, col, fill=0.6)
        with _CACHE_LOCK:
            _BG_CACHE[key] = bg
            if len(_BG_CACHE) > _BG_CACHE_MAX:
                _BG_CACHE.pop(next(iter(_BG_CACHE)))
        return bg

    def _track_color(self, tid: int) -> np.ndarray:
        key = (self.profile.name, self.split, self.clip_id, tid)
        with _CACHE_LOCK:
            col = _COLOR_CACHE.get(key)
        if col is None:
            crng = _rng(self.profile.name, self.split, self.clip_id, 11,
                        tid)
            col = crng.uniform(0.0, 1.0, 3).astype(np.float32)
            col[tid % 3] = 1.0               # saturated channel
            with _CACHE_LOCK:
                _COLOR_CACHE[key] = col
                if len(_COLOR_CACHE) > _COLOR_CACHE_MAX:
                    _COLOR_CACHE.pop(next(iter(_COLOR_CACHE)))
        return col

    def render(self, frame: int, width: int, height: int) -> np.ndarray:
        """(H, W, 3) float32 in [0, 1].  Cost scales with W*H (the decode
        cost model).  Deterministic per (profile, split, clip, frame);
        noise is drawn from the float32 Gaussian stream (a different —
        still deterministic — stream than the original float64 path, so
        pixels differ from pre-engine renders)."""
        rng = _rng(self.profile.name, self.split, self.clip_id, 7, frame)
        img = self._background(width, height).copy()
        # objects (per-track colors are constants — cached)
        for box in self.boxes_at(frame):
            cx, cy, w, h, tid = box
            _draw_rect(img, cx, cy, w, h,
                       self._track_color(int(tid)), fill=1.0)
        img += rng.standard_normal(img.shape, dtype=np.float32) \
            * np.float32(0.02)
        return np.clip(img, 0.0, 1.0)


def _draw_rect(img: np.ndarray, cx: float, cy: float, w: float, h: float,
               col: np.ndarray, fill: float) -> None:
    H, W = img.shape[:2]
    x0 = max(int((cx - w / 2) * W), 0)
    x1 = min(int(math.ceil((cx + w / 2) * W)), W)
    y0 = max(int((cy - h / 2) * H), 0)
    y1 = min(int(math.ceil((cy + h / 2) * H)), H)
    if x1 <= x0 or y1 <= y0:
        return
    img[y0:y1, x0:x1] = (1 - fill) * img[y0:y1, x0:x1] + fill * col


def _rng(*key_parts) -> np.random.Generator:
    # stable across processes (python str hash is randomized per process)
    import hashlib
    digest = hashlib.sha256(repr(key_parts).encode()).digest()
    h = int.from_bytes(digest[:8], "little")
    return np.random.Generator(np.random.Philox(key=h))


def make_clip(profile_name: str, split: str, clip_id: int,
              n_frames: int = 48) -> Clip:
    """Simulate object motion for one clip; exact GT tracks attached."""
    prof = PROFILES[profile_name]
    clip = Clip(prof, split, clip_id, n_frames)
    rng = _rng(profile_name, split, clip_id, 1, 0)
    weights = np.array([p.weight for p in prof.paths], np.float64)
    weights /= weights.sum()
    tid = 0
    # spawn objects over an extended window so mid-clip state is realistic
    for f0 in range(-int(1.2 / prof.speed[0]), n_frames):
        n_spawn = rng.poisson(prof.spawn_rate)
        for _ in range(n_spawn):
            pattern = int(rng.choice(len(prof.paths), p=weights))
            path = prof.paths[pattern]
            speed = rng.uniform(*prof.speed)
            size = rng.uniform(*prof.size)
            aspect = rng.uniform(0.8, 1.4)
            pts = np.asarray(path.waypoints, np.float64)
            total_len = np.linalg.norm(np.diff(pts, axis=0),
                                       axis=1).sum()
            n_steps = max(int(total_len / speed), 2)
            frames, boxes = [], []
            for s in range(n_steps + 1):
                f = f0 + s
                if f < 0 or f >= n_frames:
                    continue
                cx, cy = _interp(path.waypoints, s / n_steps)
                # visible only while inside the frame
                if not (0.0 <= cx <= 1.0 and 0.0 <= cy <= 1.0):
                    continue
                frames.append(f)
                boxes.append([cx, cy, size, size * aspect])
            if len(frames) >= 2:
                clip.tracks.append(TrackGT(
                    tid, pattern,
                    np.asarray(frames, np.int32),
                    np.asarray(boxes, np.float32)))
                tid += 1
    return clip


def make_split(profile_name: str, split: str, n_clips: int,
               n_frames: int = 48) -> List[Clip]:
    return [make_clip(profile_name, split, i, n_frames)
            for i in range(n_clips)]
