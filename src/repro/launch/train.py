"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 200 --batch 8 --seq 128 [--reduced] [--mesh d,m] \
        [--ckpt artifacts/ckpt] [--bf16-wire] [--accum 2]

Wires the full substrate: config -> model -> logical-axis shardings on the
requested mesh -> AdamW (+8-bit v option) -> jit'd train step (donated
state) -> skippable token pipeline -> crash-safe Supervisor with async
checkpointing.  On this CPU container use --reduced; on a real cluster the
same entry point runs the full configs (the dry-run proves they lower).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.distributed.checkpoint import Checkpointer
from repro.distributed.fault import Supervisor
from repro.distributed.sharding import LogicalRules, tree_shardings
from repro.launch.mesh import make_host_mesh
from repro.models.common import sharding_ctx
from repro.models.model import build_model
from repro.optim import adamw, cosine_schedule
from repro.train import build_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="1,1",
                    help="data,model axis sizes over local devices")
    ap.add_argument("--ckpt", default="artifacts/train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--bf16-wire", action="store_true")
    ap.add_argument("--quantize-v", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    dm, mm = (int(x) for x in args.mesh.split(","))
    mesh = make_host_mesh(dm, mm)
    rules = LogicalRules(mesh)

    opt = adamw(lr=cosine_schedule(args.lr, args.steps // 10, args.steps),
                quantize_v=args.quantize_v)
    ts = build_train_step(model, opt, accum=args.accum,
                          cast_bf16=args.bf16_wire)

    with sharding_ctx(mesh, rules), mesh:
        params = model.init_params(args.seed)
        p_sh = tree_shardings(rules, model.param_shapes(),
                              model.param_axes())
        params = jax.tree.map(jax.device_put, params, p_sh)
        opt_state = opt.init(params)
        step_jit = jax.jit(lambda p, s, b: ts(p, s, b),
                           donate_argnums=(0, 1))

        pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq,
                             seed=args.seed)
        print(f"[train] {cfg.name}: {model.param_count() / 1e6:.1f}M "
              f"params on mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}")

        sup = Supervisor(Checkpointer(args.ckpt, keep=2),
                         checkpoint_every=args.ckpt_every)
        t0 = time.time()
        losses = []

        def step_fn(state, step):
            p, s = state
            b = {k: jnp.asarray(v) for k, v in
                 pipe.batch_at(step).items()}
            p, s, m = step_jit(p, s, b)
            losses.append(float(m["loss"]))
            if step % 20 == 0:
                tok_s = (args.batch * args.seq * (step + 1)
                         / max(time.time() - t0, 1e-9))
                print(f"[train] step {step:5d} "
                      f"loss {np.mean(losses[-20:]):.4f} "
                      f"({tok_s:,.0f} tok/s)", flush=True)
            return (p, s)

        start = 0
        latest = sup.checkpointer.latest_step()
        if latest is not None:
            print(f"[train] resuming from checkpoint step {latest}")
            state, man = sup.checkpointer.restore((params, opt_state))
            params, opt_state = state
            start = latest
        sup.run((params, opt_state), step_fn, start,
                args.steps - start)
        print(f"[train] done: final loss "
              f"{np.mean(losses[-20:]) if losses else float('nan'):.4f}")


if __name__ == "__main__":
    main()
