import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record the roofline inputs.

For each cell this script:
  1. builds the model + the step function the shape's kind dictates
     (train_step with AdamW for train_*, prefill for prefill_*, one-token
     decode for decode_*/long_*);
  2. resolves in/out shardings from the logical axes via LogicalRules;
  3. ``jax.jit(...).lower(...)`` then ``.compile()`` — a sharding
     mismatch, compile-time OOM, or unsupported collective here is a bug
     in the framework, not in the launcher;
  4. records memory_analysis, cost_analysis (HLO FLOPs / bytes), the
     collective schedule parsed from the partitioned HLO (with while-loop
     trip-count weighting), and analytic per-device byte budgets;
  5. writes one JSON artifact per cell to --out.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch qwen2-0.5b --shape train_4k --mesh both --out artifacts/
    PYTHONPATH=src python -m repro.launch.dryrun --all --out artifacts/

The XLA_FLAGS line above MUST run before any other import so the CPU
platform exposes 512 placeholder devices for jax.make_mesh.  Smoke tests
and benchmarks never import this module.
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ASSIGNED_ARCHS, get_config, get_shape,
                           ALL_SHAPES, shape_skip_reason)
from repro.distributed.sharding import (LogicalRules, replicated_like,
                                        tree_shardings)
from repro.launch.hlo_stats import HloStats
from repro.launch.mesh import make_production_mesh
from repro.models.common import sharding_ctx
from repro.models.model import build_model
from repro.optim import adamw, cosine_schedule
from repro.train import build_train_step

# v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def _shard_count(sharding: NamedSharding) -> int:
    m = sharding.mesh
    sizes = dict(zip(m.axis_names, m.devices.shape))
    n = 1
    for entry in sharding.spec:
        if entry is None:
            continue
        for ax in (entry if isinstance(entry, tuple) else (entry,)):
            n *= sizes[ax]
    return n


def _bytes_per_device(sds_tree, sharding_tree) -> float:
    total = 0.0
    for sds, sh in zip(jax.tree.leaves(sds_tree),
                       jax.tree.leaves(sharding_tree, is_leaf=lambda x:
                                       isinstance(x, NamedSharding))):
        nbytes = float(np.prod(sds.shape)) * jnp.dtype(sds.dtype).itemsize
        total += nbytes / _shard_count(sh)
    return total


def build_cell(arch: str, shape_name: str, mesh, quantize_v: bool = False):
    """Returns (fn, args_sds tuple, in_shardings, out_shardings,
    byte_budget dict)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    model = build_model(cfg)
    rules = LogicalRules(mesh)

    p_sds = model.param_shapes()
    p_axes = model.param_axes()
    p_sh = tree_shardings(rules, p_sds, p_axes)

    batch_sds = model.input_specs(shape)
    batch_axes = model.input_axes(shape)
    b_sh = tree_shardings(rules, batch_sds, batch_axes)

    budget = {"params": _bytes_per_device(p_sds, p_sh),
              "inputs": _bytes_per_device(batch_sds, b_sh)}

    if shape.kind == "train":
        opt = adamw(lr=cosine_schedule(3e-4, 100, 10_000),
                    quantize_v=quantize_v)
        ts = build_train_step(model, opt)
        o_sds = jax.eval_shape(opt.init, p_sds)
        o_axes = opt.state_axes(p_axes)
        o_sh = tree_shardings(rules, o_sds, o_axes)
        budget["opt"] = _bytes_per_device(o_sds, o_sh)

        def fn(params, opt_state, batch):
            return ts(params, opt_state, batch)

        met_sds = jax.eval_shape(fn, p_sds, o_sds, batch_sds)[2]
        out_sh = (p_sh, o_sh, replicated_like(mesh, met_sds))
        return (fn, (p_sds, o_sds, batch_sds), (p_sh, o_sh, b_sh),
                out_sh, budget, model)

    if shape.kind == "prefill":
        def fn(params, batch):
            return model.prefill(params, batch)
        logits_sh = NamedSharding(
            mesh, rules.pspec_for_shape(
                (shape.global_batch, cfg.vocab_size), ("batch", "vocab")))
        _, cache_axes = model.make_cache(shape.global_batch, shape.seq_len)
        cache_sds = jax.eval_shape(fn, p_sds, batch_sds)[1]
        cache_sh = tree_shardings(rules, cache_sds, cache_axes)
        budget["cache"] = _bytes_per_device(cache_sds, cache_sh)
        return (fn, (p_sds, batch_sds), (p_sh, b_sh),
                (logits_sh, cache_sh), budget, model)

    # decode
    cache_sds = batch_sds["cache"]
    cache_axes = model.input_axes(shape)["cache"]
    cache_sh = tree_shardings(rules, cache_sds, cache_axes)
    tok_sh = NamedSharding(mesh, rules.pspec_for_shape(
        (shape.global_batch, 1), ("batch", None)))
    pos_sh = NamedSharding(mesh, rules.pspec_for_shape(
        (shape.global_batch,), ("batch",)))
    logits_sh = NamedSharding(mesh, rules.pspec_for_shape(
        (shape.global_batch, cfg.vocab_size), ("batch", "vocab")))
    budget["cache"] = _bytes_per_device(cache_sds, cache_sh)

    def fn(params, token, pos, cache):
        return model.decode_step(params, token, pos, cache)

    return (fn, (p_sds, batch_sds["token"], batch_sds["pos"], cache_sds),
            (p_sh, tok_sh, pos_sh, cache_sh),
            (logits_sh, cache_sh), budget, model)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             quantize_v: Optional[bool] = None) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "status": "?",
    }
    skip = shape_skip_reason(cfg, shape)
    if skip:
        rec["status"] = "skip"
        rec["skip_reason"] = skip
        return rec
    if quantize_v is None:
        # grok's 314B x 12 bytes of fp32 Adam state does not fit 256 chips;
        # the 8-bit second moment is the documented production setting
        quantize_v = arch == "grok-1-314b"
    rec["quantize_v"] = bool(quantize_v)
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        rules = LogicalRules(mesh)
        t0 = time.monotonic()
        with sharding_ctx(mesh, rules):
            fn, args, in_sh, out_sh, budget, model = build_cell(
                arch, shape_name, mesh, quantize_v)
            donate = (0, 1) if shape.kind == "train" else \
                ((3,) if shape.kind == "decode" else ())
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            with mesh:
                lowered = jitted.lower(*args)
                t_lower = time.monotonic() - t0
                t0 = time.monotonic()
                compiled = lowered.compile()
                t_compile = time.monotonic() - t0
        rec["lower_s"] = round(t_lower, 2)
        rec["compile_s"] = round(t_compile, 2)
        rec["bytes_per_device"] = {k: float(v) for k, v in budget.items()}
        rec["params_total"] = model.param_count()
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            rec["cost_analysis"] = {
                k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or "utilization" not in k)
            }
        except Exception as e:            # pragma: no cover
            rec["cost_analysis_error"] = repr(e)
        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: float(getattr(ma, k)) for k in dir(ma)
                if not k.startswith("_")
                and isinstance(getattr(ma, k), (int, float))}
        except Exception as e:            # pragma: no cover
            rec["memory_analysis_error"] = repr(e)
        hlo = compiled.as_text()
        st = HloStats(hlo)
        rec["collectives"] = st.collectives
        rec["ici_bytes"] = st.ici_bytes
        rec["hlo_flops"] = st.flops          # per device, loop-weighted
        rec["hlo_bytes"] = st.bytes
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = repr(e)
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true",
                    help="re-run cells that already have artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else [s.name for s in ALL_SHAPES]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                path = os.path.join(
                    args.out,
                    f"{arch}_{shape_name}_{mesh_name}.json".replace(
                        "/", "_"))
                if os.path.exists(path) and not args.force:
                    print(f"[dryrun] cached  {arch} {shape_name} "
                          f"{mesh_name}")
                    continue
                t0 = time.monotonic()
                rec = run_cell(arch, shape_name, multi)
                dt = time.monotonic() - t0
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1, default=float)
                flops = rec.get("hlo_flops", 0)
                print(f"[dryrun] {rec['status']:5s} {arch:20s} "
                      f"{shape_name:12s} {mesh_name:8s} {dt:7.1f}s "
                      f"GFLOP={flops/1e9:12.1f} "
                      f"ici={rec.get('ici_bytes', 0)/1e6:10.1f}MB",
                      flush=True)
                if rec["status"] == "fail":
                    print(rec["error"], flush=True)


if __name__ == "__main__":
    main()
