import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Perf hillclimb harness (§Perf deliverable).

Runs one (arch x shape) cell under named optimization VARIANTS, records
the three roofline terms + the top collective contributors per variant,
and prints the before/after comparison the EXPERIMENTS.md §Perf log is
written from.

Variants are config/step-level knobs (no code forking):
    baseline              paper-faithful defaults (remat=full, fp32
                          master weights on the wire, replicated
                          attention when heads don't divide)
    bf16_wire             TrainStep.cast_bf16 — fp32->bf16 cast at step
                          entry so FSDP all-gathers move bf16
    remat_dots            remat policy "dots" (keep matmul outputs;
                          trades HBM bytes for recompute FLOPs)
    remat_none            no remat (max memory, min FLOPs)
    qseq_sp               ModelConfig.attention_qseq_sp — context-
                          parallel attention for head counts that don't
                          divide the model axis
    serve_bf16            serving params held in bf16 (decode/prefill
                          cells; halves the weight-read memory term)

Usage:
    PYTHONPATH=src python -m repro.launch.perf --arch qwen2-0.5b \
        --shape train_4k --variants baseline,bf16_wire,qseq_sp
"""

import argparse
import dataclasses
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_shape
from repro.distributed.sharding import LogicalRules, replicated_like, \
    tree_shardings
from repro.launch.hlo_stats import HloStats
from repro.launch.mesh import make_production_mesh
from repro.models.common import sharding_ctx
from repro.models.model import Model, build_model
from repro.optim import adamw, cosine_schedule
from repro.train import build_train_step

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


VARIANTS: Dict[str, Dict[str, Any]] = {
    "baseline": {},
    "bf16_wire": {"cast_bf16": True},
    "remat_dots": {"remat": "dots"},
    "remat_none": {"remat": "none"},
    "qseq_sp": {"attention_qseq_sp": True},
    "serve_bf16": {"serve_bf16": True},
    # combos
    "bf16+dots": {"cast_bf16": True, "remat": "dots"},
    "bf16+qseq": {"cast_bf16": True, "attention_qseq_sp": True},
    "bf16+qseq+dots": {"cast_bf16": True, "attention_qseq_sp": True,
                       "remat": "dots"},
    "bf16+none": {"cast_bf16": True, "remat": "none"},
}


def run_variant(arch: str, shape_name: str, overrides: Dict[str, Any],
                multi_pod: bool = False) -> Dict[str, Any]:
    cfg = get_config(arch)
    cfg_kw = {k: v for k, v in overrides.items()
              if k in ("remat", "attention_qseq_sp")}
    if cfg_kw:
        cfg = dataclasses.replace(cfg, **cfg_kw)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = LogicalRules(mesh)
    model = Model(cfg)
    t0 = time.monotonic()
    with sharding_ctx(mesh, rules):
        p_sds = model.param_shapes()
        if overrides.get("serve_bf16") and shape.kind != "train":
            p_sds = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
                if s.dtype == jnp.float32 and len(s.shape) >= 2 else s,
                p_sds)
        p_axes = model.param_axes()
        p_sh = tree_shardings(rules, p_sds, p_axes)
        batch_sds = model.input_specs(shape)
        b_sh = tree_shardings(rules, batch_sds, model.input_axes(shape))

        if shape.kind == "train":
            opt = adamw(lr=cosine_schedule(3e-4, 100, 10_000),
                        quantize_v=arch == "grok-1-314b")
            ts = build_train_step(model, opt,
                                  cast_bf16=bool(
                                      overrides.get("cast_bf16")))
            o_sds = jax.eval_shape(opt.init, p_sds)
            o_sh = tree_shardings(rules, o_sds, opt.state_axes(p_axes))

            def fn(params, opt_state, batch):
                return ts(params, opt_state, batch)
            met_sds = jax.eval_shape(fn, p_sds, o_sds, batch_sds)[2]
            args = (p_sds, o_sds, batch_sds)
            in_sh = (p_sh, o_sh, b_sh)
            out_sh = (p_sh, o_sh, replicated_like(mesh, met_sds))
            donate = (0, 1)
        elif shape.kind == "prefill":
            def fn(params, batch):
                return model.prefill(params, batch)
            _, cache_axes = model.make_cache(shape.global_batch,
                                             shape.seq_len)
            cache_sds = jax.eval_shape(fn, p_sds, batch_sds)[1]
            cache_sh = tree_shardings(rules, cache_sds, cache_axes)
            from jax.sharding import NamedSharding
            logits_sh = NamedSharding(mesh, rules.pspec_for_shape(
                (shape.global_batch, cfg.vocab_size),
                ("batch", "vocab")))
            args = (p_sds, batch_sds)
            in_sh = (p_sh, b_sh)
            out_sh = (logits_sh, cache_sh)
            donate = ()
        else:
            cache_sds = batch_sds["cache"]
            cache_axes = model.input_axes(shape)["cache"]
            cache_sh = tree_shardings(rules, cache_sds, cache_axes)
            from jax.sharding import NamedSharding
            tok_sh = NamedSharding(mesh, rules.pspec_for_shape(
                (shape.global_batch, 1), ("batch", None)))
            pos_sh = NamedSharding(mesh, rules.pspec_for_shape(
                (shape.global_batch,), ("batch",)))
            logits_sh = NamedSharding(mesh, rules.pspec_for_shape(
                (shape.global_batch, cfg.vocab_size),
                ("batch", "vocab")))

            def fn(params, token, pos, cache):
                return model.decode_step(params, token, pos, cache)
            args = (p_sds, batch_sds["token"], batch_sds["pos"],
                    cache_sds)
            in_sh = (p_sh, tok_sh, pos_sh, cache_sh)
            out_sh = (logits_sh, cache_sh)
            donate = (3,)

        with mesh:
            compiled = jax.jit(fn, in_shardings=in_sh,
                               out_shardings=out_sh,
                               donate_argnums=donate).lower(
                                   *args).compile()
    st = HloStats(compiled.as_text())
    return {
        "arch": arch, "shape": shape_name,
        "overrides": {k: v for k, v in overrides.items()},
        "compile_s": round(time.monotonic() - t0, 1),
        "compute_s": st.flops / PEAK_FLOPS,
        "memory_s": st.bytes / HBM_BW,
        "collective_s": st.ici_bytes / ICI_BW,
        "hlo_flops": st.flops, "hlo_bytes": st.bytes,
        "ici_bytes": st.ici_bytes,
        "collectives": st.collectives,
        "top_collectives": st.top_collectives[:10],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--out", default=None)
    ap.add_argument("--top", action="store_true",
                    help="print top collective contributors")
    args = ap.parse_args()
    results = []
    for name in args.variants.split(","):
        rec = run_variant(args.arch, args.shape, VARIANTS[name])
        rec["variant"] = name
        results.append(rec)
        dom = max(("compute_s", "memory_s", "collective_s"),
                  key=lambda k: rec[k])
        print(f"[perf] {name:16s} compile={rec['compile_s']:6.1f}s "
              f"compute={rec['compute_s']:.3e} "
              f"memory={rec['memory_s']:.3e} "
              f"collective={rec['collective_s']:.3e}  <-{dom}",
              flush=True)
        if args.top:
            for t in rec["top_collectives"][:6]:
                print(f"        {t['kind']:18s} {t['dtype']:5s} "
                      f"x{t['weight']:<6.0f} "
                      f"{t['ici_bytes'] / 1e9:8.2f}GB  "
                      f"{t['op_name'][:90]}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=float)


if __name__ == "__main__":
    main()
